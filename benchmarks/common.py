"""Shared benchmark helpers."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import make_pipeline
from repro.models.registry import get_family
from repro.nn import init
from repro.optim import make_optimizer, warmup_constant
from repro.train.state import init_train_state
from repro.train.trainer import make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments")


def bench_config(layers=3, d_model=128, d_ff=256, experts=16, vocab=4096,
                 **moe_kw) -> ModelConfig:
    """CPU-scale stand-in for the paper's 'base' MoE model: same topology
    (MoE FFN every layer, LayerNorm/gelu/learned positions), reduced dims."""
    from repro.configs.base import MoEConfig

    moe = dict(num_experts=experts, routing="topk", top_k=1, group_size=256,
               capacity_factor=1.25, aux_loss_coef=0.0)
    moe.update(moe_kw)
    return ModelConfig(
        name="bench", family="decoder_lm", num_layers=layers, d_model=d_model,
        num_heads=4, num_kv_heads=4, d_ff=d_ff, vocab_size=vocab,
        max_seq_len=512, norm="layernorm", ffn_activation="gelu",
        pos_embed="learned", tie_embeddings=True, dtype="float32",
        remat=False, moe=MoEConfig(**moe))


# The ablation-grid helper is shared with the paper configs — one
# definition keeps benchmark cells and config variants in sync.
from repro.configs.m6 import variant  # noqa: E402,F401


def train_run(cfg: ModelConfig, steps: int, batch: int, seq: int, lr=3e-3,
              seed=0, log_every=1) -> List[Dict]:
    fam = get_family(cfg)
    tc = TrainConfig(optimizer="adamw", learning_rate=lr,
                     warmup_steps=max(steps // 10, 1))
    params = init(fam.specs(cfg), jax.random.PRNGKey(seed))
    opt = make_optimizer(tc, warmup_constant(tc.learning_rate, tc.warmup_steps))
    state = init_train_state(params, opt, tc.grad_compression)
    step = jax.jit(make_train_step(cfg, tc, opt))
    pipe = make_pipeline(cfg, batch, seq, seed=seed)
    logs = []
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        t0 = time.time()
        state, m = step(state, b)
        m["loss"].block_until_ready()
        if i % log_every == 0 or i == steps - 1:
            logs.append({"step": i, "loss": float(m["loss"]), "ce": float(m["ce"]),
                         "cv": float(jnp.mean(m.get("moe_cv", jnp.zeros(())))),
                         "cv_per_layer": [float(x) for x in jnp.atleast_1d(
                             m.get("moe_cv", jnp.zeros(())))],
                         "dropped": float(jnp.mean(m.get("moe_dropped_fraction",
                                                         jnp.zeros(())))),
                         "t": time.time() - t0})
    return logs


def time_step(cfg: ModelConfig, batch: int, seq: int, iters: int = 8, seed=0) -> Dict:
    """Median wall-clock per train step (ms), post-warmup."""
    fam = get_family(cfg)
    tc = TrainConfig(optimizer="adamw", learning_rate=1e-3)
    params = init(fam.specs(cfg), jax.random.PRNGKey(seed))
    opt = make_optimizer(tc, warmup_constant(tc.learning_rate, 10))
    state = init_train_state(params, opt, tc.grad_compression)
    step = jax.jit(make_train_step(cfg, tc, opt))
    pipe = make_pipeline(cfg, batch, seq, seed=seed)
    b = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    state, m = step(state, b)  # compile + warmup
    m["loss"].block_until_ready()
    times = []
    for _ in range(iters):
        t0 = time.time()
        state, m = step(state, b)
        m["loss"].block_until_ready()
        times.append((time.time() - t0) * 1e3)
    times.sort()
    return {"ms_per_step": times[len(times) // 2], "min_ms": times[0]}


def train_flops(cfg: ModelConfig, batch: int, seq: int) -> float:
    """Compiled-HLO FLOPs of one (unrolled) train step — Table 1's metric."""
    from repro.train.losses import total_loss
    from repro.nn import abstract
    from repro.configs.base import ShapeConfig

    cfgp = cfg.replace(scan_layers=False, remat=False)
    fam = get_family(cfgp)
    shape = ShapeConfig("probe", seq_len=seq, global_batch=batch, kind="train")
    params = abstract(fam.specs(cfgp))
    b = fam.input_specs(cfgp, shape)

    def f(p, bb):
        logits, aux = fam.forward(p, bb, cfgp)
        return total_loss(logits, bb["labels"], aux)[0]

    from repro.distributed.costs import cost_analysis_dict

    c = jax.jit(jax.grad(f)).lower(params, b).compile()
    return float(cost_analysis_dict(c)["flops"])


def save_result(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path
