"""Run every paper-table/figure benchmark.  Prints ``name,key,value`` CSV
lines and writes JSON artifacts to experiments/.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run table2     # one
"""
from __future__ import annotations

import sys
import time

BENCHES = ["table1", "table2", "fig1", "fig3", "fig4", "fig6", "roofline"]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    for name in BENCHES:
        if only and name != only:
            continue
        t0 = time.time()
        print(f"=== {name} ===", flush=True)
        if name == "table1":
            from benchmarks import table1_flops as m
        elif name == "table2":
            from benchmarks import table2_speed as m
        elif name == "fig1":
            from benchmarks import fig1_load_balance as m
        elif name == "fig3":
            from benchmarks import fig3_quality as m
        elif name == "fig4":
            from benchmarks import fig4_moe_attention as m
        elif name == "fig6":
            from benchmarks import fig6_scaling as m
        else:
            from benchmarks import roofline as m
        m.main()
        print(f"=== {name} done in {time.time() - t0:.1f}s ===", flush=True)


if __name__ == "__main__":
    main()
