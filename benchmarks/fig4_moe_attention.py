"""Fig. 4: MoE attention (Q/K/V/O as experts) vs the MoE-FFN baseline.

Paper claims: MoE attention *hurts* quality / is unstable; k top-1
prototyping partially mitigates; deeper models with fewer experts behave
better but still trail the baseline.
"""
from __future__ import annotations

from benchmarks.common import bench_config, save_result, train_run, variant


def run(steps=160, batch=16, seq=64):
    base = bench_config(layers=2, d_model=96, d_ff=192, experts=8, vocab=512)
    runs = {
        "moe_ffn_baseline": base.replace_moe(top_k=1),
        "moe_attention_top1": base.replace_moe(top_k=1, moe_attention=True),
        "moe_attention_2top1": variant(base, "prototype", 2).replace_moe(
            moe_attention=True),
    }
    # deeper, fewer experts (paper's right plot)
    deep = bench_config(layers=4, d_model=96, d_ff=192, experts=4, vocab=512)
    runs["deep_moe_attention_top1"] = deep.replace_moe(top_k=1, moe_attention=True)
    runs["deep_moe_ffn_baseline"] = deep.replace_moe(top_k=1)
    return {name: train_run(cfg, steps, batch, seq, lr=5e-3, log_every=20)
            for name, cfg in runs.items()}


def main():
    out = run()
    print("fig4,run,final_ce,diverged")
    summary = {}
    for name, logs in out.items():
        ce = logs[-1]["ce"]
        diverged = any(r["ce"] != r["ce"] or r["ce"] > 1e3 for r in logs)
        summary[name] = {"final_ce": ce, "diverged": diverged}
        print(f"fig4,{name},{ce:.4f},{diverged}")
    save_result("fig4_moe_attention", {"curves": out, "summary": summary})
    return summary


if __name__ == "__main__":
    main()
