"""Fig. 1: development of per-layer compute-load c_v with vs without the
auxiliary balancing loss, and the (non-)translation to model quality.

Paper claims: (a) aux loss drives c_v to ~0.3 at every layer quickly;
(b) without it some layers stay/return imbalanced; (c) the better balance
does NOT buy better final log-ppl (their aux run was slightly WORSE).
"""
from __future__ import annotations

from benchmarks.common import bench_config, save_result, train_run


def run(steps=120, batch=16, seq=64):
    base = bench_config(layers=3, experts=8).replace_moe(top_k=1)
    out = {}
    for name, coef in [("baseline", 0.0), ("aux_loss", 0.01)]:
        cfg = base.replace_moe(aux_loss_coef=coef)
        out[name] = train_run(cfg, steps, batch, seq, log_every=10)
    return out


def main():
    out = run()
    print("fig1,run,step,loss,cv_mean")
    for name, logs in out.items():
        for row in logs:
            print(f"fig1,{name},{row['step']},{row['ce']:.4f},{row['cv']:.3f}")
    final_cv = {k: v[-1]["cv"] for k, v in out.items()}
    final_ce = {k: v[-1]["ce"] for k, v in out.items()}
    print(f"fig1,final_cv,aux={final_cv['aux_loss']:.3f},base={final_cv['baseline']:.3f}")
    print(f"fig1,final_ce,aux={final_ce['aux_loss']:.4f},base={final_ce['baseline']:.4f}")
    # reproduce the paper's balance claim: aux loss yields much lower c_v
    assert final_cv["aux_loss"] < final_cv["baseline"]
    save_result("fig1_load_balance", out)
    return out


if __name__ == "__main__":
    main()
