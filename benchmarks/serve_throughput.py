"""Static vs. continuous batching on a mixed-length request trace, plus
the speculative-decoding sweep.

The static engine pays lockstep: every batch member decodes until the
batch's *longest* generation finishes, so a long-tailed gen-length mix
leaves most slots doing useless work.  The continuous engine evicts on
completion and refills the slot from the queue.  Same model, same
requests, same useful-token count — the artifact records tokens/s and
latency percentiles for both.

The speculative sweep then runs the continuous engine speculative
off / ngram-drafter / model-drafter on the same synthetic mixed-length
trace (greedy, so every cell is token-identical by construction),
recording acceptance rate, mean emitted tokens per verify step and the
throughput speedup over non-speculative continuous batching.  Every
speculative run re-asserts slot/block/reservation conservation after
*every* engine step (``check_invariants=True``).

The prefix-caching sweep serves a multi-tenant trace off/cold/warm on a
block-starved pool, and the SLO sweep serves a 2x-overload bursty
mixed-priority trace under fcfs vs the SLO-aware policies with
preemption + KV swap-to-host.

The mesh sweep serves one mixed trace on 1 vs 8 virtual devices
(single-device engine vs 1x1 / 2x4 / 8x1 ``(data, expert)`` serving
meshes, dropless throughout) and asserts token identity across every
cell — mesh sharding must be invisible in outputs.

The KV-quantization sweep serves the same trace with the KV cache at
none / int8 / fp8 (greedy token-match rate + max logit divergence per
cell), then re-serves a block-starved trace on pools sized to one
fixed byte budget — the capacity int8 quantization buys.

  PYTHONPATH=src python benchmarks/serve_throughput.py
  -> experiments/BENCH_serve_throughput.json
  -> experiments/BENCH_spec_decode.json
  -> experiments/BENCH_prefix_cache.json
  -> experiments/BENCH_slo_sched.json
  -> experiments/BENCH_kv_quant.json
  -> experiments/BENCH_mesh_serve.json   (re-execs itself with 8
     virtual devices when the parent owns fewer; --mesh-sweep runs it alone)
"""
from __future__ import annotations

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import dataclasses

import jax
import numpy as np

from common import bench_config, save_result
from repro.configs.base import ServeConfig, SLOConfig, SpecConfig
from repro.obs import Observability
from repro.models.registry import get_family
from repro.nn import init
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import ServingEngine
from repro.serving.trace import (
    run_trace_static,
    static_max_len,
    synthetic_multitenant,
    synthetic_priority,
    synthetic_trace,
)

MAX_SLOTS = 4
TRACE_KW = dict(seed=0, qps=1e6,                # saturated: measure batching, not arrivals
                prompt_lens=(8, 24),
                gen_lens=(8, 8, 8, 64))         # long tail: lockstep's worst case
SPEC_GAMMA = 4


def obs_sweep(cfg, params, requests, serve: ServeConfig):
    """Instrumentation overhead: the same trace served with observability
    at its default level (registry only) vs fully on (span tracing +
    periodic metrics snapshots).  Greedy, so the cells must be
    token-identical (asserted) — instrumentation reads engine state, it
    never steers it.  The artifact records the throughput ratio; the
    in-code bound is deliberately loose (>= 0.75) because toy-model CPU
    steps are microseconds — the acceptance target (within 5%) applies
    at realistic step times where the fixed per-step cost amortises."""
    eng_off = ContinuousEngine(cfg, params, serve)
    eng_off.run(requests)                           # warmup/compile
    out_off, stats_off = eng_off.run(requests)

    obs = Observability(tracing=True)
    obs.metrics_every = 10
    eng_on = ContinuousEngine(cfg, params, serve, obs=obs)
    eng_on.run(requests)                            # warmup/compile
    out_on, stats_on = eng_on.run(requests)

    assert out_on == out_off, "observability changed generated tokens"
    ratio = (stats_on["generated_tokens_per_s"]
             / stats_off["generated_tokens_per_s"])
    assert ratio >= 0.75, f"observability overhead too high ({ratio:.2f}x)"
    return {
        "off": stats_off,
        "on": stats_on,
        "tokens_per_s_ratio_on_over_off": ratio,
        "trace_events": len(obs.tracer.events()),
        "trace_dropped_events": obs.tracer.dropped_events,
        "metrics": obs.metrics.snapshot(),
    }


def spec_sweep(cfg, params, requests, serve: ServeConfig):
    """Speculative off / ngram / model on one trace; greedy throughout,
    so outputs are token-identical across cells (asserted).

    The sweep serves with the ``dropless`` dispatcher: token-identity
    needs batch-composition-invariant routing, and a finite
    capacity_factor computes per-expert capacity from the row count —
    which differs between decode (max_slots rows) and verify
    (max_slots*(gamma+1) rows) steps, so capacity-limited cells could
    legitimately diverge (see docs/serving.md).  Same params either
    way: dispatchers are execution backends, not parameters."""
    cfg = cfg.replace_moe(impl="dropless", capacity_factor=None)
    # a deliberately tiny draft model (shared vocab, ~1/4 the target's
    # width): what the "model" drafter buys depends entirely on how well
    # it predicts the target — with both randomly initialised they
    # disagree, so this cell is the honest floor (the ngram cell needs
    # no such luck: it drafts from the slot's own context)
    dcfg = cfg.replace(name="draft", num_layers=1, d_model=32, d_ff=64,
                       num_heads=2, num_kv_heads=2,
                       moe=dataclasses.replace(cfg.moe, num_experts=0))
    dparams = init(get_family(dcfg).specs(dcfg), jax.random.PRNGKey(7))
    cells = {
        "off": (None, None),
        "ngram": (SpecConfig(drafter="ngram", gamma=SPEC_GAMMA), None),
        "model": (SpecConfig(drafter="model", gamma=SPEC_GAMMA), (dcfg, dparams)),
    }
    results, outs = {}, {}
    for name, (spec, draft_model) in cells.items():
        sv = dataclasses.replace(serve, spec=spec)
        eng = ContinuousEngine(cfg, params, sv, draft_model=draft_model,
                               check_invariants=True)
        eng.run(requests)                       # warmup/compile
        outs[name], stats = eng.run(requests)
        results[name] = stats
    results["metrics"] = eng.obs.metrics.snapshot()
    for name in ("ngram", "model"):             # greedy => identical outputs
        assert outs[name] == outs["off"], f"{name} diverged from baseline"
        results[name]["speedup_vs_off"] = (
            results[name]["generated_tokens_per_s"]
            / results["off"]["generated_tokens_per_s"])
    return results


def prefix_sweep(cfg, params):
    """Prefix caching off / cold / warm on a saturated multi-tenant
    trace over a deliberately block-starved pool (12 blocks; a request's
    cold footprint is 5, its exclusive footprint once the 3-block tenant
    system prompt is shared is 2 — so sharing admits more concurrent
    requests, not just fewer prefill steps).  Greedy + dropless
    dispatch, so all three cells are token-identical (asserted); every
    cell re-asserts refcount/reservation conservation after every
    engine step.

    "cold" is the first serve of these tenants on a compiled engine
    (within-trace live sharing only); "warm" re-serves the same trace
    with the cache populated.  Compilation is paid beforehand on a
    disjoint-tenant trace whose blocks cannot match this one."""
    cfg = cfg.replace_moe(impl="dropless", capacity_factor=None)
    trace_kw = dict(qps=1e6, num_tenants=2, system_prompt_len=48,
                    suffix_lens=(2, 12), gen_lens=(4, 8, 16))
    requests = synthetic_multitenant(16, cfg.vocab_size, seed=0, **trace_kw)
    serve = ServeConfig(max_slots=MAX_SLOTS, kv_block_size=16,
                        prefill_chunk=16, num_blocks=12,
                        max_len=max(r.total_len for r in requests))

    results = {"trace": {
        "num_requests": len(requests), **trace_kw,
        "num_blocks": serve.num_blocks,
        "prompt_lens": [r.prompt_len for r in requests],
        "gen_lens": [r.max_new_tokens for r in requests],
    }}
    outs = {}

    eng_off = ContinuousEngine(cfg, params, serve, check_invariants=True)
    eng_off.run(requests)                                  # warmup/compile
    outs["off"], results["off"] = eng_off.run(requests)

    sv = dataclasses.replace(serve, prefix_cache=True)
    eng = ContinuousEngine(cfg, params, sv, check_invariants=True)
    eng.run(synthetic_multitenant(16, cfg.vocab_size, seed=99, **trace_kw))
    outs["cold"], results["cold"] = eng.run(requests)
    outs["warm"], results["warm"] = eng.run(requests)
    results["cache_stats"] = dict(eng.cache.stats)
    results["metrics"] = eng.obs.metrics.snapshot()
    eng.cache.check_conservation()

    for name in ("cold", "warm"):
        assert outs[name] == outs["off"], f"{name} diverged from baseline"
        results[name]["speedup_vs_off"] = (
            results[name]["generated_tokens_per_s"]
            / results["off"]["generated_tokens_per_s"])
    results["effective_capacity_multiplier"] = (
        results["warm"]["peak_running"] / results["off"]["peak_running"])
    return results


def slo_sweep(cfg, params):
    """SLO scheduling under 2x overload: fcfs vs the SLO-aware policies
    (each with preemption + KV swap-to-host) on one bursty
    mixed-priority trace.

    Calibration first: a saturated fcfs run measures the engine's
    serving capacity (tokens/s), and the benchmark trace's arrival rate
    is set so the *offered* load averages twice that — the regime where
    scheduling policy decides who eats the queueing delay.  Greedy +
    dropless dispatch, so every cell is token-identical per request
    (asserted — preemption/restore must be invisible in outputs); every
    cell re-asserts slot/block/reservation conservation after every
    step, including the host-swap-pool bijection
    (``check_invariants=True`` with ``ServeConfig.slo`` set).

    Headline numbers: the fcfs→priority_strict ratio of HIGH-class p95
    latency (how much tail the priority classes buy the paying tier)
    and the throughput ratio (what the swap traffic costs)."""
    from repro.serving.request import Priority

    cfg = cfg.replace_moe(impl="dropless", capacity_factor=None)
    # the classic tiered shape: sparse short interactive HIGH requests
    # against long batch NORMAL/LOW ones — queue-jumping (and evicting a
    # long decode mid-flight) is exactly what buys HIGH its tail
    trace_kw = dict(prompt_lens=(8, 24), gen_lens=(16, 32, 64),
                    gen_lens_by_class={Priority.HIGH: (4, 8)},
                    class_weights=(0.125, 0.5, 0.375),
                    burst_len=8, system_prompt_len=16, num_tenants=2)
    # 28 blocks = 4 slots x 7-block worst case: slots, not blocks, are
    # the binding constraint, so the policies differ by *ordering* and
    # swap overhead, not by how well they pack a starved pool
    serve_kw = dict(max_slots=MAX_SLOTS, kv_block_size=16,
                    prefill_chunk=16, num_blocks=28, prefix_cache=True)

    calib = synthetic_priority(24, cfg.vocab_size, seed=1, qps=1e6,
                               **trace_kw)
    serve = ServeConfig(**serve_kw, max_len=max(r.total_len for r in calib))
    eng = ContinuousEngine(cfg, params, serve, check_invariants=True)
    eng.run(calib)                                        # warmup/compile
    _, cstats = eng.run(calib)
    cap_tok_s = cstats["generated_tokens_per_s"]
    mean_gen = float(np.mean([r.max_new_tokens for r in calib]))
    # bursts alternate q / 3q every burst_len requests: mean offered
    # rate is 1.5q, so q = (4/3) * capacity gives 2x overload overall
    qps = (4.0 / 3.0) * cap_tok_s / mean_gen
    requests = synthetic_priority(128, cfg.vocab_size, seed=0, qps=qps,
                                  burst_qps=3.0 * qps, **trace_kw)
    max_len = max(r.total_len for r in requests)
    # per-cell warmup trace: disjoint seed, so compilation is paid
    # without warming the benchmark trace's tenant prompts in the cache
    warmup = synthetic_priority(16, cfg.vocab_size, seed=99, qps=1e6,
                                **trace_kw)

    results = {"trace": {
        "num_requests": len(requests), "qps": qps, "burst_qps": 3.0 * qps,
        "capacity_tokens_per_s": cap_tok_s, "overload_factor": 2.0,
        "class_counts": {p.name.lower():
                         sum(r.priority is p for r in requests)
                         for p in sorted({r.priority for r in requests})},
    }}
    outs = {}
    for name in ("fcfs", "priority_strict", "edf", "cache_aware"):
        # host pool sized for several concurrent victims: a mirror-size
        # pool fills after a few preempted working sets, after which
        # preemption declines and HIGH waits
        slo = (SLOConfig(preemption=True, host_blocks=2 * 28)
               if name != "fcfs" else None)
        sv = ServeConfig(**serve_kw, max_len=max_len, sched_policy=name,
                         slo=slo)
        cell = ContinuousEngine(cfg, params, sv, check_invariants=True)
        cell.run(warmup)                                  # warmup/compile
        outs[name], results[name] = cell.run(requests)
    results["metrics"] = cell.obs.metrics.snapshot()
    for name in ("priority_strict", "edf", "cache_aware"):
        assert outs[name] == outs["fcfs"], (
            f"{name} diverged from fcfs outputs — preemption must be "
            f"invisible under greedy decoding")
        results[name]["tokens_per_s_vs_fcfs"] = (
            results[name]["generated_tokens_per_s"]
            / results["fcfs"]["generated_tokens_per_s"])
    results["high_p95_ratio_fcfs_over_strict"] = (
        results["fcfs"]["high_p95_ms"]
        / max(results["priority_strict"]["high_p95_ms"], 1e-9))
    return results


def _token_match(ref, got):
    """Fraction of greedy tokens identical to the baseline, per position
    per request (missing/extra positions count as mismatches)."""
    tot = hit = 0
    for uid in ref:
        a, b = ref[uid], got.get(uid, [])
        tot += max(len(a), len(b))
        hit += sum(1 for x, y in zip(a, b) if x == y)
    return hit / max(tot, 1)


def quant_sweep(cfg, params):
    """KV-cache quantization none / int8 / fp8 (repro.quant) on one
    saturated mixed-length trace, answering two questions.

    Fidelity: what does storing K/V as int8 codes + per-block scales
    cost in outputs?  Each cell records tokens/s and the greedy
    token-match rate against the f32 baseline, plus the maximum
    per-row logit divergence measured on a single-request replay
    through the engine's ``logit_tap`` (rows matched by (slot,
    position); padding rows excluded).

    Capacity: what do the saved bytes buy?  The capacity cell re-serves
    a block-starved trace on pools sized to one fixed device byte
    budget — int8 codes + scales pack ~3.9x the blocks of f32 into the
    same bytes, so block reservations stop gating admission and peak
    concurrency rises (>= 1.3x asserted) while greedy outputs stay
    >= 98% token-identical (asserted; dropless dispatch keeps routing
    batch-composition-invariant, so the only divergence source is
    quantization error itself).  Every cell re-asserts conservation
    after every step, including the code-pool/scale-pool bijection
    (``check_invariants=True``)."""
    cfg = cfg.replace_moe(impl="dropless", capacity_factor=None)
    requests = synthetic_trace(16, cfg.vocab_size, **TRACE_KW)
    serve = ServeConfig(max_slots=MAX_SLOTS, kv_block_size=16,
                        prefill_chunk=16,
                        max_len=max(r.total_len for r in requests))

    results = {"trace": {
        "num_requests": len(requests),
        "prompt_lens": [r.prompt_len for r in requests],
        "gen_lens": [r.max_new_tokens for r in requests],
    }}
    outs = {}
    for name in ("none", "int8", "fp8"):
        sv = dataclasses.replace(serve, kv_quant=name)
        eng = ContinuousEngine(cfg, params, sv, check_invariants=True)
        eng.run(requests)                       # warmup/compile
        outs[name], results[name] = eng.run(requests)
        occ = eng.cache.occupancy()[0]
        results[name]["block_bytes"] = occ["block_bytes"]
        results[name]["kv_pool_bytes"] = (occ["block_bytes"]
                                          * eng.cache.num_blocks)
        eng.cache.check_conservation()
    results["metrics"] = eng.obs.metrics.snapshot()

    # -- logit divergence: single-request greedy replay under the tap ------
    probe = synthetic_trace(1, cfg.vocab_size, seed=3, qps=1e6,
                            prompt_lens=(24, 24), gen_lens=(64, 64))

    def replay(name):
        rows = {}

        def tap(lg, slots, pos, lens):
            for i, ln in enumerate(lens):
                if ln > 0:                      # length 0 = padding row
                    rows[int(slots[i]), int(pos[i])] = np.array(lg[i])

        sv = dataclasses.replace(serve, max_slots=1, kv_quant=name,
                                 max_len=max(r.total_len for r in probe))
        eng = ContinuousEngine(cfg, params, sv, logit_tap=tap)
        return eng.run(probe)[0], rows

    base_out, base_rows = replay("none")
    results["none"]["token_match_rate"] = 1.0
    results["none"]["max_logit_divergence"] = 0.0
    for name in ("int8", "fp8"):
        out, rows = replay(name)
        common = base_rows.keys() & rows.keys()
        results[name]["max_logit_divergence"] = max(
            float(np.abs(rows[k] - base_rows[k]).max()) for k in common)
        results[name]["logit_rows_compared"] = len(common)
        results[name]["token_match_rate"] = _token_match(outs["none"],
                                                         outs[name])
        results[name]["tokens_per_s_vs_none"] = (
            results[name]["generated_tokens_per_s"]
            / results["none"]["generated_tokens_per_s"])

    # -- capacity at one fixed device byte budget ---------------------------
    # shorter generations than TRACE_KW: a 3-block worst-case footprint
    # lets the block-rich int8 pool actually run many requests at once
    # instead of queueing on slots
    cap_kw = dict(seed=0, qps=1e6, prompt_lens=(8, 16), gen_lens=(16, 24))
    cap_req = synthetic_trace(24, cfg.vocab_size, **cap_kw)
    bs = 16
    per_entry = cfg.num_kv_heads * bs * cfg.resolved_head_dim
    bbytes = {"none": 2 * cfg.num_layers * per_entry * 4,
              "int8": 2 * cfg.num_layers * (per_entry
                                            + 4 * cfg.num_kv_heads)}
    budget = 8 * bbytes["none"]                 # an 8-f32-block pool
    cap = {"trace": {"num_requests": len(cap_req), **cap_kw,
                     "budget_bytes": budget}}
    cap_outs = {}
    for name in ("none", "int8"):
        nblocks = budget // bbytes[name]
        sv = ServeConfig(max_slots=8, kv_block_size=bs, prefill_chunk=16,
                         num_blocks=nblocks, kv_quant=name,
                         max_len=max(r.total_len for r in cap_req))
        eng = ContinuousEngine(cfg, params, sv, check_invariants=True)
        assert eng.cache.block_bytes == bbytes[name], "budget math drifted"
        eng.run(cap_req)                        # warmup/compile
        cap_outs[name], cap[name] = eng.run(cap_req)
        cap[name]["num_blocks"] = nblocks
        cap[name]["kv_pool_bytes"] = nblocks * bbytes[name]
        eng.cache.check_conservation()
    cap["int8"]["token_match_rate"] = _token_match(cap_outs["none"],
                                                   cap_outs["int8"])
    cap["peak_running_multiplier"] = (
        cap["int8"]["peak_running"] / max(cap["none"]["peak_running"], 1e-9))
    assert cap["peak_running_multiplier"] >= 1.3, (
        f"equal-byte int8 pool should lift peak concurrency "
        f"({cap['peak_running_multiplier']:.2f}x)")
    assert cap["int8"]["token_match_rate"] >= 0.98, (
        f"int8 capacity cell drifted from f32 outputs "
        f"({cap['int8']['token_match_rate']:.3f} match)")
    results["capacity"] = cap
    return results


def mesh_sweep(cfg, params):
    """Single-device vs mesh-sharded serving on one mixed-length trace:
    the trivial 1x1 mesh, a (data 2, expert 4) mesh and a pure-data
    (8, 1) mesh, all dropless (the ragged EP path where the shape
    divides the device grid).  Greedy, so every cell must be
    token-identical to the unsharded engine (asserted) — on virtual CPU
    devices the collectives are pure overhead, so the artifact records
    the *cost* of sharding at toy scale next to the identity guarantee,
    not a speedup."""
    # group_size=1 keeps G = row count, which divides the 8-device grid
    # for every compiled step shape here — the ragged EP path engages on
    # the expert-sharded cells rather than falling back to GSPMD
    cfg = cfg.replace_moe(impl="dropless", capacity_factor=None, group_size=1)
    requests = synthetic_trace(16, cfg.vocab_size, **TRACE_KW)
    serve_kw = dict(max_slots=8, kv_block_size=16, prefill_chunk=16,
                    max_len=max(r.total_len for r in requests))
    cells = {
        "single": None,
        "mesh_1x1": (("data", 1), ("expert", 1)),
        "mesh_2x4": (("data", 2), ("expert", 4)),
        "mesh_8x1": (("data", 8), ("expert", 1)),
    }
    results = {"trace": {
        "num_requests": len(requests),
        "devices": jax.device_count(),
        "prompt_lens": [r.prompt_len for r in requests],
        "gen_lens": [r.max_new_tokens for r in requests],
    }}
    outs = {}
    for name, spec in cells.items():
        need = 1 if spec is None else spec[0][1] * spec[1][1]
        if jax.device_count() < need:
            results[name] = {"skipped": f"needs {need} devices"}
            continue
        sv = ServeConfig(**serve_kw, mesh=spec)
        eng = ContinuousEngine(cfg, params, sv, check_invariants=True)
        eng.run(requests)                       # warmup/compile
        outs[name], results[name] = eng.run(requests)
        eng.cache.check_conservation()
        results["metrics"] = eng.obs.metrics.snapshot()
    for name in outs:
        if name == "single":
            continue
        assert outs[name] == outs["single"], (
            f"{name} diverged from the single-device engine — mesh "
            f"sharding must be token-invisible under greedy decoding")
        results[name]["tokens_per_s_vs_single"] = (
            results[name]["generated_tokens_per_s"]
            / results["single"]["generated_tokens_per_s"])
    return results


def main_mesh():
    """The mesh sweep alone — run in an 8-virtual-device process (main()
    re-execs this when the parent owns fewer)."""
    cfg = bench_config(layers=2, d_model=64, d_ff=128, experts=8, vocab=512,
                       impl="dropless", capacity_factor=None)
    params = init(get_family(cfg).specs(cfg), jax.random.PRNGKey(0))
    res = mesh_sweep(cfg, params)
    for name in ("single", "mesh_1x1", "mesh_2x4", "mesh_8x1"):
        c = res[name]
        if "skipped" in c:
            print(f"mesh[{name}]: skipped ({c['skipped']})")
            continue
        extra = (f" ({c['tokens_per_s_vs_single']:.2f}x vs single)"
                 if "tokens_per_s_vs_single" in c else "")
        print(f"mesh[{name}]: {c['generated_tokens_per_s']:.1f} tok/s, "
              f"p50 {c['p50_ms']:.0f}ms p95 {c['p95_ms']:.0f}ms{extra}")
    path = save_result("BENCH_mesh_serve", res)
    print("wrote", path)


def main():
    cfg = bench_config(layers=2, d_model=64, d_ff=128, experts=8, vocab=512,
                       impl="gather")
    fam = get_family(cfg)
    params = init(fam.specs(cfg), jax.random.PRNGKey(0))
    requests = synthetic_trace(16, cfg.vocab_size, **TRACE_KW)
    max_total = max(r.total_len for r in requests)
    static_len = static_max_len(requests)
    serve = ServeConfig(max_slots=MAX_SLOTS, kv_block_size=16,
                        prefill_chunk=16, max_len=max_total)

    results = {"trace": {
        "num_requests": len(requests),
        "prompt_lens": [r.prompt_len for r in requests],
        "gen_lens": [r.max_new_tokens for r in requests],
    }}

    static = ServingEngine(cfg, params, max_len=static_len)
    run_trace_static(static, requests, MAX_SLOTS)          # warmup/compile
    _, results["static"] = run_trace_static(static, requests, MAX_SLOTS)

    cont = ContinuousEngine(cfg, params, serve)
    cont.run(requests)                                     # warmup/compile
    _, results["continuous"] = cont.run(requests)          # engine drains clean
    results["metrics"] = cont.obs.metrics.snapshot()

    s, c = results["static"], results["continuous"]
    results["speedup_tokens_per_s"] = (
        c["generated_tokens_per_s"] / s["generated_tokens_per_s"])
    print(f"static:     {s['generated_tokens_per_s']:.1f} tok/s, "
          f"p50 {s['p50_ms']:.0f}ms p95 {s['p95_ms']:.0f}ms")
    print(f"continuous: {c['generated_tokens_per_s']:.1f} tok/s, "
          f"p50 {c['p50_ms']:.0f}ms p95 {c['p95_ms']:.0f}ms "
          f"({results['speedup_tokens_per_s']:.2f}x)")
    results["obs"] = obs_sweep(cfg, params, requests, serve)
    print(f"obs overhead: "
          f"{results['obs']['tokens_per_s_ratio_on_over_off']:.2f}x tok/s "
          f"with tracing+metrics on "
          f"({results['obs']['trace_events']} trace events)")
    path = save_result("BENCH_serve_throughput", results)
    print("wrote", path)

    # -- speculative decoding sweep (same trace, continuous engine) --------
    spec_results = {
        "trace": results["trace"],
        "gamma": SPEC_GAMMA,
        "cells": spec_sweep(cfg, params, requests, serve),
    }
    for name in ("ngram", "model"):
        c = spec_results["cells"][name]
        print(f"spec[{name}]: {c['generated_tokens_per_s']:.1f} tok/s "
              f"({c['speedup_vs_off']:.2f}x), acceptance "
              f"{c['acceptance_rate']:.2f}, "
              f"{c['spec_tokens_per_step']:.2f} tok/verify-step")
    path = save_result("BENCH_spec_decode", spec_results)
    print("wrote", path)

    # -- prefix caching sweep (multi-tenant trace, constrained pool) -------
    pres = prefix_sweep(cfg, params)
    for name in ("off", "cold", "warm"):
        c = pres[name]
        extra = ""
        if name != "off":
            extra = (f" ({c['speedup_vs_off']:.2f}x, "
                     f"{c['cached_token_ratio']:.0%} prompt tokens cached)")
        print(f"prefix[{name}]: {c['generated_tokens_per_s']:.1f} tok/s, "
              f"p50 {c['p50_ms']:.0f}ms p95 {c['p95_ms']:.0f}ms, "
              f"peak {c['peak_running']:.0f} running{extra}")
    print(f"effective capacity multiplier "
          f"{pres['effective_capacity_multiplier']:.2f}x")
    path = save_result("BENCH_prefix_cache", pres)
    print("wrote", path)

    # -- SLO scheduling sweep (2x-overload mixed-priority trace) -----------
    sres = slo_sweep(cfg, params)
    for name in ("fcfs", "priority_strict", "edf", "cache_aware"):
        c = sres[name]
        pre = (f", {c['preemptions']:.0f} preemptions "
               f"({c['swapped_blocks']:.0f} blocks swapped)"
               if "preemptions" in c else "")
        print(f"slo[{name}]: {c['generated_tokens_per_s']:.1f} tok/s, "
              f"high p95 {c['high_p95_ms']:.0f}ms, "
              f"goodput {c.get('goodput', 0):.0%}{pre}")
    print(f"high-class p95: fcfs/priority_strict = "
          f"{sres['high_p95_ratio_fcfs_over_strict']:.2f}x")
    path = save_result("BENCH_slo_sched", sres)
    print("wrote", path)

    # -- KV-quantization sweep (fidelity + equal-byte capacity) ------------
    qres = quant_sweep(cfg, params)
    for name in ("none", "int8", "fp8"):
        c = qres[name]
        extra = ""
        if name != "none":
            extra = (f", match {c['token_match_rate']:.1%}, "
                     f"max logit drift {c['max_logit_divergence']:.3g}")
        print(f"quant[{name}]: {c['generated_tokens_per_s']:.1f} tok/s, "
              f"{c['block_bytes']} B/block{extra}")
    qc = qres["capacity"]
    print(f"quant capacity: {qc['int8']['num_blocks']} int8 vs "
          f"{qc['none']['num_blocks']} f32 blocks in "
          f"{qc['trace']['budget_bytes']} B -> "
          f"{qc['peak_running_multiplier']:.2f}x peak running, "
          f"match {qc['int8']['token_match_rate']:.1%}")
    path = save_result("BENCH_kv_quant", qres)
    print("wrote", path)

    # -- mesh-sharded serving sweep (needs 8 virtual devices) --------------
    if jax.device_count() >= 8:
        main_mesh()
    else:
        import subprocess
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        subprocess.run([sys.executable, os.path.abspath(__file__),
                        "--mesh-sweep"], check=True, env=env)


if __name__ == "__main__":
    if "--mesh-sweep" in sys.argv:
        main_mesh()
    else:
        main()
