"""Static vs. continuous batching on a mixed-length request trace.

The static engine pays lockstep: every batch member decodes until the
batch's *longest* generation finishes, so a long-tailed gen-length mix
leaves most slots doing useless work.  The continuous engine evicts on
completion and refills the slot from the queue.  Same model, same
requests, same useful-token count — the artifact records tokens/s and
latency percentiles for both.

  PYTHONPATH=src python benchmarks/serve_throughput.py
  -> experiments/BENCH_serve_throughput.json
"""
from __future__ import annotations

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import jax
import numpy as np

from common import bench_config, save_result
from repro.configs.base import ServeConfig
from repro.models.registry import get_family
from repro.nn import init
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import ServingEngine
from repro.serving.trace import run_trace_static, static_max_len, synthetic_trace

MAX_SLOTS = 4
TRACE_KW = dict(seed=0, qps=1e6,                # saturated: measure batching, not arrivals
                prompt_lens=(8, 24),
                gen_lens=(8, 8, 8, 64))         # long tail: lockstep's worst case


def main():
    cfg = bench_config(layers=2, d_model=64, d_ff=128, experts=8, vocab=512,
                       impl="gather")
    fam = get_family(cfg)
    params = init(fam.specs(cfg), jax.random.PRNGKey(0))
    requests = synthetic_trace(16, cfg.vocab_size, **TRACE_KW)
    max_total = max(r.total_len for r in requests)
    static_len = static_max_len(requests)
    serve = ServeConfig(max_slots=MAX_SLOTS, kv_block_size=16,
                        prefill_chunk=16, max_len=max_total)

    results = {"trace": {
        "num_requests": len(requests),
        "prompt_lens": [r.prompt_len for r in requests],
        "gen_lens": [r.max_new_tokens for r in requests],
    }}

    static = ServingEngine(cfg, params, max_len=static_len)
    run_trace_static(static, requests, MAX_SLOTS)          # warmup/compile
    _, results["static"] = run_trace_static(static, requests, MAX_SLOTS)

    cont = ContinuousEngine(cfg, params, serve)
    cont.run(requests)                                     # warmup/compile
    _, results["continuous"] = cont.run(requests)          # engine drains clean

    s, c = results["static"], results["continuous"]
    results["speedup_tokens_per_s"] = (
        c["generated_tokens_per_s"] / s["generated_tokens_per_s"])
    print(f"static:     {s['generated_tokens_per_s']:.1f} tok/s, "
          f"p50 {s['p50_ms']:.0f}ms p95 {s['p95_ms']:.0f}ms")
    print(f"continuous: {c['generated_tokens_per_s']:.1f} tok/s, "
          f"p50 {c['p50_ms']:.0f}ms p95 {c['p95_ms']:.0f}ms "
          f"({results['speedup_tokens_per_s']:.2f}x)")
    path = save_result("BENCH_serve_throughput", results)
    print("wrote", path)


if __name__ == "__main__":
    main()
