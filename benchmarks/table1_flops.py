"""Table 1: FLOPs of top-k vs k top-1 routing at Capacity kx and 1x.

Paper claim: with limited (1x) capacity, all strategies have ~equal
compute FLOPs; with kx capacity, FLOPs grow with k.
"""
from __future__ import annotations

from benchmarks.common import bench_config, save_result, train_flops, variant

STRATEGIES = [("topk", 1, "Top-1"), ("topk", 2, "Top-2"), ("topk", 4, "Top-4"),
              ("prototype", 2, "2 Top-1"), ("prototype", 4, "4 Top-1")]


def run(batch=4, seq=128):
    base = bench_config()
    rows = {}
    for cap_mode, cap_name in [("k", "Capacity kx"), ("one", "Capacity 1x")]:
        row = {}
        for routing, k, label in STRATEGIES:
            cfg = variant(base, routing, k, capacity_mode=cap_mode)
            row[label] = train_flops(cfg, batch, seq) / 1e9
        rows[cap_name] = row
    return rows


def main():
    rows = run()
    print("table1,strategy,gflops")
    for cap, row in rows.items():
        for label, g in row.items():
            print(f"table1,{cap}|{label},{g:.3f}")
    top1 = rows["Capacity kx"]["Top-1"]
    # paper claims: kx capacity FLOPs grow with k ...
    assert rows["Capacity kx"]["Top-4"] > 1.5 * top1
    # ... and 1x capacity keeps all strategies within ~15% of Top-1
    for label, g in rows["Capacity 1x"].items():
        assert g < 1.4 * top1, (label, g, top1)
    save_result("table1_flops", rows)
    return rows


if __name__ == "__main__":
    main()
