"""Roofline report: reads experiments/dryrun.json (written by
repro.launch.dryrun) and prints the per-(arch x shape x mesh) three-term
table for EXPERIMENTS.md S Roofline."""
from __future__ import annotations

import json
import os

from benchmarks.common import RESULTS_DIR


def main(path=None):
    path = path or os.path.join(RESULTS_DIR, "dryrun.json")
    if not os.path.exists(path):
        print("roofline,SKIPPED (run `python -m repro.launch.dryrun` first)")
        return {}
    with open(path) as f:
        results = json.load(f)
    print("roofline,cell,chips,t_compute_ms,t_memory_ms,t_collective_ms,"
          "dominant,model/hlo_flops,mfu_bound,mem_gb_per_dev,fits_16gb")
    for key in sorted(results):
        v = results[key]
        if v.get("status") != "ok":
            print(f"roofline,{key},ERROR,{v.get('error', '')[:80]}")
            continue
        rl = v["roofline"]
        mem = v["memory"]["peak_bytes_per_device"] / 1e9
        ratio = rl.get("useful_flops_ratio")
        mfu = rl.get("mfu_bound")
        print(f"roofline,{key},{v['chips']},{rl['t_compute']*1e3:.2f},"
              f"{rl['t_memory']*1e3:.2f},{rl['t_collective']*1e3:.2f},"
              f"{rl['dominant']},{0 if not ratio else round(ratio, 3)},"
              f"{0 if not mfu else round(mfu, 3)},{mem:.2f},"
              f"{v['memory']['fits_16gb']}")
    return results


if __name__ == "__main__":
    main()
