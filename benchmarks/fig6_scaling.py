"""Fig. 6 / Table 5: scaling-law ordering and the paper's exact configs.

At CPU scale we verify (a) the Table 5 parameter counts EXACTLY
(1.4B/10.8B/103.2B/1002.7B — spec-level, no allocation), (b) the scaling-
law ordering on width-scaled toy models (more experts => lower loss at
equal steps), and (c) that prototyping beats the same-size baseline
(the paper's 1T headline, at toy scale).
"""
from __future__ import annotations

from benchmarks.common import bench_config, save_result, train_run, variant
from repro.configs.registry import get_config
from repro.models.registry import get_family
from repro.nn import count_params


def run(steps=400, batch=24, seq=64):
    out = {"param_counts": {}}
    for arch, expect in [("m6-base", 1.4e9), ("m6-10b", 10.8e9),
                         ("m6-100b", 103.2e9), ("m6-1t", 1002.7e9)]:
        cfg = get_config(arch)
        n = count_params(get_family(cfg).specs(cfg))
        out["param_counts"][arch] = {"params": n, "expected": expect,
                                     "rel_err": abs(n - expect) / expect}
    # scaling ordering: 4 vs 16 experts, same active compute (top-1)
    curves = {}
    for name, e in [("small_2e", 2), ("large_16e", 16)]:
        cfg = bench_config(layers=2, d_model=96, d_ff=192, experts=e, vocab=512)
        curves[name] = train_run(cfg.replace_moe(top_k=1), steps, batch, seq,
                                 lr=5e-3, log_every=20)
    # prototyping vs same-size baseline (the 1T-model claim, toy scale)
    big = bench_config(layers=2, d_model=96, d_ff=192, experts=16, vocab=512)
    curves["large_16e_2top1"] = train_run(variant(big, "prototype", 2), steps,
                                          batch, seq, lr=5e-3, log_every=20)
    out["curves"] = curves
    return out


def main():
    out = run()
    print("fig6,arch,params_B,rel_err")
    for arch, d in out["param_counts"].items():
        print(f"fig6,{arch},{d['params']/1e9:.2f},{d['rel_err']:.4f}")
        assert d["rel_err"] < 0.015
    finals = {k: v[-1]["ce"] for k, v in out["curves"].items()}
    for k, v in finals.items():
        print(f"fig6,{k},final_ce,{v:.4f}")
    scaling_holds = finals["large_16e"] < finals["small_2e"]
    print(f"fig6,scaling_law_holds,{scaling_holds}")
    assert finals["large_16e_2top1"] < finals["large_16e"]    # prototyping win
    out["scaling_law_holds"] = bool(scaling_holds)
    save_result("fig6_scaling", out)
    return out


if __name__ == "__main__":
    main()
