"""Table 2: training speed (ms/step) of routing strategies at Capacity 1x,
plus a beyond-paper sweep of routing strategy x execution backend.

Paper claim: the looping argmax makes top-k (k>1) markedly slower, while
k top-1 prototyping stays within a few percent of top-1.

The sweep isolates where the time goes per (strategy, dispatcher) cell
of the MoE layer forward — the dispatcher axis runs over the
``repro.core.dispatch`` registry (einsum / gather / pallas / alltoall):

* ``route_ms``  — RoutingPlan construction only (the index view);
* ``sort_ms``   — sorted/ragged view construction (``dropless`` only:
  argsort by expert id + segment offsets; 0 elsewhere);
* ``ffn_ms``    — expert FFN on an already-dispatched buffer (kernel
  FFN for the pallas dispatcher, ragged grouped GEMM over the sorted
  buffer for dropless, einsum FFN otherwise);
* ``layer_ms``  — the full layer forward through the dispatcher;
* ``dispatch_combine_ms`` — layer minus route/sort/ffn: the token
  movement cost (the einsum backend pays O(T*E*C*M) one-hot
  contractions here, index-view backends pay O(k*T*M));
* ``dropped_fraction`` — the layer's dropped-token metric for the cell
  (identically 0.0 for ``dropless``, which runs capacity_factor=None;
  capacity-ful cells run the paper's Capacity-1x convention).

Caveat for the ``EC Top-C x dropless`` cell: for expert-choice, capacity
IS the routing rule, so its capacity-infinity limit is every expert
selecting every token — that cell measures a dense all-experts model by
construction (see docs/moe_architecture.md), which is why its ffn time
towers over the token-choice dropless cells.

Note: on a single device (this benchmark) the ``alltoall`` dispatcher
has no expert-sharded mesh and degrades to its gather fallback, so its
column measures the fallback dispatch; on a mesh it additionally pays
the two all_to_all collectives.

Results land in experiments/table2_speed.json (paper table) and
experiments/BENCH_table2_speed_sweep.json (per-strategy/dispatcher
breakdown).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import bench_config, save_result, time_step, variant

STRATEGIES = [("topk", 1, "Top-1"), ("topk", 2, "Top-2"), ("topk", 4, "Top-4"),
              ("prototype", 2, "2 Top-1"), ("prototype", 4, "4 Top-1")]

SWEEP_STRATEGIES = STRATEGIES + [("expert_choice", 2, "EC Top-C"),
                                 ("hash", 1, "Hash-1")]
SWEEP_DISPATCHERS = ("einsum", "gather", "pallas", "alltoall", "dropless")


def run(batch=8, seq=256, experts=32):
    base = bench_config(experts=experts).replace_moe(capacity_mode="one")
    out = {}
    for routing, k, label in STRATEGIES:
        cfg = variant(base, routing, k, capacity_mode="one")
        out[label] = time_step(cfg, batch, seq)["ms_per_step"]
    return out


def _median_ms(fn, *args, iters=16):
    fn(*args).block_until_ready()  # compile + warmup
    times = []
    for _ in range(iters):
        t0 = time.time()
        fn(*args).block_until_ready()
        times.append((time.time() - t0) * 1e3)
    times.sort()
    return times[len(times) // 2]


def time_moe_layer(cfg, batch, seq, iters=16):
    """Per-phase forward timings of one MoE layer (see module docstring)."""
    from repro.core import moe
    from repro.core.dispatch import expert_ffn
    from repro.core.dispatch.dropless import plan_block_rows
    from repro.core.routing import route
    from repro.kernels.moe_dropless import ops as dropless_ops
    from repro.nn import init

    m = cfg.moe
    params = init(moe.moe_ffn_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, seq, cfg.d_model),
                          cfg.activation_dtype)
    xg, G = moe.group_tokens(x, m)
    T = xg.shape[1]
    capacity = m.capacity(T)

    def route_only(p, xx):
        xgg, _ = moe.group_tokens(xx, m)
        w = p.get("router")
        plan = route(xgg, None if w is None else w.astype(jnp.float32), m, capacity)
        return jnp.sum(plan.masked_gate) + plan.aux_loss

    sort_ms = 0.0
    if m.impl == "dropless":
        w = params.get("router")
        plan = route(xg, None if w is None else w.astype(jnp.float32),
                     m, capacity)
        bx = plan_block_rows(plan)
        # sort split: ragged-view construction off a fixed plan
        sort_fn = jax.jit(lambda pl: jnp.sum(pl.ragged(bx).gate))
        sort_ms = _median_ms(sort_fn, plan, iters=iters)
        rag = plan.ragged(bx)
        R = rag.token.shape[1]
        buf = jax.random.normal(jax.random.PRNGKey(2), (G * R, cfg.d_model),
                                cfg.activation_dtype)
        be = rag.block_expert.reshape(-1)
        gate_w = params.get("gate")
        ffn_only = jax.jit(lambda p, b: jnp.sum(dropless_ops.ragged_ffn(
            b, be, p["up"], gate_w, p["down"], cfg.ffn_activation, block_x=bx)))
    else:
        buf = jax.random.normal(jax.random.PRNGKey(2),
                                (m.num_experts, G * capacity, cfg.d_model),
                                cfg.activation_dtype)
        ffn_only = jax.jit(lambda p, b: jnp.sum(
            expert_ffn(p, b, cfg, use_kernel=m.impl == "pallas")))
    # one compile serves both the timing loop and the dropped metric
    layer = jax.jit(lambda p, xx: (
        lambda y, aux: (jnp.sum(y), aux["moe_dropped_fraction"]))(
            *moe.moe_ffn_apply(p, xx, cfg)))
    dropped = float(layer(params, x)[1])

    route_ms = _median_ms(jax.jit(route_only), params, x, iters=iters)
    ffn_ms = _median_ms(ffn_only, params, buf, iters=iters)
    layer_ms = _median_ms(lambda p, xx: layer(p, xx)[0], params, x, iters=iters)
    return {
        "route_ms": route_ms,
        "sort_ms": sort_ms,
        "ffn_ms": ffn_ms,
        "layer_ms": layer_ms,
        "dispatch_combine_ms": max(layer_ms - route_ms - sort_ms - ffn_ms, 0.0),
        "dropped_fraction": dropped,
        "capacity": capacity,
        "groups": G,
    }


def run_sweep(batch=8, seq=256, experts=32, dispatchers=SWEEP_DISPATCHERS):
    base = bench_config(experts=experts).replace_moe(capacity_mode="one")
    out = {}
    for routing, k, label in SWEEP_STRATEGIES:
        out[label] = {}
        for impl in dispatchers:
            cfg = variant(base, routing, k, capacity_mode="one").replace_moe(impl=impl)
            if impl == "dropless":
                # the backend's native mode: capacity-free, zero drops
                cfg = cfg.replace_moe(capacity_factor=None)
            out[label][impl] = time_moe_layer(cfg, batch, seq)
    return out


def main():
    out = run()
    print("table2,strategy,ms_per_step")
    for label, ms in out.items():
        print(f"table2,{label},{ms:.1f}")
    # qualitative reproduction: 4 top-1 faster than top-4 (argmax loop)
    ratio = out["Top-4"] / out["4 Top-1"]
    print(f"table2,top4_over_4top1,{ratio:.3f}")
    save_result("table2_speed", out)

    sweep = run_sweep()
    print("sweep,strategy,dispatcher,layer_ms,route_ms,sort_ms,"
          "dispatch_combine_ms,ffn_ms,dropped_fraction")
    for label, impls in sweep.items():
        for impl, r in impls.items():
            print(f"sweep,{label},{impl},{r['layer_ms']:.2f},{r['route_ms']:.2f},"
                  f"{r['sort_ms']:.2f},{r['dispatch_combine_ms']:.2f},"
                  f"{r['ffn_ms']:.2f},{r['dropped_fraction']:.4f}")
    save_result("BENCH_table2_speed_sweep", sweep)
    return out


if __name__ == "__main__":
    main()
