"""Table 2: training speed (ms/step) of routing strategies at Capacity 1x.

Paper claim: the looping argmax makes top-k (k>1) markedly slower, while
k top-1 prototyping stays within a few percent of top-1.
"""
from __future__ import annotations

from benchmarks.common import bench_config, save_result, time_step, variant

STRATEGIES = [("topk", 1, "Top-1"), ("topk", 2, "Top-2"), ("topk", 4, "Top-4"),
              ("prototype", 2, "2 Top-1"), ("prototype", 4, "4 Top-1")]


def run(batch=8, seq=256, experts=32):
    base = bench_config(experts=experts).replace_moe(capacity_mode="one")
    out = {}
    for routing, k, label in STRATEGIES:
        cfg = variant(base, routing, k, capacity_mode="one")
        out[label] = time_step(cfg, batch, seq)["ms_per_step"]
    return out


def main():
    out = run()
    print("table2,strategy,ms_per_step")
    for label, ms in out.items():
        print(f"table2,{label},{ms:.1f}")
    # qualitative reproduction: 4 top-1 faster than top-4 (argmax loop)
    ratio = out["Top-4"] / out["4 Top-1"]
    print(f"table2,top4_over_4top1,{ratio:.3f}")
    save_result("table2_speed", out)
    return out


if __name__ == "__main__":
    main()
