"""Fig. 3 / Table 3: model quality vs k for top-k and k top-1 prototyping,
under Capacity kx and Capacity 1x.

Paper claims (at base scale): (a) k>1 beats top-1 even at 1x capacity;
(b) diminishing returns from k=2 -> 4; (c) k top-1 ~= top-k at kx
capacity but loses some of its edge at 1x capacity.

The synthetic clustered-bigram LM (see repro.data.pipeline) has exactly
the mixture structure that rewards multi-expert routing, so the ordering
is observable at CPU scale.  We report final training CE ("log PPL").
"""
from __future__ import annotations

from benchmarks.common import bench_config, save_result, train_run, variant

GRID = [("topk", 1, "Top-1"), ("topk", 2, "Top-2"), ("topk", 4, "Top-4"),
        ("prototype", 2, "2 Top-1"), ("prototype", 4, "4 Top-1")]


def run(steps=150, batch=24, seq=64):
    base = bench_config(layers=2, d_model=96, d_ff=192, experts=8, vocab=512)
    out = {}
    for cap in ["k", "one"]:
        for routing, k, label in GRID:
            cfg = variant(base, routing, k, capacity_mode=cap)
            logs = train_run(cfg, steps, batch, seq, lr=5e-3, log_every=20)
            out[f"cap_{cap}|{label}"] = logs
    return out


def _final(logs, n=3):
    tail = [r["ce"] for r in logs[-n:]]
    return sum(tail) / len(tail)


def main():
    out = run()
    finals = {k: _final(v) for k, v in out.items()}
    print("fig3,setting,final_ce")
    for k, v in finals.items():
        print(f"fig3,{k},{v:.4f}")
    # headline claim: larger k beats top-1 at standard capacity
    assert finals["cap_k|Top-2"] < finals["cap_k|Top-1"]
    assert finals["cap_k|2 Top-1"] < finals["cap_k|Top-1"]
    save_result("fig3_quality", {"curves": out, "finals": finals})
    return finals


if __name__ == "__main__":
    main()
