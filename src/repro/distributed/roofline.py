"""Three-term roofline model for TPU v5e (target hardware).

  t_compute    = HLO_FLOPs  / (chips * 197e12)   bf16 peak / chip
  t_memory     = HLO_bytes  / (chips * 819e9)    HBM bandwidth / chip
  t_collective = coll_bytes / (chips * 50e9)     per-link ICI bandwidth

Inputs come from the dry-run: ``compiled.cost_analysis()`` (flops, bytes
accessed) and the HLO collective parser.  MODEL_FLOPS = 6*N_active*D
(dense: N_active = N) gives the useful-compute ratio.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 / chip (v5e)
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    chips: int
    model_flops: Optional[float] = None   # 6 * N_active * tokens

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        if not self.model_flops or not self.flops:
            return None
        return self.model_flops / self.flops

    @property
    def mfu_bound(self) -> Optional[float]:
        """Upper bound on model-FLOPs utilisation implied by the terms:
        useful FLOPs / (chips * peak * bound_time)."""
        if not self.model_flops or self.bound_time == 0:
            return None
        return self.model_flops / (self.chips * PEAK_FLOPS * self.bound_time)

    def to_dict(self) -> Dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
        }


def model_flops_train(n_params_active: float, tokens: float) -> float:
    """6*N*D for a train step (fwd 2ND + bwd 4ND)."""
    return 6.0 * n_params_active * tokens


def model_flops_forward(n_params_active: float, tokens: float) -> float:
    return 2.0 * n_params_active * tokens
