"""Analytic FLOP / HBM-byte models per (architecture family, shape).

Why analytic: XLA's HloCostAnalysis counts while-loop bodies ONCE, so
`compiled.cost_analysis()` undercounts anything inside `lax.scan` (our
layer stacks, SSD chunk scans) by the trip count.  The dry-run therefore
records raw cost_analysis output for transparency but computes roofline
terms from these models, which are validated against cost_analysis on
*unrolled* reduced-depth probes (tests/test_costs.py, EXPERIMENTS.md).

Conventions: a matmul of (m,k)x(k,n) is 2mkn FLOPs.  Backward = 2x
forward; full remat adds one forward recompute (train = 4x fwd).  Bytes
are HBM traffic with documented access-count factors — napkin-math level,
good to ~2x, which is enough to identify the dominant roofline term.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """`compiled.cost_analysis()` across jax versions: some return the
    properties dict directly, some a one-element list of it."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        if not cost:
            raise RuntimeError(
                "compiled.cost_analysis() returned no data on this backend")
        cost = cost[0]
    return cost


def moe_rows_per_token(m, tokens_per_group: int) -> float:
    """Expert-buffer rows processed per routed token (E*C / T_g).

    Capacity-ful: k_eff * gamma (padded capacity slots compute too).
    Dropless (capacity_factor=None): the sorted ragged buffer — routed
    choices (active_k per token) plus block-alignment padding, using the
    same adaptive block size the dispatcher picks.  capacity_mode does
    not clamp anything in dropless mode.
    """
    if m.capacity_factor is None:
        from repro.kernels.moe_dropless.ops import padded_rows, pick_block_rows

        n = m.active_k * tokens_per_group
        bx = pick_block_rows(n, m.num_experts)
        return padded_rows(n, m.num_experts, bx) / float(tokens_per_group)
    k_eff = 1 if m.capacity_mode == "one" else m.active_k
    return k_eff * m.capacity_factor


def _moe_terms(cfg: ModelConfig, tokens_per_group: int) -> Dict[str, float]:
    """Per-token FLOPs for router, dispatch/combine, expert FFN."""
    m = cfg.moe
    d = cfg.d_model
    if m.num_experts == 0:
        n_mats = 3 if cfg.ffn_activation in ("swiglu", "geglu") else 2
        return {"router": 0.0, "dispatch": 0.0,
                "expert": 2.0 * d * cfg.d_ff * n_mats}
    cap_total = moe_rows_per_token(m, tokens_per_group)
    router = 2.0 * d * m.num_experts
    if m.impl == "einsum":
        # dispatch 'gtec,gtm->egcm' + combine: 2 * (E*C) * M each
        dispatch = 2.0 * 2.0 * cap_total * tokens_per_group * d
    else:  # gather / pallas: data movement only
        dispatch = 0.0
    n_mats = 3 if cfg.ffn_activation in ("swiglu", "geglu") else 2
    expert = cap_total * 2.0 * d * cfg.d_ff * n_mats  # padded rows compute too
    return {"router": router, "dispatch": dispatch, "expert": expert}


def _attn_proj_flops(cfg: ModelConfig, d: float = None) -> float:
    d = d or cfg.d_model
    hd = cfg.resolved_head_dim
    return 2.0 * d * hd * (2 * cfg.num_heads + 2 * cfg.num_kv_heads)


def _lm_layer_fwd(cfg: ModelConfig, kv_len: float, tokens_per_group: int) -> float:
    """Per-token forward FLOPs of one decoder layer, attending kv_len."""
    hd = cfg.resolved_head_dim
    attn = _attn_proj_flops(cfg) + 2.0 * 2.0 * cfg.num_heads * hd * kv_len
    moe = _moe_terms(cfg, tokens_per_group)
    return attn + sum(moe.values())


def _groups(cfg: ModelConfig, total_tokens: int) -> int:
    from repro.core.moe import _largest_divisor_leq

    return _largest_divisor_leq(total_tokens, max(total_tokens // cfg.moe.group_size, 1))


def _unembed_flops(cfg: ModelConfig) -> float:
    from repro.models.layers import padded_vocab

    return 2.0 * cfg.d_model * padded_vocab(cfg.vocab_size)


# ---------------------------------------------------------------------------
# FLOPs per family
# ---------------------------------------------------------------------------

def _decoder_lm_fwd_flops(cfg: ModelConfig, tokens: float, kv_len: float) -> float:
    tpg = (cfg.moe.group_size if cfg.moe.num_experts else 1)
    per_tok = _lm_layer_fwd(cfg, kv_len, tpg) * cfg.num_layers + _unembed_flops(cfg)
    return per_tok * tokens


def _xlstm_fwd_flops(cfg: ModelConfig, tokens: float, kv_len: float, decode: bool) -> float:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    H = cfg.num_heads
    dh = d_in // H
    W = dh if decode else min(cfg.ssm_chunk, kv_len)
    n_sl = sum(1 for i in range(cfg.num_layers)
               if cfg.xlstm_slstm_period and i % cfg.xlstm_slstm_period == cfg.xlstm_slstm_period - 1)
    n_ml = cfg.num_layers - n_sl
    # mLSTM block per token
    proj = 2.0 * d * d_in * 2 + 2.0 * d_in * d_in * 3 + 2.0 * d_in * d + 2.0 * d_in * 2 * H
    cell = 4.0 * W * d_in + 6.0 * dh * d_in  # intra-chunk + state in/out
    if decode:
        cell = 6.0 * dh * d_in
    ml = proj + cell
    # sLSTM block per token
    pf = int(d * 4 / 3) // 8 * 8 or 8
    sl = 2.0 * d * 4 * d + 2.0 * d * 4 * dh + 2.0 * d * 2 * pf + 2.0 * pf * d
    return (n_ml * ml + n_sl * sl + _unembed_flops(cfg)) * tokens


def _mamba_layer_fwd(cfg: ModelConfig, decode: bool) -> float:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    N = cfg.ssm_state
    H = cfg.ssm_heads or max(d_in // 64, 1)
    P = d_in // H
    W = 1 if decode else cfg.ssm_chunk
    proj = 2.0 * d * (2 * d_in + 2 * N + H) + 2.0 * d_in * d
    conv = 2.0 * cfg.ssm_conv_width * (d_in + 2 * N)
    if decode:
        cell = 4.0 * H * P * N  # state update + readout
    else:
        # intra: scores (W*N shared + 2*W*P*H) + off/state: 4*N*P*H
        cell = 2.0 * W * N + 2.0 * W * d_in + 4.0 * N * d_in
    return proj + conv + cell


def _zamba_fwd_flops(cfg: ModelConfig, tokens: float, kv_len: float, decode: bool) -> float:
    import math

    d2 = 2 * cfg.d_model
    hd2 = d2 // cfg.num_heads
    n_shared = math.ceil(cfg.num_layers / cfg.zamba_shared_period)
    # shared block on 2d: qkvo + quadratic + gelu ffn (2 mats... ffn_specs
    # with gelu -> up+down) + out proj
    attn = (2.0 * d2 * hd2 * (2 * cfg.num_heads + 2 * cfg.num_kv_heads)
            + 4.0 * cfg.num_heads * hd2 * kv_len)
    ffn = 2.0 * d2 * cfg.d_ff * 2
    shared = attn + ffn + 2.0 * d2 * cfg.d_model
    mamba = _mamba_layer_fwd(cfg, decode) * cfg.num_layers
    return (mamba + n_shared * shared + _unembed_flops(cfg)) * tokens


def _encdec_fwd_flops(cfg: ModelConfig, tokens: float, src_len: float) -> float:
    hd = cfg.resolved_head_dim
    n_mats = 3 if cfg.ffn_activation in ("swiglu", "geglu") else 2
    ffn = 2.0 * cfg.d_model * cfg.d_ff * n_mats
    enc_layer = _attn_proj_flops(cfg) + 4.0 * cfg.num_heads * hd * src_len + ffn
    # decoder: causal self (avg kv_len/2) + cross attending src_len
    dec_layer = (_attn_proj_flops(cfg) + 4.0 * cfg.num_heads * hd * (src_len / 2)
                 + _attn_proj_flops(cfg) + 4.0 * cfg.num_heads * hd * src_len + ffn)
    n_enc = cfg.num_encoder_layers or cfg.num_layers
    return (n_enc * enc_layer + cfg.num_layers * dec_layer + _unembed_flops(cfg)) * tokens


def flops_for(cfg: ModelConfig, shape: ShapeConfig, *,
              attention_impl: str = "reference") -> float:
    """Total program FLOPs for one step of this cell."""
    S, B = shape.seq_len, shape.global_batch
    if shape.kind == "train":
        tokens, kv = float(S * B), S / 2.0
        mult = 4.0 if cfg.remat else 3.0   # fwd + (refwd) + bwd
    elif shape.kind == "prefill":
        tokens, kv, mult = float(S * B), S / 2.0, 1.0
    else:  # decode: one token against a kv_len cache
        tokens, kv, mult = float(B), float(S), 1.0

    if cfg.family == "xlstm":
        fwd = _xlstm_fwd_flops(cfg, tokens, kv, shape.kind == "decode")
    elif cfg.family == "zamba":
        fwd = _zamba_fwd_flops(cfg, tokens, kv, shape.kind == "decode")
    elif cfg.family == "encdec":
        fwd = _encdec_fwd_flops(cfg, tokens, float(S))
    else:
        fwd = _decoder_lm_fwd_flops(cfg, tokens, kv)
    return fwd * mult


# ---------------------------------------------------------------------------
# Bytes per family (HBM traffic)
# ---------------------------------------------------------------------------

ACT_RW_FACTOR = 24.0   # reads+writes of ~d-wide tensors per layer (fwd+bwd)
ACT_RW_FWD = 8.0


def _resolve_attn_impl(cfg: ModelConfig, S: int, T: int, override: str) -> str:
    """Mirror repro.models.attention's auto dispatch."""
    impl = override or cfg.attention_impl
    if impl == "auto":
        from repro.models.attention import _CHUNK_THRESHOLD

        impl = "chunked" if S * T > _CHUNK_THRESHOLD else "reference"
    return impl


def bytes_for(cfg: ModelConfig, shape: ShapeConfig, n_params: float, *,
              attention_impl: str = "",
              optimizer: str = "adamw") -> float:
    """Total program HBM bytes for one step (all chips combined)."""
    S, B = shape.seq_len, shape.global_batch
    attention_impl = _resolve_attn_impl(cfg, S, S, attention_impl)
    wb = 2.0 if cfg.param_dtype == "bfloat16" else 4.0
    ab = 2.0 if cfg.dtype == "bfloat16" else 4.0
    d = cfg.d_model
    L = cfg.num_layers
    hd = cfg.resolved_head_dim

    if shape.kind == "train":
        tokens = float(S * B)
        # params: fwd read + remat refwd read + bwd read; grads f32 w+r;
        # optimizer state r+w (adam 2 moments, adafactor ~0) + update
        opt = 16.0 if optimizer == "adamw" else 2.0
        param_traffic = n_params * (3 * wb + 8.0 + opt + wb)
        act = tokens * d * ab * ACT_RW_FACTOR * L
        attn_quad = 0.0
        if cfg.family not in ("xlstm",):
            n_attn = L if cfg.family != "zamba" else -(-L // cfg.zamba_shared_period)
            if attention_impl == "reference":
                # materialised (S x S) scores+probs f32: ~3 accesses each
                attn_quad = 6.0 * B * cfg.num_heads * S * S * 4.0 * n_attn
        moe_traffic = 0.0
        if cfg.moe.num_experts:
            cap = moe_rows_per_token(cfg.moe, cfg.moe.group_size)
            per_tok = (2 * cap * d * ab                      # dispatch+return buffers
                       + 2 * cap * cfg.moe.num_experts * 0)  # combine fused
            combine = 2.0 * cap * cfg.moe.group_size * ab    # (T,E,C) r+w per token
            moe_traffic = tokens * (per_tok + combine) * L * 3.0
        return param_traffic + act + attn_quad + moe_traffic

    if shape.kind == "prefill":
        tokens = float(S * B)
        param_traffic = n_params * wb
        act = tokens * d * ab * ACT_RW_FWD * L
        attn_quad = 0.0
        if cfg.family not in ("xlstm",) and attention_impl == "reference":
            n_attn = L if cfg.family != "zamba" else -(-L // cfg.zamba_shared_period)
            attn_quad = 3.0 * B * cfg.num_heads * S * S * 4.0 * n_attn
        cache_write = 2.0 * B * S * cfg.num_kv_heads * hd * ab * L
        return param_traffic + act + attn_quad + cache_write

    # decode: weights + full KV cache (or recurrent state) read per step
    param_traffic = n_params * wb
    if cfg.moe.num_experts:  # only active experts' weights are touched
        frac = min(1.0, cfg.moe.active_k * B / cfg.moe.num_experts + 0.2)
        param_traffic = n_params * wb * frac
    if cfg.family == "xlstm":
        d_in = cfg.ssm_expand * d
        state = B * (cfg.num_heads * (d_in // cfg.num_heads) ** 2 + 3 * d_in) * 4.0 * L
        cache_traffic = 2.0 * state
    elif cfg.family == "zamba":
        H = cfg.ssm_heads or 1
        P = (cfg.ssm_expand * d) // H
        state = B * H * P * cfg.ssm_state * 4.0 * L * 2.0
        n_shared = -(-L // cfg.zamba_shared_period)
        kvc = 2.0 * B * S * cfg.num_kv_heads * (2 * d // cfg.num_heads) * ab * n_shared
        cache_traffic = state + kvc
    elif cfg.family == "encdec":
        kvc = 2.0 * B * S * cfg.num_kv_heads * hd * ab * L * 2  # self + cross
        cache_traffic = kvc
    else:
        cache_traffic = 2.0 * B * S * cfg.num_kv_heads * hd * ab * L
    act = float(B) * d * ab * ACT_RW_FWD * L
    return param_traffic + cache_traffic + act
