"""Parse compiled (post-SPMD) HLO text for collective traffic — exactly,
including loop trip counts.

`compiled.cost_analysis()` does not report collective bytes, and (worse)
XLA's HloCostAnalysis counts a while-loop body ONCE, so anything inside a
`lax.scan` (our layer stack) is undercounted by the trip count.  This
parser fixes both for collectives:

  1. split the HLO module into computations,
  2. walk from ENTRY, multiplying by `known_trip_count` at every `while`
     (scan bodies carry `backend_config={"known_trip_count":{"n": L}}`),
  3. sum each collective instruction's *result* bytes x its multiplier.

Result bytes equal operand bytes for all-reduce / all-to-all /
collective-permute, the gathered size for all-gather, the scattered size
for reduce-scatter (we scale by group size to recover input bytes).
Effective wire bytes: all-reduce counts 2x (ring = reduce-scatter +
all-gather).  Async `-start`/`-done` pairs count once at `-start`.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_COLL_RE = re.compile(
    r"=\s*(.*?)\s*\b(" + "|".join(COLLECTIVES) + r")(-start)?\(")
_COMP_START_RE = re.compile(r"^\s*(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*->.*\{\s*$")
_WHILE_RE = re.compile(r"\bwhile\(.*?body=(%[\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"\b(?:call|fusion)\(.*?(?:to_apply|calls)=(%[\w.\-]+)")
_COND_RE = re.compile(r"\bconditional\(.*?branch_computations=\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _split_computations(text: str) -> Tuple[Dict[str, List[str]], str]:
    comps: Dict[str, List[str]] = {}
    entry = None
    current = None
    for line in text.splitlines():
        m = _COMP_START_RE.match(line)
        if m and line.rstrip().endswith("{"):
            current = m.group(1)
            comps[current] = []
            if line.lstrip().startswith("ENTRY"):
                entry = current
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is not None:
            comps[current].append(line)
    return comps, entry


def _collect(comps: Dict[str, List[str]], name: str, mult: float,
             raw: Dict[str, float], counts: Dict[str, int],
             effective: List[float], seen_stack: Tuple[str, ...] = ()):
    if name not in comps or name in seen_stack:
        return
    for line in comps[name]:
        cm = _COLL_RE.search(line)
        if cm and "-done(" not in line:
            lhs, kind = cm.group(1), cm.group(2)
            nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(lhs))
            raw[kind] += nbytes * mult
            counts[kind] += 1
            if kind == "all-reduce":
                effective[0] += 2.0 * nbytes * mult
            elif kind == "reduce-scatter":
                g = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
                n = len(g.group(1).split(",")) if g else 1
                effective[0] += float(nbytes) * n * mult
            else:
                effective[0] += float(nbytes) * mult
        wm = _WHILE_RE.search(line)
        if wm:
            tm = _TRIP_RE.search(line)
            trip = float(tm.group(1)) if tm else 1.0
            _collect(comps, wm.group(1), mult * trip, raw, counts, effective,
                     seen_stack + (name,))
            continue
        callm = _CALL_RE.search(line)
        if callm:
            _collect(comps, callm.group(1), mult, raw, counts, effective,
                     seen_stack + (name,))
        condm = _COND_RE.search(line)
        if condm:
            for branch in condm.group(1).split(","):
                _collect(comps, branch.strip(), mult, raw, counts, effective,
                         seen_stack + (name,))


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-kind result bytes (trip-count weighted), op counts (static),
    and effective wire bytes under "total"."""
    comps, entry = _split_computations(hlo_text)
    raw: Dict[str, float] = defaultdict(float)
    counts: Dict[str, int] = defaultdict(int)
    effective = [0.0]
    if entry is not None:
        _collect(comps, entry, 1.0, raw, counts, effective)
    out: Dict[str, float] = {k: float(v) for k, v in raw.items()}
    out["total"] = effective[0]
    out["count"] = float(sum(counts.values()))
    for k, v in counts.items():
        out[f"n_{k}"] = float(v)
    return out


def while_trip_counts(hlo_text: str) -> List[int]:
    return [int(n) for n in _TRIP_RE.findall(hlo_text)]


def op_histogram(hlo_text: str, top: int = 25) -> Dict[str, int]:
    """Instruction-kind histogram — spot remat recompute & layout churn."""
    hist: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*(?:\([^=]*?\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+([a-z0-9_-]+)\(", line)
        if m:
            hist[m.group(1)] += 1
    return dict(sorted(hist.items(), key=lambda kv: -kv[1])[:top])
