"""Fault tolerance & straggler mitigation.

* :class:`StepWatchdog` — EMA step-time tracker that flags straggling
  steps (e.g. a slow host or preemption warning) and can trigger an early
  checkpoint.
* :func:`run_with_restarts` — wraps a training loop; on exception it
  reloads the latest checkpoint and resumes, up to ``max_restarts``.
  Because the data pipeline is seekable (pure function of step), resume
  is exact.
* :class:`Heartbeat` — background liveness file (cluster managers watch
  its mtime to detect hung workers and reschedule).
"""
from __future__ import annotations

import logging
import os
import threading
import time
from typing import Callable, Optional

log = logging.getLogger("repro.fault")


class StepWatchdog:
    def __init__(self, ema: float = 0.9, threshold: float = 2.5, warmup: int = 5):
        self.ema = ema
        self.threshold = threshold
        self.warmup = warmup
        self._avg: Optional[float] = None
        self._n = 0
        self.straggler_events = 0

    def observe(self, step_time: float) -> bool:
        """Returns True if this step was a straggler."""
        self._n += 1
        if self._avg is None:
            self._avg = step_time
            return False
        is_straggler = (self._n > self.warmup
                        and step_time > self.threshold * self._avg)
        if is_straggler:
            self.straggler_events += 1
            log.warning("straggler step: %.3fs vs EMA %.3fs", step_time, self._avg)
        # don't poison the EMA with outliers
        if not is_straggler:
            self._avg = self.ema * self._avg + (1 - self.ema) * step_time
        return is_straggler


class Heartbeat:
    def __init__(self, path: str, interval: float = 10.0):
        self.path = path
        self.interval = interval
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._beat, daemon=True)

    def start(self):
        self._thread.start()
        return self

    def _beat(self):
        while not self._stop.is_set():
            try:
                with open(self.path, "w") as f:
                    f.write(str(time.time()))
            except OSError:
                pass
            self._stop.wait(self.interval)

    def stop(self):
        self._stop.set()


def run_with_restarts(loop_fn: Callable[[int], int], resume_step_fn: Callable[[], int],
                      max_restarts: int = 3) -> int:
    """Run ``loop_fn(start_step) -> final_step``; on failure restart from
    ``resume_step_fn()`` (latest checkpoint), at most ``max_restarts``."""
    restarts = 0
    while True:
        start = resume_step_fn()
        try:
            return loop_fn(start)
        except KeyboardInterrupt:
            raise
        except Exception as e:  # noqa: BLE001 — any worker failure
            restarts += 1
            if restarts > max_restarts:
                log.error("exceeded max_restarts=%d; giving up", max_restarts)
                raise
            log.warning("training loop failed (%s); restart %d from step %s",
                        e, restarts, resume_step_fn())
