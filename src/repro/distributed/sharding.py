"""Logical-axis sharding rules -> PartitionSpecs / sharding constraints.

Parameters and activations carry *logical* axis names ("embed", "mlp",
"heads", "expert", "batch", "groups", ...).  A :class:`Rules` object maps
them to mesh axes for a given (config, mesh) pair, with automatic
fallback to replication when a dimension is not divisible by the mesh
axis size (e.g. granite's 40 experts or 24 heads on a 16-way model axis).

``shard(x, *logical_axes)`` applies a ``with_sharding_constraint`` when a
Rules context is active and is a no-op otherwise, so model code is
written once and runs both on a laptop and on the production mesh.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

_ctx = threading.local()


class Rules:
    """Logical->mesh axis maps for parameters and activations."""

    def __init__(self, mesh: Mesh, params: Mapping[str, MeshAxes], acts: Mapping[str, MeshAxes]):
        self.mesh = mesh
        self.params = dict(params)
        self.acts = dict(acts)

    def axis_size(self, axes: MeshAxes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n


def make_rules(cfg, mesh: Mesh, *, expert_axis: Optional[str] = None) -> Rules:
    """Build rules for a ModelConfig on a mesh.

    Mesh axes: optional "pod" (extra DP), "data" (DP), "model" (TP/EP).
    """
    axes = mesh.axis_names
    has_pod = "pod" in axes
    dp: MeshAxes = ("pod", "data") if has_pod else ("data",)
    tp = "model" if "model" in axes else None
    if expert_axis == "dp":
        # pure data parallelism: fold the model axis into DP (right call
        # for small models whose experts/heads don't divide the model
        # axis — kills the per-layer TP activation all-reduces)
        dp = dp + (tp,) if tp else dp
        tp = None
        expert_axis = None
    model_size = mesh.shape[tp] if tp else 1

    def div(n: int, ax: MeshAxes) -> MeshAxes:
        if ax is None:
            return None
        size = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            size *= mesh.shape[a]
        return ax if n % size == 0 else None

    m = cfg.moe
    e_ax = expert_axis or (m.expert_axis if m.num_experts else "model")
    hd = cfg.resolved_head_dim

    params = {
        "embed": dp if cfg.fsdp and cfg.d_model % _size(mesh, dp) == 0 else None,
        "mlp": div(cfg.d_ff, tp) if cfg.d_ff else tp,
        "heads": div(cfg.num_heads * hd, tp),
        "kv_heads": div(cfg.num_kv_heads * hd, tp) if cfg.num_kv_heads % model_size == 0 else None,
        "vocab": tp,  # vocab is padded to a multiple of 256, always divisible
        "expert": div(m.num_experts, e_ax) if m.num_experts else None,
        "layers": None,
        "ssm_inner": div(cfg.ssm_expand * cfg.d_model, tp) if cfg.ssm_state else None,
    }
    # If experts can't shard (e.g. granite's 40 on 16), keep TP on the
    # per-expert mlp dim instead (expert-TP fallback).
    if m.num_experts and params["expert"] is None:
        params["mlp"] = div(cfg.d_ff, tp)
    elif m.num_experts:
        # experts consume the model axis; per-expert mlp stays unsharded
        params["mlp"] = None if e_ax == tp else div(cfg.d_ff, tp)

    acts = {
        "batch": dp,
        "groups": dp,
        "seq": None,
        "embed": None,
        "mlp": params["mlp"],
        "heads": params["heads"],
        "kv_heads": params["kv_heads"],
        "vocab": params["vocab"],
        "expert": params["expert"],
        "cache_seq": None,
    }
    return Rules(mesh, params, acts)


def _size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    prev = getattr(_ctx, "rules", None)
    _ctx.rules = rules
    try:
        yield rules
    finally:
        _ctx.rules = prev


def active_rules() -> Optional[Rules]:
    return getattr(_ctx, "rules", None)


def logical_to_pspec(logical_axes: Sequence[Optional[str]], table: Mapping[str, MeshAxes],
                     shape: Optional[Sequence[int]] = None, mesh: Optional[Mesh] = None) -> P:
    spec = []
    used = set()
    for i, name in enumerate(logical_axes):
        ax = table.get(name) if name is not None else None
        if ax is not None:
            flat = ax if isinstance(ax, tuple) else (ax,)
            if any(a in used for a in flat):
                ax = None
            elif shape is not None and mesh is not None:
                size = 1
                for a in flat:
                    size *= mesh.shape[a]
                if shape[i] % size != 0:
                    ax = None
            if ax is not None:
                used.update(flat)
        spec.append(ax)
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Constrain activation sharding if a Rules context is active."""
    rules = active_rules()
    if rules is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"{len(logical_axes)} axes for rank-{x.ndim} array")
    spec = logical_to_pspec(logical_axes, rules.acts, x.shape, rules.mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def param_pspecs(spec_tree, rules: Rules):
    """ParamSpec tree -> PartitionSpec tree under `rules.params`."""
    from repro.nn import map_specs

    return map_specs(
        lambda s: logical_to_pspec(s.logical_axes, rules.params, s.shape, rules.mesh),
        spec_tree,
    )


def activation_shardings(tree, cfg, global_batch: int, seq_len: int, rules: Rules):
    """Heuristic NamedShardings for decode-state / batch pytrees.

    Per leaf: the first dim equal to ``global_batch`` shards over DP; a
    dim matching a known head count (kv heads, q heads, ssm heads) shards
    over the model axis; if no batch dim shards (e.g. batch=1 long-context
    decode), the dim equal to ``seq_len`` takes DP instead (sequence
    sharding).  Divisibility is always checked; fallback is replication.
    """
    mesh = rules.mesh
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tp = "model" if "model" in mesh.axis_names else None
    dp_size = _size(mesh, dp)
    tp_size = mesh.shape[tp] if tp else 1
    hd = cfg.resolved_head_dim
    head_like = {cfg.num_kv_heads, cfg.num_heads, cfg.ssm_heads or -1,
                 cfg.num_kv_heads * hd, cfg.num_heads * hd}

    def one(leaf):
        shape = getattr(leaf, "shape", None)
        if shape is None or len(shape) == 0:
            return NamedSharding(mesh, P())
        entries = [None] * len(shape)
        batch_done = False
        for i, d in enumerate(shape):
            if not batch_done and d == global_batch and d % dp_size == 0 and d > 1:
                entries[i] = dp if len(dp) > 1 else dp[0]
                batch_done = True
                break
        tp_done = False
        for i, d in enumerate(shape):
            if entries[i] is None and tp and not tp_done and d in head_like and d % tp_size == 0:
                entries[i] = tp
                tp_done = True
        if not tp_done and tp and len(shape) >= 3:
            # heads can't shard (e.g. kv=8 on a 16-way model axis):
            # sequence-shard the KV cache over the model axis instead
            for i, d in enumerate(shape):
                if entries[i] is None and d == seq_len and d % tp_size == 0 and d > 1:
                    entries[i] = tp
                    tp_done = True
                    break
        if not batch_done:
            for i, d in enumerate(shape):
                if entries[i] is None and d == seq_len and d % dp_size == 0 and d > 1:
                    entries[i] = dp if len(dp) > 1 else dp[0]
                    break
        while entries and entries[-1] is None:
            entries.pop()
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map(one, tree)


def param_shardings(spec_tree, rules: Rules):
    ps = param_pspecs(spec_tree, rules)
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(rules.mesh, p), ps,
        is_leaf=lambda x: isinstance(x, P),
    )
