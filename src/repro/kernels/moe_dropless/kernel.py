"""Pallas TPU kernel: ragged/blocked grouped FFN over a sorted token buffer.

The dropless execution path sorts tokens by expert id and pads each
expert's segment to a multiple of ``block_x`` rows (the RaggedView
layout), so every fixed-size row block belongs to exactly one expert.
This kernel is the MegaBlocks idea on TPU: iterate row blocks over the
sorted buffer and look the block's expert id up from a scalar-prefetched
``block_expert`` array — the weight BlockSpec index map reads it from
SMEM, so each block DMAs only its own expert's weight tiles.  There is
no capacity dimension anywhere: compute is proportional to the number of
sorted rows, not to ``E * C``.

  grid = (N/bx, I/bi)  — row blocks outer; the intermediate dimension is
                         innermost (arbitrary), accumulated in VMEM
                         scratch exactly like the capacity-ful
                         ``moe_ffn`` kernel.

VMEM working set per step matches ``repro.kernels.moe_ffn`` (the weight
tiles are per-block instead of per-expert-grid-step, but the same
shapes); consecutive blocks of the same expert re-use the resident tiles
because their index maps resolve to the same blocks.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4/0.5 releases.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _act(h, g, activation: str):
    if g is not None:
        if activation == "swiglu":
            return jax.nn.silu(g) * h
        return jax.nn.gelu(g) * h
    if activation == "gelu":
        return jax.nn.gelu(h)
    return jnp.maximum(h, 0.0)


def _kernel_gated(be_ref, x_ref, up_ref, gate_ref, down_ref, o_ref, acc_ref, *,
                  activation, n_i):
    _body(x_ref, up_ref, gate_ref, down_ref, o_ref, acc_ref, activation, n_i)


def _kernel_plain(be_ref, x_ref, up_ref, down_ref, o_ref, acc_ref, *,
                  activation, n_i):
    _body(x_ref, up_ref, None, down_ref, o_ref, acc_ref, activation, n_i)


def _body(x_ref, up_ref, gate_ref, down_ref, o_ref, acc_ref, activation, n_i):
    ib = pl.program_id(1)

    @pl.when(ib == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)        # (bx, M)
    h = jnp.dot(x, up_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32)              # (bx, bi)
    g = None
    if gate_ref is not None:
        g = jnp.dot(x, gate_ref[0].astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    h = _act(h, g, activation)
    acc_ref[...] += jnp.dot(h, down_ref[0].astype(jnp.float32),
                            preferred_element_type=jnp.float32)  # (bx, M)

    @pl.when(ib == n_i - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def ragged_ffn_kernel(x: jax.Array, block_expert: jax.Array, w_up: jax.Array,
                      w_gate: Optional[jax.Array], w_down: jax.Array,
                      activation: str = "swiglu", block_x: int = 128,
                      block_i: int = 512, interpret: bool = False) -> jax.Array:
    """x: (N, M) sorted token rows, N % block_x == 0; block_expert:
    (N/block_x,) int32 expert id per row block.  Returns (N, M)."""
    N, M = x.shape
    E, _, I = w_up.shape
    bx = block_x
    bi = min(block_i, I)
    assert N % bx == 0 and I % bi == 0, (N, bx, I, bi)
    n_i = I // bi
    nb = N // bx
    assert block_expert.shape == (nb,), (block_expert.shape, nb)

    in_specs = [
        pl.BlockSpec((bx, M), lambda b, ib, be: (b, 0)),
        pl.BlockSpec((1, M, bi), lambda b, ib, be: (be[b], 0, ib)),
    ]
    args = [x, w_up]
    if w_gate is not None:
        in_specs.append(pl.BlockSpec((1, M, bi), lambda b, ib, be: (be[b], 0, ib)))
        args.append(w_gate)
    in_specs.append(pl.BlockSpec((1, bi, M), lambda b, ib, be: (be[b], ib, 0)))
    args.append(w_down)

    kernel = functools.partial(
        _kernel_gated if w_gate is not None else _kernel_plain,
        activation=activation, n_i=n_i)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, n_i),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bx, M), lambda b, ib, be: (b, 0)),
        scratch_shapes=[pltpu.VMEM((bx, M), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, M), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(block_expert, *args)
