"""Pure-jnp reference for the ragged grouped FFN (sorted-gather form).

Same blocked view of the sorted token buffer as the kernel: rows reshape
to (NB, bx, M) blocks, each block gathers its expert's weight matrices
(``w[block_expert]``) and runs the dense FFN — f32 accumulation, so this
also serves as the ``custom_vjp`` backward and the non-TPU forward path.
The weight gather materialises (NB, M, I) — a factor ``bx`` smaller than
a per-row gather — which is the price of expressing raggedness in pure
jnp; the Pallas kernel streams the same tiles through VMEM instead.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def ragged_ffn_ref(x: jax.Array, block_expert: jax.Array, w_up: jax.Array,
                   w_gate: Optional[jax.Array], w_down: jax.Array,
                   activation: str = "swiglu") -> jax.Array:
    """x: (N, M) sorted rows; block_expert: (NB,) with N % NB == 0."""
    N, M = x.shape
    nb = block_expert.shape[0]
    bx = N // nb
    xb = x.reshape(nb, bx, M).astype(jnp.float32)
    up = w_up[block_expert].astype(jnp.float32)          # (NB, M, I)
    h = jnp.einsum("bxm,bmi->bxi", xb, up)
    if w_gate is not None:
        g = jnp.einsum("bxm,bmi->bxi", xb,
                       w_gate[block_expert].astype(jnp.float32))
        h = jax.nn.silu(g) * h if activation == "swiglu" else jax.nn.gelu(g) * h
    elif activation == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jnp.maximum(h, 0.0)
    down = w_down[block_expert].astype(jnp.float32)      # (NB, I, M)
    return jnp.einsum("bxi,bim->bxm", h, down).reshape(N, M).astype(x.dtype)
