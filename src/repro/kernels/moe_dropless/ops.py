"""jit'd public wrapper for the ragged grouped-FFN kernel.

Forward runs the Pallas kernel on TPU; on every other backend the
pure-jnp sorted-gather reference runs instead (the issue of streaming
weight tiles per row block is a TPU memory-system question — interpret
mode would only re-derive the reference semantics, more slowly).  The
wrapper carries a ``custom_vjp`` whose backward always differentiates
the reference (same math, f32 accumulation), so the dropless backend is
trainable on any platform.

``block_expert`` is integer routing metadata: its cotangent is the empty
``float0`` tangent type, never a real gradient.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.moe_dropless.kernel import ragged_ffn_kernel
from repro.kernels.moe_dropless.ref import ragged_ffn_ref


def pick_block_rows(n_choices: int, num_experts: int, max_block: int = 128) -> int:
    """Row-block granularity for the ragged layout: largest power of two
    <= max_block whose worst-case segment padding (one block per expert)
    does not exceed the real rows.  Keeps small-T execution — decode
    steps route a handful of choices — from paying E*max_block padded
    rows; floor 8 preserves TPU sublane alignment."""
    bx = max_block
    while bx > 8 and num_experts * bx > max(n_choices, 1):
        bx //= 2
    return bx


def padded_rows(n_choices: int, num_experts: int, block_rows: int) -> int:
    """Static row count of the sorted+padded ragged buffer (the same
    bound RoutingPlan._ragged_index_view allocates)."""
    n = n_choices + num_experts * (block_rows - 1)
    return -(-n // block_rows) * block_rows


def _run(x, block_expert, w_up, w_gate, w_down, activation, block_x, block_i):
    if jax.default_backend() != "tpu":
        return ragged_ffn_ref(x, block_expert, w_up, w_gate, w_down, activation)
    I = w_up.shape[-1]
    bi = min(block_i, I)
    while bi > 1 and I % bi:
        bi //= 2
    # loop invariant: bi divides I on exit (worst case bi == 1)
    return ragged_ffn_kernel(x, block_expert, w_up, w_gate, w_down, activation,
                             block_x=block_x, block_i=bi)


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _ragged_ffn(x, block_expert, w_up, w_gate, w_down, activation, block_x, block_i):
    return _run(x, block_expert, w_up, w_gate, w_down, activation, block_x, block_i)


def _ragged_ffn_fwd(x, block_expert, w_up, w_gate, w_down, activation, block_x, block_i):
    y = _run(x, block_expert, w_up, w_gate, w_down, activation, block_x, block_i)
    return y, (x, block_expert, w_up, w_gate, w_down)


def _ragged_ffn_bwd(activation, block_x, block_i, res, g):
    x, block_expert, w_up, w_gate, w_down = res
    ct_be = np.zeros(block_expert.shape, jax.dtypes.float0)
    if w_gate is None:
        _, vjp = jax.vjp(
            lambda xx, up, down: ragged_ffn_ref(xx, block_expert, up, None,
                                                down, activation),
            x, w_up, w_down)
        dx, dup, ddown = vjp(g)
        return dx, ct_be, dup, None, ddown
    _, vjp = jax.vjp(
        lambda xx, up, gate, down: ragged_ffn_ref(xx, block_expert, up, gate,
                                                  down, activation),
        x, w_up, w_gate, w_down)
    dx, dup, dgate, ddown = vjp(g)
    return dx, ct_be, dup, dgate, ddown


_ragged_ffn.defvjp(_ragged_ffn_fwd, _ragged_ffn_bwd)


@partial(jax.jit, static_argnames=("activation", "block_x", "block_i"))
def ragged_ffn(x: jax.Array, block_expert: jax.Array, w_up: jax.Array,
               w_gate: Optional[jax.Array], w_down: jax.Array,
               activation: str = "swiglu", block_x: int = 128,
               block_i: int = 512) -> jax.Array:
    return _ragged_ffn(x, block_expert, w_up, w_gate, w_down, activation,
                       block_x, block_i)


__all__ = ["ragged_ffn", "ragged_ffn_ref", "pick_block_rows", "padded_rows"]
