from repro.kernels.moe_dropless import ops
from repro.kernels.moe_dropless.ops import ragged_ffn
from repro.kernels.moe_dropless.ref import ragged_ffn_ref
