"""jit'd wrapper: (B,S,H,D) layout in, kernel in (B,H,S,D), GQA-aware."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "block_q", "block_kv"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_q: int = 512,
                    block_kv: int = 512) -> jax.Array:
    """q: (B, S, Hq, D); k/v: (B, S, Hkv, D) -> (B, S, Hq, D)."""
    B, S, Hq, D = q.shape
    bq = min(block_q, S)
    bkv = min(block_kv, S)
    while S % bq:
        bq //= 2
    while S % bkv:
        bkv //= 2
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    o = flash_attention_kernel(qt, kt, vt, causal=causal, block_q=bq,
                               block_kv=bkv, interpret=_interpret())
    return jnp.transpose(o, (0, 2, 1, 3))


__all__ = ["flash_attention", "attention_ref"]
