"""Pure-jnp oracle for blocked causal (flash) attention with GQA."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True) -> jax.Array:
    """q: (B, S, Hq, D); k/v: (B, S, Hkv, D); Hq % Hkv == 0."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32)) * (D ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, Hq, D).astype(q.dtype)
