"""Pallas TPU flash attention (causal, GQA) — online-softmax blocked.

Grid: (B, Hq, Sq/bq, Skv/bkv) with the KV axis innermost ("arbitrary");
running max/denominator/accumulator live in VMEM scratch across KV steps.
Causality skips whole KV blocks above the diagonal (work ~halves).

VMEM per step (bf16, bq=bkv=512, D=128): q 0.13 + k 0.13 + v 0.13 MB +
f32 acc (bq, D) 0.25MB — comfortably under VMEM; block sizes are 128-
aligned for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4/0.5 releases.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, bq, bkv, n_kv, causal):
    qb = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: kv block strictly above the diagonal contributes nothing
    run = (not causal) or (kb * bkv <= qb * bq + bq - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)               # (bkv, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq,bkv)
        if causal:
            rows = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            cols = kb * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        v = v_ref[0, 0].astype(jnp.float32)               # (bkv, D)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kb == n_kv - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True,
                           block_q: int = 512, block_kv: int = 512,
                           interpret: bool = False):
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D) -> (B, Hq, S, D)."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    bq = min(block_q, S)
    bkv = min(block_kv, S)
    assert S % bq == 0 and S % bkv == 0
    n_kv = S // bkv
    grid = (B, Hq, S // bq, n_kv)

    kernel = functools.partial(_kernel, scale=D ** -0.5, bq=bq, bkv=bkv,
                               n_kv=n_kv, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qb, kb: (b, h, qb, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, qb, kb: (b, h // G, kb, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, qb, kb: (b, h // G, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, qb, kb: (b, h, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
