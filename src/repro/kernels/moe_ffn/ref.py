"""Pure-jnp oracle for the grouped expert FFN kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def moe_ffn_ref(x: jax.Array, w_up: jax.Array, w_gate: Optional[jax.Array],
                w_down: jax.Array, activation: str = "swiglu") -> jax.Array:
    """x: (E, X, M); w_up: (E, M, I); w_gate: (E, M, I) or None;
    w_down: (E, I, M).  Per-expert FFN, f32 accumulation."""
    x32 = x.astype(jnp.float32)
    h = jnp.einsum("exm,emi->exi", x32, w_up.astype(jnp.float32))
    if w_gate is not None:
        g = jnp.einsum("exm,emi->exi", x32, w_gate.astype(jnp.float32))
        if activation == "swiglu":
            h = jax.nn.silu(g) * h
        else:
            h = jax.nn.gelu(g) * h
    elif activation == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.relu(h)
    y = jnp.einsum("exi,eim->exm", h, w_down.astype(jnp.float32))
    return y.astype(x.dtype)
