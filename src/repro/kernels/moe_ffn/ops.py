"""jit'd public wrapper for the grouped expert-FFN kernel.

On non-TPU backends the kernel runs in interpret mode (Python semantics,
used for CI correctness); on TPU it lowers to Mosaic.  Shapes that do not
tile evenly are padded on the row dimension (padded rows compute garbage
that is sliced away — they never touch real rows).

The (E, X, M) input is the per-expert capacity buffer produced by the
MoE layer's index-view dispatch (X = G*C rows per expert); empty slots
are zero rows, which the kernel processes like any other — their outputs
are discarded by the gate-weighted combine.

``pallas_call`` has no autodiff rule, so :func:`moe_ffn` carries a
``custom_vjp``: forward runs the kernel, backward differentiates the
pure-jnp reference (same math, f32 accumulation) — making the pallas
impl trainable, not just a serving path.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.moe_ffn.kernel import moe_ffn_kernel
from repro.kernels.moe_ffn.ref import moe_ffn_ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _run_kernel(x, w_up, w_gate, w_down, activation, block_x, block_i):
    E, X, M = x.shape
    I = w_up.shape[-1]
    bx = min(block_x, max(8, X))
    bi = min(block_i, I)
    while bi > 1 and I % bi:
        bi //= 2
    # loop invariant: bi divides I on exit (worst case bi == 1)
    pad_x = (-X) % bx
    xp = jnp.pad(x, ((0, 0), (0, pad_x), (0, 0))) if pad_x else x
    y = moe_ffn_kernel(xp, w_up, w_gate, w_down, activation,
                       block_x=bx, block_i=bi, interpret=_interpret())
    return y[:, :X] if pad_x else y


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _moe_ffn(x, w_up, w_gate, w_down, activation, block_x, block_i):
    return _run_kernel(x, w_up, w_gate, w_down, activation, block_x, block_i)


def _moe_ffn_fwd(x, w_up, w_gate, w_down, activation, block_x, block_i):
    y = _run_kernel(x, w_up, w_gate, w_down, activation, block_x, block_i)
    return y, (x, w_up, w_gate, w_down)


def _moe_ffn_bwd(activation, block_x, block_i, res, g):
    x, w_up, w_gate, w_down = res
    if w_gate is None:
        _, vjp = jax.vjp(
            lambda xx, up, down: moe_ffn_ref(xx, up, None, down, activation),
            x, w_up, w_down)
        dx, dup, ddown = vjp(g)
        return dx, dup, None, ddown
    _, vjp = jax.vjp(
        lambda xx, up, gate, down: moe_ffn_ref(xx, up, gate, down, activation),
        x, w_up, w_gate, w_down)
    return vjp(g)


_moe_ffn.defvjp(_moe_ffn_fwd, _moe_ffn_bwd)


@partial(jax.jit, static_argnames=("activation", "block_x", "block_i"))
def moe_ffn(x: jax.Array, w_up: jax.Array, w_gate: Optional[jax.Array],
            w_down: jax.Array, activation: str = "swiglu",
            block_x: int = 128, block_i: int = 512) -> jax.Array:
    return _moe_ffn(x, w_up, w_gate, w_down, activation, block_x, block_i)


__all__ = ["moe_ffn", "moe_ffn_ref"]
