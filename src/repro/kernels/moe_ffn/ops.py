"""jit'd public wrapper for the grouped expert-FFN kernel.

On non-TPU backends the kernel runs in interpret mode (Python semantics,
used for CI correctness); on TPU it lowers to Mosaic.  Shapes that do not
tile evenly are padded on the row dimension (padded rows compute garbage
that is sliced away — they never touch real rows).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.moe_ffn.kernel import moe_ffn_kernel
from repro.kernels.moe_ffn.ref import moe_ffn_ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("activation", "block_x", "block_i"))
def moe_ffn(x: jax.Array, w_up: jax.Array, w_gate: Optional[jax.Array],
            w_down: jax.Array, activation: str = "swiglu",
            block_x: int = 128, block_i: int = 512) -> jax.Array:
    E, X, M = x.shape
    I = w_up.shape[-1]
    bx = min(block_x, max(8, X))
    bi = min(block_i, I)
    while I % bi:
        bi //= 2
    pad_x = (-X) % bx
    xp = jnp.pad(x, ((0, 0), (0, pad_x), (0, 0))) if pad_x else x
    y = moe_ffn_kernel(xp, w_up, w_gate, w_down, activation,
                       block_x=bx, block_i=bi, interpret=_interpret())
    return y[:, :X] if pad_x else y


__all__ = ["moe_ffn", "moe_ffn_ref"]
