from repro.kernels.moe_ffn import ops
from repro.kernels.moe_ffn.ops import moe_ffn
from repro.kernels.moe_ffn.ref import moe_ffn_ref
