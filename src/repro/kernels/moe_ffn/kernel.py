"""Pallas TPU kernel: grouped (per-expert) FFN on dispatched MoE buffers.

The paper's appendix attributes ~98% of MoE-layer forward FLOPs to the
two expert matmuls (EdCM x eMI and back).  This kernel fuses
up-projection, activation (swiglu/gelu/relu) and down-projection for all
experts in one pallas_call:

  grid = (E, X/bx, I/bi)   — experts and row-blocks parallel; the
                             intermediate dimension is the innermost
                             (arbitrary) axis, accumulated in VMEM scratch.

VMEM working set per step (bf16):
  x block (bx, M) + w_up/w_gate (M, bi) + w_down (bi, M) + f32 acc (bx, M)
  for bx=128, bi=512, M=2048: 0.5 + 2*2 + 2 + 1 MB ~= 7.5MB < 16MB VMEM.
MXU alignment: bx, bi multiples of 128; M is the contraction dim.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4/0.5 releases.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _act(h, g, activation: str):
    if g is not None:
        if activation == "swiglu":
            return jax.nn.silu(g) * h
        return jax.nn.gelu(g) * h
    if activation == "gelu":
        return jax.nn.gelu(h)
    return jnp.maximum(h, 0.0)


def _kernel_gated(x_ref, up_ref, gate_ref, down_ref, o_ref, acc_ref, *, activation, n_i):
    _body(x_ref, up_ref, gate_ref, down_ref, o_ref, acc_ref, activation, n_i)


def _kernel_plain(x_ref, up_ref, down_ref, o_ref, acc_ref, *, activation, n_i):
    _body(x_ref, up_ref, None, down_ref, o_ref, acc_ref, activation, n_i)


def _body(x_ref, up_ref, gate_ref, down_ref, o_ref, acc_ref, activation, n_i):
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)          # (bx, M)
    h = jnp.dot(x, up_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32)          # (bx, bi)
    g = None
    if gate_ref is not None:
        g = jnp.dot(x, gate_ref[0].astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    h = _act(h, g, activation)
    acc_ref[...] += jnp.dot(h, down_ref[0].astype(jnp.float32),
                            preferred_element_type=jnp.float32)  # (bx, M)

    @pl.when(ib == n_i - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def moe_ffn_kernel(x: jax.Array, w_up: jax.Array, w_gate: Optional[jax.Array],
                   w_down: jax.Array, activation: str = "swiglu",
                   block_x: int = 128, block_i: int = 512,
                   interpret: bool = False) -> jax.Array:
    """x: (E, X, M) dispatched tokens; returns (E, X, M)."""
    E, X, M = x.shape
    I = w_up.shape[-1]
    bx = min(block_x, X)
    bi = min(block_i, I)
    assert X % bx == 0 and I % bi == 0, (X, bx, I, bi)
    n_i = I // bi
    grid = (E, X // bx, n_i)

    in_specs = [
        pl.BlockSpec((1, bx, M), lambda e, xb, ib: (e, xb, 0)),
        pl.BlockSpec((1, M, bi), lambda e, xb, ib: (e, 0, ib)),
    ]
    args = [x, w_up]
    if w_gate is not None:
        in_specs.append(pl.BlockSpec((1, M, bi), lambda e, xb, ib: (e, 0, ib)))
        args.append(w_gate)
    in_specs.append(pl.BlockSpec((1, bi, M), lambda e, xb, ib: (e, ib, 0)))
    args.append(w_down)

    kernel = functools.partial(
        _kernel_gated if w_gate is not None else _kernel_plain,
        activation=activation, n_i=n_i)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bx, M), lambda e, xb, ib: (e, xb, 0)),
        out_shape=jax.ShapeDtypeStruct((E, X, M), x.dtype),
        scratch_shapes=[pltpu.VMEM((bx, M), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*args)
