"""jit'd wrappers: dense (B, T, Hkv, D) cache layout and the paged
(block-pool + block-table) layout used by the continuous-batching
serving engine."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import (
    decode_attention_kernel,
    paged_decode_attention_kernel,
    quantized_paged_decode_attention_kernel,
)
from repro.kernels.decode_attention.ref import (
    decode_attention_ref,
    paged_decode_attention_ref,
    quantized_paged_decode_attention_ref,
)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("block_kv",))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, block_kv: int = 1024) -> jax.Array:
    """q: (B, Hq, D); k/v: (B, T, Hkv, D); lengths: (B,). -> (B, Hq, D)."""
    B, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)
    kt = jnp.transpose(k, (0, 2, 1, 3))     # (B, Hkv, T, D)
    vt = jnp.transpose(v, (0, 2, 1, 3))
    out = decode_attention_kernel(qg, kt, vt, lengths.reshape(B, 1).astype(jnp.int32),
                                  block_kv=block_kv, interpret=_interpret())
    return out.reshape(B, Hq, D)


@jax.jit
def paged_decode_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                           block_tables: jax.Array,
                           lengths: jax.Array) -> jax.Array:
    """q: (N, Hq, D) one query per row (decode slot, prefill-chunk
    token, or speculative verify row — rows are position-addressed, so
    several rows of one slot at consecutive positions sharing a block
    table are just more rows); k_pool/v_pool: (P, Hkv, bs, D) shared
    block pool; block_tables: (N, MB) int32 pool block ids covering each
    row's context in order; lengths: (N,) valid context per row (0 =>
    masked row, output 0).  Returns (N, Hq, D).

    On TPU the Pallas kernel streams only the table-addressed pool
    blocks (no dense gather); elsewhere the pure-jnp gather reference
    runs (the kernel's scalar-prefetch indirection is a TPU
    memory-system question — interpret mode would re-derive the
    reference semantics through a full pool gather anyway).
    """
    N, Hq, D = q.shape
    Hkv = k_pool.shape[1]
    if jax.default_backend() != "tpu":
        return paged_decode_attention_ref(q, k_pool, v_pool, block_tables, lengths)
    G = Hq // Hkv
    qg = q.reshape(N, Hkv, G, D)
    out = paged_decode_attention_kernel(qg, k_pool, v_pool, block_tables, lengths)
    return out.reshape(N, Hq, D)


def paged_update_attention(q, k, v, k_pool, v_pool, write_blocks,
                           write_offsets, block_tables, lengths):
    """One serving step's K/V write + paged attention, fused at the op
    level: scatter this step's per-row K/V at ``(write_blocks, :,
    write_offsets)``, then attend through the block tables.  The write
    lands before the read, so a prefill-chunk row sees its same-step
    predecessors (exact causal prefill).  Returns ``(out, k_pool,
    v_pool)`` — pools flow through so callers can donate them.

    q: (N, Hq, D); k/v: (N, Hkv, D); pools: (P, Hkv, bs, D);
    write_blocks/write_offsets: (N,) pool coords (masked rows target the
    garbage block); block_tables: (N, MB); lengths: (N,).
    """
    k_pool = k_pool.at[write_blocks, :, write_offsets].set(k.astype(k_pool.dtype))
    v_pool = v_pool.at[write_blocks, :, write_offsets].set(v.astype(v_pool.dtype))
    out = paged_decode_attention(q, k_pool, v_pool, block_tables, lengths)
    return out, k_pool, v_pool


@partial(jax.jit, static_argnames=("policy",))
def quantized_paged_decode_attention(q, k_pool, v_pool, k_scales, v_scales,
                                     block_tables, lengths, *, policy):
    """:func:`paged_decode_attention` over a quantized pool: k_pool /
    v_pool hold int8 codes, k_scales/v_scales (P, Hkv) float32 absmax
    scales keyed by the same block ids (``value = policy.decode(code) *
    scale``).  ``policy`` is a :class:`repro.quant.KVQuantPolicy`
    singleton riding in the jit static args.  On TPU the Pallas kernel
    dequantizes tiles in-register inside the online-softmax loop;
    elsewhere the pure-jnp gather reference runs."""
    N, Hq, D = q.shape
    Hkv = k_pool.shape[1]
    if jax.default_backend() != "tpu":
        return quantized_paged_decode_attention_ref(
            q, k_pool, v_pool, k_scales, v_scales, block_tables, lengths,
            policy=policy)
    G = Hq // Hkv
    qg = q.reshape(N, Hkv, G, D)
    out = quantized_paged_decode_attention_kernel(
        qg, k_pool, v_pool, k_scales, v_scales, block_tables, lengths,
        decode=policy.decode)
    return out.reshape(N, Hq, D)


def quantized_paged_update_attention(q, k, v, k_pool, v_pool, k_scales,
                                     v_scales, write_blocks, write_offsets,
                                     block_tables, lengths, *, policy):
    """Quantized :func:`paged_update_attention`: quantize-scatter this
    step's per-row K/V (maintaining the per-block absmax scales — fresh
    blocks restart at 0, grown blocks rescale their resident codes),
    then attend through the block tables with fused dequant.  Returns
    ``(out, k_pool, v_pool, k_scales, v_scales)`` so callers can donate
    all four pool buffers."""
    from repro.quant.policy import quant_write_kv

    k_pool, k_scales = quant_write_kv(k_pool, k_scales, k, write_blocks,
                                      write_offsets, policy=policy)
    v_pool, v_scales = quant_write_kv(v_pool, v_scales, v, write_blocks,
                                      write_offsets, policy=policy)
    out = quantized_paged_decode_attention(
        q, k_pool, v_pool, k_scales, v_scales, block_tables, lengths,
        policy=policy)
    return out, k_pool, v_pool, k_scales, v_scales


def sharded_paged_update_attention(q, k, v, k_pool, v_pool, write_blocks,
                                   write_offsets, block_tables, lengths,
                                   *, mesh, axis="data"):
    """:func:`paged_update_attention` under shard_map over the mesh's
    data axis.

    Every operand partitions on its leading dimension: rows (the engine
    lays step rows out shard-major, each shard's rows covering its own
    slots) and the stacked pool (each shard owns a contiguous
    ``(shard_blocks + 1)``-row slice ending in its private garbage
    block).  Block tables and write coords carry *shard-local* ids, so
    each body indexes only its own pool slice — attention never reads
    another shard's blocks, and no unsharded ``(num_blocks, ...)`` pool
    appears inside the mapped computation.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    dx = P(axis)
    fn = shard_map(paged_update_attention, mesh=mesh, in_specs=(dx,) * 9,
                   out_specs=(dx, dx, dx), check_rep=False)
    return fn(q, k, v, k_pool, v_pool, write_blocks, write_offsets,
              block_tables, lengths)


def sharded_quantized_paged_update_attention(q, k, v, k_pool, v_pool,
                                             k_scales, v_scales,
                                             write_blocks, write_offsets,
                                             block_tables, lengths, *,
                                             policy, mesh, axis="data"):
    """:func:`quantized_paged_update_attention` under shard_map over the
    mesh's data axis — the same leading-dimension partitioning as
    :func:`sharded_paged_update_attention`, with the scale pools sharded
    alongside their code pools (both are keyed by shard-local ids)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    dx = P(axis)
    body = partial(quantized_paged_update_attention, policy=policy)
    fn = shard_map(body, mesh=mesh, in_specs=(dx,) * 11,
                   out_specs=(dx,) * 5, check_rep=False)
    return fn(q, k, v, k_pool, v_pool, k_scales, v_scales, write_blocks,
              write_offsets, block_tables, lengths)


__all__ = ["decode_attention", "decode_attention_ref",
           "paged_decode_attention", "paged_decode_attention_ref",
           "paged_update_attention", "sharded_paged_update_attention",
           "quantized_paged_decode_attention",
           "quantized_paged_decode_attention_ref",
           "quantized_paged_update_attention",
           "sharded_quantized_paged_update_attention"]
