"""jit'd wrapper: standard (B, Hq, D) query / (B, T, Hkv, D) cache layout."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_kernel
from repro.kernels.decode_attention.ref import decode_attention_ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("block_kv",))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, block_kv: int = 1024) -> jax.Array:
    """q: (B, Hq, D); k/v: (B, T, Hkv, D); lengths: (B,). -> (B, Hq, D)."""
    B, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)
    kt = jnp.transpose(k, (0, 2, 1, 3))     # (B, Hkv, T, D)
    vt = jnp.transpose(v, (0, 2, 1, 3))
    out = decode_attention_kernel(qg, kt, vt, lengths.reshape(B, 1).astype(jnp.int32),
                                  block_kv=block_kv, interpret=_interpret())
    return out.reshape(B, Hq, D)


__all__ = ["decode_attention", "decode_attention_ref"]
