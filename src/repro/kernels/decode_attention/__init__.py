from repro.kernels.decode_attention import ops
from repro.kernels.decode_attention.ops import (
    decode_attention,
    paged_decode_attention,
    paged_update_attention,
    quantized_paged_decode_attention,
    quantized_paged_update_attention,
    sharded_paged_update_attention,
    sharded_quantized_paged_update_attention,
)
from repro.kernels.decode_attention.ref import (
    decode_attention_ref,
    paged_decode_attention_ref,
    quantized_paged_decode_attention_ref,
)
