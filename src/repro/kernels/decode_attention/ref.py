"""Pure-jnp oracle for single-token GQA decode attention over a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array) -> jax.Array:
    """q: (B, Hq, D) one query per sequence; k/v: (B, T, Hkv, D) cache;
    lengths: (B,) valid prefix per sequence.  Returns (B, Hq, D)."""
    B, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k.astype(jnp.float32)) * (D ** -0.5)
    valid = jnp.arange(T)[None, :] < lengths[:, None]            # (B,T)
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(q.dtype)
