"""Pure-jnp oracles for single-token GQA decode attention: dense cache
and paged (block-table) cache variants."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # finite mask: rows with length 0 must not produce NaN


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array) -> jax.Array:
    """q: (B, Hq, D) one query per sequence; k/v: (B, T, Hkv, D) cache;
    lengths: (B,) valid prefix per sequence.  Returns (B, Hq, D)."""
    B, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k.astype(jnp.float32)) * (D ** -0.5)
    valid = jnp.arange(T)[None, :] < lengths[:, None]            # (B,T)
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(q.dtype)


def paged_decode_attention_ref(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, block_tables: jax.Array,
                               lengths: jax.Array) -> jax.Array:
    """q: (N, Hq, D) one query row per (slot | prefill-chunk |
    speculative-verify) token;
    k_pool/v_pool: (P, Hkv, bs, D) the shared block pool; block_tables:
    (N, MB) int32 pool block ids covering each row's context in order;
    lengths: (N,) valid context per row (0 => inactive row, output 0).
    Returns (N, Hq, D).

    Each row attends to positions [0, length) of its own slot's context,
    read through the block table — scattered pool blocks, no dense
    per-slot slab.  Masking uses a finite NEG_INF so fully-masked rows
    stay NaN-free (NaN would poison other tokens through the einsum
    dispatcher's zero-weight combine products).
    """
    N, Hq, D = q.shape
    _, Hkv, bs, _ = k_pool.shape
    MB = block_tables.shape[1]
    G = Hq // Hkv
    # gather this row's context: (N, MB, Hkv, bs, D) -> (N, Hkv, MB*bs, D)
    k = jnp.transpose(k_pool[block_tables], (0, 2, 1, 3, 4)).reshape(N, Hkv, MB * bs, D)
    v = jnp.transpose(v_pool[block_tables], (0, 2, 1, 3, 4)).reshape(N, Hkv, MB * bs, D)
    qg = q.reshape(N, Hkv, G, D).astype(jnp.float32)
    scores = jnp.einsum("nkgd,nktd->nkgt", qg, k.astype(jnp.float32)) * (D ** -0.5)
    valid = jnp.arange(MB * bs)[None, :] < lengths[:, None]         # (N, T)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("nkgt,nktd->nkgd", probs, v.astype(jnp.float32))
    out = jnp.where((lengths > 0)[:, None, None, None], out, 0.0)
    return out.reshape(N, Hq, D).astype(q.dtype)


def quantized_paged_decode_attention_ref(q, k_pool, v_pool, k_scales,
                                         v_scales, block_tables, lengths,
                                         *, policy):
    """Quantized-pool oracle: identical math to
    :func:`paged_decode_attention_ref` after dequantizing the gathered
    tiles.  k_pool/v_pool hold int8 codes; k_scales/v_scales are
    (P, Hkv) float32 per-block-per-head absmax scales keyed by the same
    block ids, so value = policy.decode(code) * scale.
    """
    N, Hq, D = q.shape
    _, Hkv, bs, _ = k_pool.shape
    MB = block_tables.shape[1]
    G = Hq // Hkv
    # (N, MB, Hkv, bs, D) codes * (N, MB, Hkv, 1, 1) scales
    k = policy.decode(k_pool[block_tables]) * \
        k_scales[block_tables][..., None, None]
    v = policy.decode(v_pool[block_tables]) * \
        v_scales[block_tables][..., None, None]
    k = jnp.transpose(k, (0, 2, 1, 3, 4)).reshape(N, Hkv, MB * bs, D)
    v = jnp.transpose(v, (0, 2, 1, 3, 4)).reshape(N, Hkv, MB * bs, D)
    qg = q.reshape(N, Hkv, G, D).astype(jnp.float32)
    scores = jnp.einsum("nkgd,nktd->nkgt", qg, k) * (D ** -0.5)
    valid = jnp.arange(MB * bs)[None, :] < lengths[:, None]         # (N, T)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("nkgt,nktd->nkgd", probs, v)
    out = jnp.where((lengths > 0)[:, None, None, None], out, 0.0)
    return out.reshape(N, Hq, D).astype(q.dtype)
