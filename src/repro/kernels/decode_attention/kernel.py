"""Pallas TPU kernel: single-token GQA decode attention over a KV cache.

Decode is bandwidth-bound: the whole valid cache streams HBM->VMEM once
per step.  Grid (B, Hkv, T/bkv), KV innermost ("arbitrary") with online-
softmax scratch; all G grouped q-heads for a kv head are processed
together so the streamed K/V block is reused G times (the GQA bandwidth
win).  Valid-length masking comes from a (B, 1) lengths operand.

VMEM per step (bf16, bkv=1024, D=128, G=8): k/v 0.5MB, q (G,D) tiny,
f32 acc (G,D) tiny — far under VMEM; bandwidth is the limit by design.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4/0.5 releases.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, bkv, n_kv):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0, 0]
    # skip whole blocks past the valid prefix
    @pl.when(t * bkv < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bkv, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G,bkv)
        cols = t * bkv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(t == n_kv - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def decode_attention_kernel(q, k, v, lengths, *, block_kv: int = 1024,
                            interpret: bool = False):
    """q: (B, Hkv, G, D); k/v: (B, Hkv, T, D); lengths: (B, 1) int32.
    Returns (B, Hkv, G, D)."""
    B, Hkv, G, D = q.shape
    T = k.shape[2]
    bkv = min(block_kv, T)
    while T % bkv:
        bkv //= 2
    n_kv = T // bkv
    grid = (B, Hkv, n_kv)

    kernel = functools.partial(_kernel, scale=D ** -0.5, bkv=bkv, n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, t: (b, 0)),          # lengths
            pl.BlockSpec((1, 1, G, D), lambda b, h, t: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, t: (b, h, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, t: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, q, k, v)


# ---------------------------------------------------------------------------
# Paged variant: KV lives in a shared block pool, per-row block tables
# ---------------------------------------------------------------------------

def _paged_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale, bs, n_b):
    """Same online-softmax recurrence as ``_kernel``; the KV block for
    grid step (i, h, b) is DMA'd from pool block ``tbl_ref[i, b]`` (the
    BlockSpec index maps read the scalar-prefetched table from SMEM, the
    MegaBlocks-style trick the dropless FFN kernel uses for weights)."""
    i = pl.program_id(0)
    b = pl.program_id(2)

    @pl.when(b == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[i]
    @pl.when(b * bs < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bs, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, bs)
        cols = b * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(b == n_b - 1)
    def _finish():
        # length-0 rows never accumulate: l stays 0 -> output exactly 0
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def paged_decode_attention_kernel(q, k_pool, v_pool, block_tables, lengths, *,
                                  interpret: bool = False):
    """q: (N, Hkv, G, D); k_pool/v_pool: (P, Hkv, bs, D); block_tables:
    (N, MB) int32 pool block ids per row; lengths: (N,) int32.
    Returns (N, Hkv, G, D)."""
    N, Hkv, G, D = q.shape
    _, _, bs, _ = k_pool.shape
    MB = block_tables.shape[1]
    grid = (N, Hkv, MB)

    kernel = functools.partial(_paged_kernel, scale=D ** -0.5, bs=bs, n_b=MB)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_tables, lengths
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda i, h, b, tbl, lens: (i, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D), lambda i, h, b, tbl, lens: (tbl[i, b], h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D), lambda i, h, b, tbl, lens: (tbl[i, b], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda i, h, b, tbl, lens: (i, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, Hkv, G, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), q, k_pool, v_pool)


# ---------------------------------------------------------------------------
# Quantized paged variant: int8 code pools + per-(block, head) f32 scales
# ---------------------------------------------------------------------------

def _quant_paged_kernel(tbl_ref, len_ref, q_ref, k_ref, ks_ref, v_ref,
                        vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                        scale, bs, n_b, decode):
    """``_paged_kernel`` with in-register dequant: the K/V tiles arrive
    as int8 codes (half the HBM->VMEM bytes of bf16 — the decode
    bandwidth win), their (1, 1) scale blocks ride the same
    ``tbl[i, b]`` index map, and ``value = decode(code) * scale`` is
    materialised in VMEM registers inside the online-softmax loop —
    never written back anywhere."""
    i = pl.program_id(0)
    b = pl.program_id(2)

    @pl.when(b == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[i]
    @pl.when(b * bs < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, D)
        k = decode(k_ref[0, 0]) * ks_ref[0, 0]               # (bs, D) f32
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, bs)
        cols = b * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        v = decode(v_ref[0, 0]) * vs_ref[0, 0]               # (bs, D) f32
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(b == n_b - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def quantized_paged_decode_attention_kernel(q, k_pool, v_pool, k_scales,
                                            v_scales, block_tables, lengths,
                                            *, decode,
                                            interpret: bool = False):
    """q: (N, Hkv, G, D); k_pool/v_pool: (P, Hkv, bs, D) int8 codes;
    k_scales/v_scales: (P, Hkv) float32; block_tables: (N, MB) int32;
    lengths: (N,) int32; decode: the policy's code -> f32 map (must be
    Pallas-traceable — the built-ins are astype / bitcast).
    Returns (N, Hkv, G, D)."""
    N, Hkv, G, D = q.shape
    _, _, bs, _ = k_pool.shape
    MB = block_tables.shape[1]
    grid = (N, Hkv, MB)

    kernel = functools.partial(_quant_paged_kernel, scale=D ** -0.5, bs=bs,
                               n_b=MB, decode=decode)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_tables, lengths
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda i, h, b, tbl, lens: (i, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D), lambda i, h, b, tbl, lens: (tbl[i, b], h, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, h, b, tbl, lens: (tbl[i, b], h)),
            pl.BlockSpec((1, 1, bs, D), lambda i, h, b, tbl, lens: (tbl[i, b], h, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, h, b, tbl, lens: (tbl[i, b], h)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda i, h, b, tbl, lens: (i, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, Hkv, G, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pool, k_scales, v_pool, v_scales)
