"""qwen3-8b [hf:Qwen/Qwen3-8B; dense]: 36L d=4096 32H (GQA kv=8, head_dim
128) d_ff=12288, vocab 151936, qk_norm.  Dense: the paper's MoE routing is
inapplicable (DESIGN.md 4)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="decoder_lm",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    max_seq_len=32768,
    rope_theta=1e6,
    qk_norm=True,
    ffn_activation="swiglu",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                          head_dim=16, d_ff=96, vocab_size=263, max_seq_len=128,
                          dtype="float32")
