"""pixtral-12b [hf:mistralai/Pixtral-12B-2409; vlm]: mistral-nemo decoder
backbone 40L d=5120 32H (GQA kv=8, head_dim 128) d_ff=14336, vocab 131072.
The pixtral-ViT frontend is a STUB: ``input_specs`` supplies precomputed
patch embeddings (B, num_image_tokens, d_model)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    max_seq_len=131072,
    rope_theta=1e6,
    ffn_activation="swiglu",
    num_image_tokens=1024,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                          head_dim=16, d_ff=96, vocab_size=263, max_seq_len=256,
                          num_image_tokens=8, dtype="float32")
