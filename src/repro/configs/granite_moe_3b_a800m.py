"""granite-moe-3b-a800m [hf:ibm-granite family; moe]: 32L d=1536 24H (GQA
kv=8, head_dim 64) per-expert d_ff=512, vocab 49155, 40 experts top-8.

The paper's technique applies directly: ``routing="topk", top_k=8`` is the
published baseline; ``prototyped()`` gives the M6-T 8*top-1 variant
(8 prototypes x 5 experts)."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="decoder_lm",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    max_seq_len=32768,
    rope_theta=1e4,
    tie_embeddings=True,
    ffn_activation="swiglu",
    moe=MoEConfig(num_experts=40, routing="topk", top_k=8,
                  capacity_factor=1.25, group_size=512),
)


def prototyped(k: int = 8) -> ModelConfig:
    """M6-T expert prototyping variant: k prototypes of E/k experts."""
    return CONFIG.replace_moe(routing="prototype", num_prototypes=k)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=269, max_seq_len=128, dtype="float32",
    ).replace_moe(num_experts=8, top_k=2, group_size=64)
