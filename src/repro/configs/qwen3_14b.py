"""qwen3-14b [hf:Qwen family; dense]: 40L d=5120 40H (GQA kv=8, head_dim
128) d_ff=17408, vocab 151936, qk_norm."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="decoder_lm",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    max_seq_len=32768,
    rope_theta=1e6,
    qk_norm=True,
    ffn_activation="swiglu",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=80, num_heads=5, num_kv_heads=1,
                          head_dim=16, d_ff=112, vocab_size=263, max_seq_len=128,
                          dtype="float32")
