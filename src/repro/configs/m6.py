"""The paper's own M6 multimodal MoE configs (Table 5).

All share hidden 1024, 16 heads (head_dim 64), LayerNorm, gelu expert FFN
(2 matrices — matches the published parameter counts), learned positions,
BERT-Chinese vocab 21128, image prefix of 16 patch features (4x4 patches
through a ResNet stub), text up to 128 subwords.

Table 5: base 1.4B (5L, I=4096, 32e), 10B (10L, 128e), 100B (24L, 512e),
1T (24L, I=21248, 960e, init 0.002, Adafactor lr 5e-3).
"""
from repro.configs.base import ModelConfig, MoEConfig


def _m6(name, layers, d_ff, experts, init_range=0.02, **moe_kw) -> ModelConfig:
    return ModelConfig(
        name=name,
        family="m6",
        num_layers=layers,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=d_ff,
        vocab_size=21128,
        max_seq_len=256,
        norm="layernorm",
        pos_embed="learned",
        ffn_activation="gelu",
        tie_embeddings=True,
        num_image_tokens=16,
        initializer_range=init_range,
        moe=MoEConfig(num_experts=experts, routing="topk", top_k=1,
                      capacity_factor=1.25, aux_loss_coef=0.0,
                      group_size=1024, **moe_kw),
    )


M6_BASE = _m6("m6-base", 5, 4096, 32)
M6_10B = _m6("m6-10b", 10, 4096, 128)
M6_100B = _m6("m6-100b", 24, 4096, 512)
M6_1T = _m6("m6-1t", 24, 21248, 960, init_range=0.002)

CONFIG = M6_BASE


def variant(base: ModelConfig, routing: str, k: int, capacity_mode: str = "k") -> ModelConfig:
    """Paper ablation grid (Top-1/2/4, 2/4 Top-1, Capacity kx / 1x) plus
    any other registered router (expert_choice, hash, plugins) k-way."""
    if routing == "prototype":
        return base.replace_moe(routing="prototype", num_prototypes=k,
                                prototype_top_k=1, capacity_mode=capacity_mode)
    return base.replace_moe(routing=routing, top_k=k, capacity_mode=capacity_mode)


def smoke() -> ModelConfig:
    return M6_BASE.replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=96, vocab_size=263, max_seq_len=64, num_image_tokens=4,
        dtype="float32",
    ).replace_moe(num_experts=8, group_size=32)
