"""zamba2-7b [arXiv:2411.15242; hybrid]: 81 Mamba2 layers d=3584 with a
shared attention block (32H over concat(x, x0) -> 2d, head_dim 224,
d_ff=14336) applied every 6 layers; ssm_state=64, vocab 32000."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="zamba",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    max_seq_len=524288,
    ssm_state=64,
    ssm_heads=112,          # d_inner 7168 / head dim 64
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=128,
    zamba_shared_period=6,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(num_layers=5, d_model=64, num_heads=4, num_kv_heads=4,
                          d_ff=96, vocab_size=263, max_seq_len=256, ssm_state=16,
                          ssm_heads=4, ssm_chunk=16, zamba_shared_period=2,
                          dtype="float32")
