"""deepseek-7b [arXiv:2401.02954; dense llama-arch]: 30L d=4096 32H
(kv=32, head_dim 128) d_ff=11008, vocab 102400."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="decoder_lm",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=102400,
    max_seq_len=32768,
    rope_theta=1e4,
    ffn_activation="swiglu",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                          head_dim=16, d_ff=96, vocab_size=263, max_seq_len=128,
                          dtype="float32")
