"""The assigned input-shape cells (LM transformer shapes, seq x batch)."""
from repro.configs.base import ShapeConfig

TRAIN_4K = ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524288, global_batch=1, kind="decode")

SHAPES = {s.name: s for s in [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K]}

# long_500k requires sub-quadratic sequence mixing; only the SSM/hybrid
# archs run it (see DESIGN.md 4).  Everything else: train + prefill + decode.
SUBQUADRATIC_FAMILIES = ("xlstm", "zamba")


def shapes_for(cfg) -> list:
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.family in SUBQUADRATIC_FAMILIES:
        out.append(LONG_500K)
    return out
