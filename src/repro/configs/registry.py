"""Architecture registry: --arch <id> -> (full config, smoke config)."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig

_ARCH_MODULES = {
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "m6-base": "repro.configs.m6",
    "m6-10b": "repro.configs.m6",
    "m6-100b": "repro.configs.m6",
    "m6-1t": "repro.configs.m6",
}

_M6_ATTR = {"m6-base": "M6_BASE", "m6-10b": "M6_10B",
            "m6-100b": "M6_100B", "m6-1t": "M6_1T"}

ARCH_IDS = [a for a in _ARCH_MODULES if not a.startswith("m6")]
ALL_IDS = list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(_ARCH_MODULES[arch])
    if arch in _M6_ATTR:
        return getattr(mod, _M6_ATTR[arch])
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(_ARCH_MODULES[arch])
    return mod.smoke()


def get_module(arch: str):
    return importlib.import_module(_ARCH_MODULES[arch])
