"""xlstm-125m [arXiv:2405.04517; ssm]: 12 blocks d=768 4H, sLSTM+mLSTM
(every 4th block sLSTM, xLSTM[3:1]-style), d_ff=0 (projections inside
blocks).  No FFN => the paper's MoE routing is inapplicable."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="xlstm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    max_seq_len=524288,
    xlstm_slstm_period=4,
    ssm_expand=2,
    ssm_chunk=256,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
                          vocab_size=263, max_seq_len=256, ssm_chunk=32,
                          dtype="float32")
