"""seamless-m4t-large-v2 [arXiv:2308.11596; audio enc-dec]: 24L encoder +
24L decoder, d=1024 16H (kv=16, head_dim 64), d_ff=8192, vocab 256206.
The speech frontend is a STUB: ``input_specs`` supplies precomputed frame
embeddings (B, S, d_model)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,
    num_encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    max_seq_len=32768,
    norm="layernorm",
    ffn_activation="relu",
    rope_theta=1e4,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(num_layers=2, num_encoder_layers=2, d_model=64,
                          num_heads=4, num_kv_heads=4, head_dim=16, d_ff=96,
                          vocab_size=263, max_seq_len=128, dtype="float32")
