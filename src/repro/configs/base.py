"""Config dataclasses shared by every architecture.

A single :class:`ModelConfig` covers all assigned families; family-specific
fields are ignored by families that do not use them.  Configs are plain
frozen dataclasses so they hash/compare cleanly and can key jit caches.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-Experts + expert-prototyping (M6-T) configuration."""

    num_experts: int = 0                 # 0 => dense FFN
    # Routing strategy: a key into the repro.core.routers registry.
    # Built-ins: "topk" (GShard/Switch sequential top-k, looping argmax),
    # "prototype" (M6-T k top-1 expert prototyping), "expert_choice"
    # (experts pick their top-C tokens), "hash" (stateless position hash).
    routing: str = "topk"
    top_k: int = 1                       # k for topk/expert_choice/hash routing
    num_prototypes: int = 1              # Z for prototype routing
    prototype_top_k: int = 1             # k' inside each prototype (paper: 1)
    # Capacity convention (M6-T 3.2): "k" => C = k*T/N*gamma ; "one" => C = 1*T/N*gamma
    capacity_mode: str = "k"
    # gamma (paper Table 5).  None => *dropless*: capacity is effectively
    # infinite (no token is ever dropped) and requires an execution
    # backend that never allocates (E, C) buffers (impl="dropless" —
    # validated in __post_init__ against the dispatcher registry).
    capacity_factor: Optional[float] = 1.25
    aux_loss_coef: float = 0.01          # 0 disables the balancing loss
    router_z_loss_coef: float = 0.0      # beyond-paper stability option
    router_dtype: str = "float32"        # routers always f32 (stability)
    # Renormalise each token's kept gates to sum to 1.  Applies to every
    # router (including prototype, where pre-registry code ignored it;
    # Fig. 8 itself uses raw softmax gates — hence the False default).
    normalize_gates: bool = False
    group_size: int = 2048               # tokens per routing group (GShard "d")
    combine_dtype: str = "auto"          # "auto": activation dtype (mesh-tf bf16)
    # Execution backend: a key into the repro.core.dispatch registry.
    # Built-ins: "einsum" (paper-faithful GShard one-hot einsums),
    # "gather" (index-view gather/scatter), "pallas" (grouped-GEMM
    # kernel), "alltoall" (explicit expert-parallel shard_map dispatch).
    impl: str = "einsum"
    moe_attention: bool = False          # M6-T 3.4 (negative result)
    expert_axis: str = "model"           # mesh axis experts are sharded over

    def __post_init__(self):
        if self.num_experts > 0:
            # Lazy imports: the registries live above configs in the layer
            # graph, but validation only runs at instance creation, after
            # repro.core.{routers,dispatch} have had a chance to register
            # plugins.
            from repro.core.dispatch import get_dispatcher
            from repro.core.routers import get_router

            get_router(self.routing)      # raises with the registry key list
            dispatcher = get_dispatcher(self.impl)  # likewise for backends
            if self.capacity_factor is None and not getattr(
                    dispatcher, "supports_dropless", False):
                from repro.core.dispatch import available_dispatchers
                capable = [n for n in available_dispatchers() if getattr(
                    get_dispatcher(n), "supports_dropless", False)]
                raise ValueError(
                    f"capacity_factor=None (dropless) needs a capacity-free "
                    f"execution backend, but impl={self.impl!r} allocates "
                    f"(E, C) buffers; dropless-capable dispatchers: "
                    f"{', '.join(capable) or '(none registered)'}")
            if self.capacity_factor is None and self.moe_attention:
                raise ValueError(
                    "capacity_factor=None (dropless) is incompatible with "
                    "moe_attention=True: attention experts run the dense "
                    "einsum path, whose (G, T, E, C) view would be "
                    "O(G*T^2*E) at the dropless capacity C=T")

    @property
    def active_k(self) -> int:
        """Expert choices per token (expected, for capacity/metrics)."""
        if self.num_experts == 0:
            return 0
        if self.routing == "prototype":
            return self.num_prototypes * self.prototype_top_k
        return self.top_k

    @property
    def experts_per_prototype(self) -> int:
        if self.routing != "prototype":
            return self.num_experts
        assert self.num_experts % self.num_prototypes == 0, (
            f"num_experts={self.num_experts} not divisible by "
            f"num_prototypes={self.num_prototypes}"
        )
        return self.num_experts // self.num_prototypes

    @property
    def dropless(self) -> bool:
        """True when capacity_factor=None: no token is ever dropped."""
        return self.capacity_factor is None

    def capacity(self, tokens_per_shard: int) -> int:
        """Per-expert capacity C = k*T/N*gamma (Eq. 2), or 1x variant.

        Dropless mode returns T: a token's K choices target distinct
        experts, so no expert can ever hold more than T slots per group —
        every choice is valid and the routing quality is exactly the
        capacity-infinity limit.  Only the dense (G,T,E,C) views would
        pay for this bound, and dropless backends never build them.
        """
        if self.capacity_factor is None:
            return max(tokens_per_shard, 1)
        k_eff = 1 if self.capacity_mode == "one" else max(self.active_k, 1)
        c = int(k_eff * tokens_per_shard / max(self.num_experts, 1) * self.capacity_factor)
        return max(c, 1)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "decoder_lm"   # decoder_lm | encdec | xlstm | zamba | vlm | m6
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0            # 0 => d_model // num_heads
    d_ff: int = 512              # dense FFN hidden (or per-expert hidden for MoE)
    vocab_size: int = 1024
    max_seq_len: int = 8192
    # attention details
    qk_norm: bool = False        # qwen3-style per-head RMSNorm on q,k
    qkv_bias: bool = False       # qwen2.5-style bias on QKV
    pos_embed: str = "rope"      # rope | learned (M6/BERT style)
    rope_theta: float = 1e6
    attn_logit_softcap: float = 0.0
    # "auto": chunked online-softmax when S*T is large (O(S*block) memory),
    # reference otherwise; "reference"/"chunked" force a path.
    attention_impl: str = "auto"
    attention_block: int = 512
    # FFN
    ffn_activation: str = "swiglu"   # swiglu | gelu | relu
    # norms / embeddings
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # MoE
    moe: MoEConfig = dataclasses.field(default_factory=MoEConfig)
    moe_layer_period: int = 1    # apply MoE FFN every k-th layer (1 = all)
    # enc-dec
    num_encoder_layers: int = 0
    # xLSTM
    xlstm_slstm_period: int = 0  # every k-th block is sLSTM (0 = none/all-mLSTM)
    # SSM / Mamba2 (zamba)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    zamba_shared_period: int = 6  # shared attn block applied every k mamba layers
    # VLM / multimodal stubs
    num_image_tokens: int = 0    # image/audio prefix embeddings (precomputed)
    # numerics
    dtype: str = "bfloat16"      # activation/compute dtype
    param_dtype: str = "float32"
    initializer_range: float = 0.02   # M6-T Table 5 (0.002 for 1T)
    # distribution
    remat: bool = True
    scan_layers: bool = True
    fsdp: bool = False           # shard params over data axis too (ZeRO-3 style)
    # training details
    dropout: float = 0.0         # paper uses 0.1; synthetic runs use 0

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def weight_dtype(self):
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def replace_moe(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, moe=dataclasses.replace(self.moe, **kw))


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative decoding (``repro.serving.speculative``).

    A drafter proposes up to ``gamma`` cheap continuation tokens per
    decode slot; the engine then scores all ``gamma + 1`` positions in a
    single verify step (they are ordinary prefill-chunk-style rows) and
    accepts a prefix of the drafts under the greedy / rejection-sampling
    rule — temperature 0 stays token-identical to non-speculative
    decoding, temperature > 0 preserves the target distribution.
    """

    # Drafter: a key into the repro.serving.speculative registry.
    # Built-ins: "ngram" (prompt-lookup self-drafting from the slot's own
    # prompt + generated context, no extra params) and "model" (a small
    # draft model sharing the target's vocab).
    drafter: str = "ngram"
    gamma: int = 4               # max draft tokens per slot per verify step
    # Registered config id (configs/registry ALL_IDS) for the "model"
    # drafter's draft model; smoke-sized at serve time.  Tests and
    # benchmarks may instead hand the engine a (cfg, params) pair.
    draft: Optional[str] = None
    max_ngram: int = 3           # longest context suffix the ngram drafter matches

    def __post_init__(self):
        if self.gamma < 1:
            raise ValueError("SpecConfig.gamma must be >= 1")
        if self.max_ngram < 1:
            raise ValueError("SpecConfig.max_ngram must be >= 1")
        # Lazy import, mirroring MoEConfig's router/dispatcher checks:
        # the drafter registry lives above configs in the layer graph and
        # plugins must have a chance to register before validation.
        from repro.serving.speculative import get_drafter_cls

        get_drafter_cls(self.drafter)   # raises with the registry key list


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """SLO-aware scheduling (``repro.serving.slo``): preemption with KV
    swap-to-host.  Attach to ``ServeConfig.slo`` to let the scheduler
    evict a running lower-priority victim (KV blocks copied to a
    host-side numpy pool, re-admission restores them and resumes at the
    exact token) whenever a higher-priority arrival cannot be admitted.
    Pairs with the ``priority_strict`` / ``edf`` / ``cache_aware``
    admission policies, but works under any policy.
    """

    preemption: bool = True
    # Host-pool size in KV blocks.  None => mirror the device pool (a
    # preempted working set can never exceed what was resident).
    host_blocks: Optional[int] = None
    # Per-request preemption cap: after this many round trips a request
    # is pinned (never picked as victim again) so repeated preemption
    # cannot livelock a long job under sustained high-priority load.
    max_preemptions: int = 8
    # Only waiting requests whose priority class value is <= this
    # trigger preemption (0 = HIGH only, the default).  Every class
    # still jumps the *queue* under a priority-aware admission policy;
    # the threshold decides who may evict running work — swap round
    # trips are not free, and letting every NORMAL arrival churn LOW
    # requests out of their slots costs more throughput than the queue
    # reordering buys.
    preempt_threshold: int = 0
    # Deadline-aware admission shedding: reject (finish with status
    # "shed", counted in requests_shed_total) queued requests whose
    # effective_deadline_ms is provably unmeetable given their prefill
    # length and the measured decode ms/token.  Off by default — a shed
    # request gets *no* tokens, so the gate must be an explicit opt-in
    # (--slo-shed).  Requests without a deadline are never shed.
    shed: bool = False

    def __post_init__(self):
        if self.host_blocks is not None and self.host_blocks < 1:
            raise ValueError("SLOConfig.host_blocks must be >= 1")
        if self.max_preemptions < 0:
            raise ValueError("SLOConfig.max_preemptions must be >= 0")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Continuous-batching serving shapes (``repro.serving.continuous``).

    All three sizes are *static*: the engine's jit'd step functions close
    over them, so requests entering and leaving the pool never trigger a
    recompile.  ``max_len`` bounds prompt_len + max_new_tokens per
    request (KV block tables are sized ceil(max_len / kv_block_size)).
    """

    max_slots: int = 8           # decode slots (concurrent requests)
    kv_block_size: int = 16      # tokens per KV block (paged cache page)
    prefill_chunk: int = 32      # prompt tokens ingested per mixed step
    max_len: int = 256           # per-request context bound
    # Total KV blocks in the pool.  None => fully provisioned
    # (max_slots * ceil(max_len / kv_block_size)): admission can never
    # deadlock mid-flight.  Smaller pools exercise queueing on blocks.
    num_blocks: Optional[int] = None
    # Speculative decoding; None => one token per slot per decode step.
    spec: Optional[SpecConfig] = None
    # Admission policy: a key into the repro.serving.scheduler registry
    # ("fcfs" | "sjf" | "prefill_first").
    sched_policy: str = "fcfs"
    # Block-level prefix caching (repro.serving.prefix_cache): requests
    # whose prompt prefix hashes to already-resident KV blocks bind and
    # share them (refcounted, copy-on-write), skip their prefill, and
    # charge admission only the unshared footprint.  Default off keeps
    # the exact PagedKVCache behaviour.
    prefix_cache: bool = False
    # SLO-aware scheduling: priority preemption with KV swap-to-host
    # (repro.serving.slo).  None => no preemption; priorities and
    # deadlines still order admission under the slo policies.
    slo: Optional[SLOConfig] = None
    # KV-cache quantization: a key into the repro.quant policy registry
    # ("none" | "int8" | "fp8").  Quantized pools store int8 codes plus
    # per-(layer, block, kv_head) float32 absmax scales; decode
    # attention dequantizes in-kernel.  "none" keeps the full-precision
    # pools bitwise identical to the pre-quant engine.
    kv_quant: str = "none"
    # Serving device mesh as ((axis, size), ...) — must name exactly
    # ("data", "expert"), in that order; size-1 axes are allowed.  Slots
    # and KV block pools partition over "data" (contiguous slot ranges,
    # one allocator per shard), expert FFN weights over "expert" (ragged
    # all-to-all dispatch for the dropless backend).  None => the
    # single-device engine, bit-for-bit the pre-mesh behaviour.
    mesh: Optional[Tuple[Tuple[str, int], ...]] = None

    def __post_init__(self):
        if self.max_slots < 1 or self.kv_block_size < 1 or self.prefill_chunk < 1:
            raise ValueError("max_slots, kv_block_size, prefill_chunk must be >= 1")
        if self.max_len < 2:
            raise ValueError("max_len must be >= 2 (one prompt + one generated)")
        if self.mesh is not None:
            names = tuple(a for a, _ in self.mesh)
            if names != ("data", "expert"):
                raise ValueError(
                    f"ServeConfig.mesh axes must be ('data', 'expert'), got {names}; "
                    "use size 1 for an axis you don't shard over")
            if any(int(n) < 1 for _, n in self.mesh):
                raise ValueError("ServeConfig.mesh axis sizes must be >= 1")
            d = self.data_shards
            if self.max_slots % d:
                raise ValueError(
                    f"max_slots={self.max_slots} must divide evenly over "
                    f"{d} data shards")
            if self.resolved_num_blocks % d:
                raise ValueError(
                    f"num_blocks={self.resolved_num_blocks} must divide evenly "
                    f"over {d} data shards")
        from repro.serving.scheduler import get_policy

        get_policy(self.sched_policy)   # raises with the registry key list
        from repro.quant import get_kv_quant

        get_kv_quant(self.kv_quant)     # likewise for KV quantization

    @property
    def data_shards(self) -> int:
        """Slot/KV-pool shards along the mesh's data axis (1 if unsharded)."""
        return dict(self.mesh).get("data", 1) if self.mesh else 1

    @property
    def expert_shards(self) -> int:
        return dict(self.mesh).get("expert", 1) if self.mesh else 1

    @property
    def blocks_per_slot(self) -> int:
        return -(-self.max_len // self.kv_block_size)

    @property
    def resolved_num_blocks(self) -> int:
        return self.num_blocks if self.num_blocks is not None else (
            self.max_slots * self.blocks_per_slot)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: what gets lowered and at what size."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 8e-5      # paper: AdamW 8e-5
    optimizer: str = "adamw"         # adamw | adafactor (paper 1T: adafactor @5e-3)
    warmup_steps: int = 500          # paper Table 5
    weight_decay: float = 0.01
    grad_clip_norm: float = 1.0
    zero1: bool = True               # shard optimizer state over DP axis
    grad_compression: str = "none"   # none | bf16 | int8
    microbatches: int = 1            # grad accumulation
    seed: int = 0
