"""olmoe-1b-7b [arXiv:2409.02060; moe]: 16L d=2048 16H (kv=16, head_dim
128) per-expert d_ff=1024, vocab 50304, 64 experts top-8."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="decoder_lm",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    max_seq_len=32768,
    rope_theta=1e4,
    qk_norm=True,  # OLMoE uses QK-norm
    ffn_activation="swiglu",
    moe=MoEConfig(num_experts=64, routing="topk", top_k=8,
                  capacity_factor=1.25, group_size=512),
)


def prototyped(k: int = 8) -> ModelConfig:
    return CONFIG.replace_moe(routing="prototype", num_prototypes=k)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=48, vocab_size=311, max_seq_len=128, dtype="float32",
    ).replace_moe(num_experts=8, top_k=2, group_size=64)
