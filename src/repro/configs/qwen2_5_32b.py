"""qwen2.5-32b [hf:Qwen family; dense]: 64L d=5120 40H (GQA kv=8,
head_dim 128) d_ff=27648, vocab 152064, QKV bias."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="decoder_lm",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152064,
    max_seq_len=32768,
    rope_theta=1e6,
    qkv_bias=True,
    ffn_activation="swiglu",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                          head_dim=16, d_ff=96, vocab_size=263, max_seq_len=128,
                          dtype="float32")
