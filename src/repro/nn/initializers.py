"""Weight initializers.

The paper (M6-T §4, Table 5) uses BERT truncated-normal init (mu=0,
sigma=0.02) for <=100B models and sigma reduced 10x (0.002) for the 1T
model, "to lower the absolute values of initialized weights" (also noted
by Switch Transformer).  All initializers here are pure functions
``(key, shape, dtype) -> array`` so they can live inside ParamSpec trees.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp


def zeros_init(key, shape, dtype):
    del key
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype):
    del key
    return jnp.ones(shape, dtype)


def normal_init(stddev: float = 0.02):
    def _init(key, shape, dtype):
        return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)

    return _init


def truncated_normal_init(stddev: float = 0.02, lower: float = -2.0, upper: float = 2.0):
    """BERT-style truncated normal (truncated at +/-2 sigma)."""

    def _init(key, shape, dtype):
        x = jax.random.truncated_normal(key, lower, upper, shape, jnp.float32)
        return (x * stddev).astype(dtype)

    return _init


def scaled_normal_init(fan_in_axes=(-2,), scale: float = 1.0):
    """Variance-scaled (1/sqrt(fan_in)) normal init, used for projections."""

    def _init(key, shape, dtype):
        fan_in = 1
        for ax in fan_in_axes:
            fan_in *= shape[ax]
        stddev = scale / math.sqrt(max(fan_in, 1))
        x = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
        return (x * stddev).astype(dtype)

    return _init
