"""Lightweight declarative parameter system (no flax dependency).

Modules declare a tree of :class:`ParamSpec` (shape, dtype, logical axes,
initializer).  Generic machinery then derives:

  * real parameter pytrees (``init``),
  * ``jax.sharding.PartitionSpec`` trees from logical-axis rules (``pspecs``),
  * abstract ``ShapeDtypeStruct`` trees for dry-runs (``abstract``),
  * parameter counts (``count``).

Models themselves are pure functions ``apply(params, inputs, ...)``.
"""
from repro.nn.spec import (
    ParamSpec,
    abstract,
    count_params,
    init,
    pspecs,
    map_specs,
)
from repro.nn.initializers import (
    normal_init,
    scaled_normal_init,
    truncated_normal_init,
    zeros_init,
    ones_init,
)

__all__ = [
    "ParamSpec",
    "abstract",
    "count_params",
    "init",
    "pspecs",
    "map_specs",
    "normal_init",
    "scaled_normal_init",
    "truncated_normal_init",
    "zeros_init",
    "ones_init",
]
