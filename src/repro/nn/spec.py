"""ParamSpec trees: declarative parameters -> init / sharding / counting.

A model module exposes ``specs(cfg) -> nested dict[str, ParamSpec]``.
Logical axis names on each spec (e.g. ``("embed", "mlp")``) are mapped to
mesh axes by rules in :mod:`repro.distributed.sharding`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative description of a single parameter tensor."""

    shape: Tuple[int, ...]
    dtype: Any
    logical_axes: Tuple[Optional[str], ...]
    init: Callable[[Any, Tuple[int, ...], Any], jax.Array]

    def __post_init__(self):
        if len(self.shape) != len(self.logical_axes):
            raise ValueError(
                f"shape {self.shape} and logical_axes {self.logical_axes} "
                "must have the same rank"
            )


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _flatten(tree):
    return jax.tree_util.tree_flatten(tree, is_leaf=_is_spec)


def map_specs(fn, tree):
    """tree_map over ParamSpec leaves."""
    return jax.tree_util.tree_map(fn, tree, is_leaf=_is_spec)


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree, is_leaf=_is_spec)
    return flat


def init(tree, key, dtype_override: Optional[Any] = None):
    """Materialise a ParamSpec tree into real arrays.

    RNG is split deterministically by a hash of each leaf's key-path so
    that adding/removing parameters does not silently change unrelated
    initialisations.
    """
    flat = _leaf_paths(tree)
    leaves = []
    for path, spec in flat:
        path_str = jax.tree_util.keystr(path)
        sub = jax.random.fold_in(key, _stable_hash(path_str))
        dtype = dtype_override or spec.dtype
        leaves.append(spec.init(sub, spec.shape, dtype))
    treedef = jax.tree_util.tree_structure(tree, is_leaf=_is_spec)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _stable_hash(s: str) -> int:
    # Deterministic across processes (unlike Python's salted hash()).
    h = 2166136261
    for ch in s.encode():
        h = (h ^ ch) * 16777619 & 0xFFFFFFFF
    return h


def abstract(tree, dtype_override: Optional[Any] = None):
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    return map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype_override or s.dtype), tree
    )


def pspecs(tree, rules: Mapping[str, Optional[str]]):
    """Derive a PartitionSpec tree from logical axis -> mesh axis rules.

    ``rules`` maps logical axis name to mesh axis name (or a tuple of mesh
    axes, or None for replicated).  Unknown logical axes are replicated.
    """

    def _one(spec: ParamSpec):
        axes = []
        used = set()
        for la in spec.logical_axes:
            mesh_ax = rules.get(la) if la is not None else None
            # A mesh axis may appear at most once in a PartitionSpec.
            if mesh_ax is not None:
                flat_ax = mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
                if any(a in used for a in flat_ax):
                    mesh_ax = None
                else:
                    used.update(flat_ax)
            axes.append(mesh_ax)
        # Trim trailing Nones for readability.
        while axes and axes[-1] is None:
            axes.pop()
        return P(*axes)

    return map_specs(_one, tree)


def stack_specs(tree, n: int):
    """Prefix every spec with a stacked ``layers`` axis of size n (for
    scan-over-layers parameter stacking)."""

    def _stack(spec: ParamSpec) -> ParamSpec:
        base_init = spec.init

        def _init(key, shape, dtype):
            keys = jax.random.split(key, shape[0])
            return jnp.stack([base_init(k, shape[1:], dtype) for k in keys])

        return ParamSpec((n,) + spec.shape, spec.dtype, ("layers",) + spec.logical_axes, _init)

    return map_specs(_stack, tree)


def count_params(tree) -> int:
    flat, _ = _flatten(tree)
    total = 0
    for leaf in flat:
        if isinstance(leaf, ParamSpec):
            n = 1
            for d in leaf.shape:
                n *= d
            total += n
        else:
            total += leaf.size
    return total


def tree_bytes(tree) -> int:
    flat, _ = _flatten(tree)
    total = 0
    for leaf in flat:
        if isinstance(leaf, ParamSpec):
            n = 1
            for d in leaf.shape:
                n *= d
            total += n * jnp.dtype(leaf.dtype).itemsize
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total
