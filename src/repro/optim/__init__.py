from repro.optim.api import Optimizer, make_optimizer
from repro.optim.schedules import warmup_constant, warmup_cosine
