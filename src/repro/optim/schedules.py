"""LR schedules. The paper (Table 5) uses linear warmup of 500 steps."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_constant(peak_lr: float, warmup_steps: int = 500):
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        return peak_lr * warm

    return schedule


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        progress = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * progress))
        return peak_lr * warm * (final_frac + (1 - final_frac) * cos)

    return schedule
