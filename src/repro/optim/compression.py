"""Gradient compression for data-parallel reduction.

Two modes (TrainConfig.grad_compression):

* ``bf16``: cast gradients to bfloat16 before the DP reduction — the JAX
  analogue of the paper's "FP16 communication" (Table 5); halves DP
  all-reduce bytes.
* ``int8``: per-tensor symmetric int8 quantisation with error feedback.
  Used with ``compressed_psum`` (an explicit shard_map collective:
  quantise -> all_gather(int8) -> dequantise+sum) when the trainer runs
  in explicit-collective mode; the error-feedback residual makes the
  scheme unbiased over time.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads, mode: str, error_feedback=None):
    """Lossy-compress a gradient tree; returns (compressed_grads, new_ef).

    For ``bf16`` compression the dtype conversion *is* the compression —
    under GSPMD the DP psum then moves bf16.  For ``int8`` we apply
    quantise->dequantise with error feedback (the psum itself still runs
    in the dequantised domain under GSPMD; the explicit int8 collective
    path is `compressed_psum` below).
    """
    if mode == "none":
        return grads, error_feedback
    if mode == "bf16":
        return jax.tree_util.tree_map(lambda g: g.astype(jnp.bfloat16), grads), error_feedback
    if mode == "int8":
        if error_feedback is None:
            error_feedback = jax.tree_util.tree_map(
                lambda g: jnp.zeros_like(g, jnp.float32), grads)

        def one(g, ef):
            target = g.astype(jnp.float32) + ef
            q, s = quantize_int8(target)
            deq = dequantize_int8(q, s)
            return deq.astype(g.dtype), target - deq

        pairs = jax.tree_util.tree_map(one, grads, error_feedback)
        is_pair = lambda x: isinstance(x, tuple)
        out = jax.tree_util.tree_map(lambda t: t[0], pairs, is_leaf=is_pair)
        ef = jax.tree_util.tree_map(lambda t: t[1], pairs, is_leaf=is_pair)
        return out, ef
    raise ValueError(f"unknown compression mode {mode!r}")


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8 all-gather + local dequantised sum (inside shard_map).

    Moves 1/4 the bytes of an f32 psum (int8 payload + one f32 scale per
    shard) at the cost of an all-gather layout.
    """
    q, scale = quantize_int8(x)
    qs = jax.lax.all_gather(q, axis_name)          # (n, ...)
    ss = jax.lax.all_gather(scale, axis_name)      # (n,)
    deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * q.ndim)
    return jnp.sum(deq, axis=0).astype(x.dtype)
