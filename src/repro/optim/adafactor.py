"""Adafactor (Shazeer & Stern 2018) — sublinear memory second moments.

This is what let the paper fit the 1T model's optimizer state on
32GB V100s: matrices store factored row/col second moments instead of a
full tensor.  Implementation follows the paper: decay beta2_t = 1 - t^-0.8,
update clipping at RMS d=1.0, optional parameter-scale multiplication.
The M6-T paper uses lr=5e-3 (not the 0.01 default, which diverged).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.api import Optimizer


def _factored(shape) -> bool:
    return len(shape) >= 2


def adafactor(schedule, eps1: float = 1e-30, eps2: float = 1e-3,
              clip_threshold: float = 1.0, decay_pow: float = 0.8,
              multiply_by_parameter_scale: bool = True) -> Optimizer:
    def init(params):
        def one(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),        # row
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),  # col
                }
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

        return {"v": jax.tree_util.tree_map(
            one, params, is_leaf=lambda x: hasattr(x, "shape"))}

    def update(grads, state, params, step):
        t = (step + 1).astype(jnp.float32)
        beta2 = 1.0 - t ** -decay_pow
        lr = schedule(step + 1)

        def one(g, v, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps1
            if _factored(p.shape):
                vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                # v_hat = vr vc / mean_row(vr)
                denom = jnp.mean(vr, axis=-1, keepdims=True)
                vhat = vr[..., None] * vc[..., None, :] / jnp.maximum(denom[..., None], eps1)
                new_v = {"vr": vr, "vc": vc}
            else:
                vhat = beta2 * v["v"] + (1 - beta2) * g2
                new_v = {"v": vhat}
            u = g32 / jnp.sqrt(vhat + eps1)
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps1)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            scale = lr
            if multiply_by_parameter_scale:
                p_rms = jnp.sqrt(jnp.mean(jnp.square(p.astype(jnp.float32))))
                scale = lr * jnp.maximum(p_rms, eps2)
            return (-scale * u).astype(p.dtype), new_v

        leaves_g, treedef = jax.tree_util.tree_flatten(grads)
        # state["v"] has an extra dict level below each param position;
        # flatten_up_to stops at the grads structure.
        leaves_v = treedef.flatten_up_to(state["v"])
        leaves_p = treedef.flatten_up_to(params)
        outs = [one(g, v, p) for g, v, p in zip(leaves_g, leaves_v, leaves_p)]
        updates = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
        new_v = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
        return updates, {"v": new_v}

    return Optimizer(init, update)
