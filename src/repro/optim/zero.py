"""ZeRO-1: shard optimizer state over the data-parallel axes.

Under GSPMD this is purely a sharding declaration: optimizer states
mirror the parameter trees, and we extend each state tensor's
PartitionSpec with the DP axes on the first dimension that is currently
replicated and divisible.  The partitioner then computes the optimizer
update sharded over DP and all-gathers the applied updates — the ZeRO-1
communication schedule — with state memory cut by |DP|.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import Rules


def _extend_spec(spec: P, shape, mesh: Mesh, dp_axes) -> P:
    """Add DP axes to the first replicated, divisible dim of `shape`."""
    used = set()
    for entry in spec:
        if entry is None:
            continue
        for a in (entry if isinstance(entry, tuple) else (entry,)):
            used.add(a)
    dp = tuple(a for a in dp_axes if a not in used)
    if not dp:
        return spec
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, e in enumerate(entries):
        if e is None and shape[i] > 0 and shape[i] % dp_size == 0:
            entries[i] = dp if len(dp) > 1 else dp[0]
            break
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def zero1_shardings(opt_state_shapes, param_pspecs, rules: Rules):
    """NamedSharding tree for optimizer state.

    ``opt_state_shapes``: tree of ShapeDtypeStruct from
    ``jax.eval_shape(optimizer.init, params)``.  State leaves that mirror
    a parameter keep its model-parallel spec; reduced-rank factors
    (Adafactor vr/vc) fall back to P().  All leaves additionally get DP
    sharding on a free divisible dimension (the ZeRO-1 cut).
    """
    mesh = rules.mesh
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    is_p = lambda x: isinstance(x, P)

    def build(state_sub):
        flat_p, treedef = jax.tree_util.tree_flatten(param_pspecs, is_leaf=is_p)
        try:
            flat_s = treedef.flatten_up_to(state_sub)
        except ValueError:
            return jax.tree_util.tree_map(
                lambda l: NamedSharding(mesh, _extend_spec(P(), l.shape, mesh, dp_axes)),
                state_sub)
        out = []
        for pspec, s in zip(flat_p, flat_s):
            out.append(jax.tree_util.tree_map(
                lambda l, _p=pspec: NamedSharding(
                    mesh, _extend_spec(_p if len(_p) <= len(l.shape) else P(),
                                       l.shape, mesh, dp_axes)),
                s))
        return jax.tree_util.tree_unflatten(treedef, out)

    if isinstance(opt_state_shapes, dict):
        return {k: build(v) for k, v in opt_state_shapes.items()}
    return build(opt_state_shapes)
