"""AdamW (decoupled weight decay) — the paper's optimizer for <=100B."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.api import Optimizer


def adamw(schedule, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {
            "mu": jax.tree_util.tree_map(zeros, params),
            "nu": jax.tree_util.tree_map(zeros, params),
        }

    def update(grads, state, params, step):
        step1 = step + 1
        lr = schedule(step1)
        c1 = 1 - b1 ** step1.astype(jnp.float32)
        c2 = 1 - b2 ** step1.astype(jnp.float32)

        def upd(g, mu, nu, p):
            g32 = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g32
            nu = b2 * nu + (1 - b2) * jnp.square(g32)
            mhat = mu / c1
            nhat = nu / c2
            u = -lr * (mhat / (jnp.sqrt(nhat) + eps) + weight_decay * p.astype(jnp.float32))
            return u.astype(p.dtype), mu, nu

        out = jax.tree_util.tree_map(upd, grads, state["mu"], state["nu"], params)
        updates = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"mu": mu, "nu": nu}

    return Optimizer(init, update)
