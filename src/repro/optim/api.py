"""Optimizer interface (optax-like, self-contained).

An Optimizer is a pair of pure functions:
  init(params) -> state
  update(grads, state, params, step) -> (updates, new_state)
Updates are *added* to params by the trainer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.configs.base import TrainConfig


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]


def make_optimizer(tc: TrainConfig, schedule: Callable[[Any], Any]) -> Optimizer:
    if tc.optimizer == "adamw":
        from repro.optim.adamw import adamw

        return adamw(schedule, weight_decay=tc.weight_decay)
    if tc.optimizer == "adafactor":
        from repro.optim.adafactor import adafactor

        return adafactor(schedule)
    raise ValueError(f"unknown optimizer {tc.optimizer!r}")
