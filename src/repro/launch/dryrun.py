import os
# MUST run before anything imports jax: it locks device count on first
# init.  Append to (never clobber) caller-set XLA_FLAGS, and respect a
# device count the caller already forced; REPRO_DRYRUN_DEVICES overrides
# the 512 default for small-scale CI runs.
_existing = os.environ.get("XLA_FLAGS", "")
if "--xla_force_host_platform_device_count" not in _existing:
    _count = os.environ.get("REPRO_DRYRUN_DEVICES", "512")
    os.environ["XLA_FLAGS"] = " ".join(
        f for f in (_existing, f"--xla_force_host_platform_device_count={_count}")
        if f)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
the production mesh with 512 virtual host devices, proving the sharding
config is coherent (no real hardware, no real allocation: inputs are
ShapeDtypeStructs).  Records memory_analysis / cost_analysis / collective
traffic for the roofline (EXPERIMENTS.md S Dry-run / S Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmoe-1b-7b \
      --shape train_4k --mesh single --out experiments/dryrun.json
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.configs.registry import ARCH_IDS, ALL_IDS, get_config
from repro.configs.shapes import SHAPES, shapes_for
from repro.distributed.costs import bytes_for, cost_analysis_dict, flops_for
from repro.distributed.hlo import collective_bytes, op_histogram
from repro.distributed.roofline import (
    Roofline, model_flops_forward, model_flops_train)
from repro.distributed.sharding import (
    Rules, activation_shardings, make_rules, param_shardings, use_rules)
from repro.launch.mesh import make_production_mesh
from repro.models.registry import get_family
from repro.nn import abstract as abstract_params
from repro.nn import count_params
from repro.nn.spec import ParamSpec, map_specs
from repro.optim import make_optimizer, warmup_constant
from repro.optim.zero import zero1_shardings
from repro.train.state import TrainState
from repro.train.trainer import make_train_step


def active_param_count(cfg: ModelConfig, specs) -> float:
    """Parameters touched per token: non-expert + experts * k/E."""
    total = count_params(specs)
    if cfg.moe.num_experts == 0:
        return float(total)
    expert = 0
    flat, _ = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    for leaf in flat:
        if not isinstance(leaf, ParamSpec):
            continue
        axes = leaf.logical_axes
        if axes and axes[0] == "layers":  # stacked scan params
            axes = axes[1:]
        if axes and axes[0] == "expert":  # expert weights (router excluded:
            n = 1                         # its axes start with "embed")
            for d in leaf.shape:
                n *= d
            expert += n
    frac = cfg.moe.active_k / cfg.moe.num_experts
    return float(total - expert + expert * frac)


def _batch_shardings(batch_specs: Dict, shape: ShapeConfig, cfg, rules: Rules):
    return activation_shardings(batch_specs, cfg, shape.global_batch,
                                shape.seq_len, rules)


def _auto_microbatches(cfg: ModelConfig, shape: ShapeConfig, mesh,
                       rules: Optional[Rules] = None) -> int:
    """Grad-accumulation so per-layer saved activations (scan+remat keeps
    one carry per layer) fit the HBM budget: tokens/dev/mb * d * 2B * L
    <= ~2.5GB, mb a power of two dividing the per-device batch."""
    if rules is not None:
        dp = rules.axis_size(rules.acts.get("batch"))
    else:
        dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    tokens_per_dev = shape.tokens / dp
    budget = 2.5e9
    # recurrent families hold chunk-scan residuals beyond the d_model
    # carry; weight their activation footprint accordingly
    family_factor = {"xlstm": 16.0, "zamba": 2.0}.get(cfg.family, 1.0)
    need = (tokens_per_dev * cfg.d_model * 2.0 * max(cfg.num_layers, 1)
            * family_factor / budget)
    mb = 1
    while mb < need and mb < 32 and (shape.global_batch // dp) % (mb * 2) == 0:
        mb *= 2
    return mb


def _train_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, rules: Rules,
                expert_axis: Optional[str] = None):
    fam = get_family(cfg)
    specs = fam.specs(cfg)
    params_abs = abstract_params(specs)
    n_total = count_params(specs)
    tp = mesh.shape.get("model", 1)
    if rules.params.get("mlp") is None and rules.params.get("expert") is None \
            and rules.params.get("heads") is None:
        tp = 1  # pure-DP sharding: params fully replicated without FSDP
    # FSDP when replicated (~2 bytes/param grads + params) per device is big
    wb = 2.0 if cfg.param_dtype == "bfloat16" else 4.0
    if n_total * 2 * wb / tp > 6e9 and not cfg.fsdp:
        cfg = cfg.replace(fsdp=True)
        rules = make_rules(cfg, mesh, expert_axis=expert_axis)  # param rules change
    import os as _os

    tc = TrainConfig(optimizer="adafactor" if n_total > 3e11 else "adamw",
                     microbatches=_auto_microbatches(cfg, shape, mesh, rules),
                     grad_compression=_os.environ.get("REPRO_GRAD_COMPRESSION", "none"))
    opt = make_optimizer(tc, warmup_constant(tc.learning_rate, tc.warmup_steps))

    state_abs = jax.eval_shape(
        lambda p: TrainState(p, opt.init(p), jnp.zeros((), jnp.int32), None),
        params_abs)
    p_shard = param_shardings(specs, rules)
    opt_shard = zero1_shardings(state_abs.opt_state,
                                jax.tree_util.tree_map(lambda s: s.spec, p_shard,
                                                       is_leaf=lambda x: isinstance(x, NamedSharding)),
                                rules)
    state_shard = TrainState(p_shard, opt_shard, NamedSharding(mesh, P()), None)

    batch_abs = fam.input_specs(cfg, shape)
    b_shard = _batch_shardings(batch_abs, shape, cfg, rules)

    step = make_train_step(cfg, tc, opt)

    def wrapped(state, batch):
        with use_rules(rules):
            return step(state, batch)

    jitted = jax.jit(wrapped, in_shardings=(state_shard, b_shard),
                     donate_argnums=(0,))
    lowered = jitted.lower(state_abs, batch_abs)
    n_active = active_param_count(cfg, specs)
    mf = model_flops_train(n_active, shape.tokens)
    return lowered, mf


def _prefill_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, rules: Rules):
    fam = get_family(cfg)
    specs = fam.specs(cfg)
    params_abs = abstract_params(specs)
    p_shard = param_shardings(specs, rules)
    batch_abs = fam.input_specs(cfg, shape)
    b_shard = _batch_shardings(batch_abs, shape, cfg, rules)

    if fam.prefill is not None:
        def wrapped(params, batch):
            with use_rules(rules):
                return fam.prefill(params, batch, cfg, max_len=shape.seq_len)
    else:
        def wrapped(params, batch):
            with use_rules(rules):
                return fam.forward(params, batch, cfg)

    jitted = jax.jit(wrapped, in_shardings=(p_shard, b_shard))
    lowered = jitted.lower(params_abs, batch_abs)
    n_active = active_param_count(cfg, specs)
    mf = model_flops_forward(n_active, shape.tokens)
    return lowered, mf


def _decode_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, rules: Rules):
    fam = get_family(cfg)
    specs = fam.specs(cfg)
    params_abs = abstract_params(specs)
    p_shard = param_shardings(specs, rules)
    dspec = fam.decode_input_specs(cfg, shape)
    tok_abs, state_abs = dspec["tokens"], dspec["state"]
    t_shard = activation_shardings(tok_abs, cfg, shape.global_batch, shape.seq_len, rules)
    s_shard = activation_shardings(state_abs, cfg, shape.global_batch, shape.seq_len, rules)

    def wrapped(params, tokens, state):
        with use_rules(rules):
            return fam.decode(params, tokens, state, cfg)

    jitted = jax.jit(wrapped, in_shardings=(p_shard, t_shard, s_shard),
                     donate_argnums=(2,))
    lowered = jitted.lower(params_abs, tok_abs, state_abs)
    n_active = active_param_count(cfg, specs)
    mf = model_flops_forward(n_active, shape.global_batch)  # 1 token / seq
    return lowered, mf


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             moe_impl: Optional[str] = None, save_hlo: Optional[str] = None,
             remat: Optional[bool] = None, expert_axis: Optional[str] = None,
             group_size: Optional[int] = None) -> Dict:
    cfg = get_config(arch)
    if os.environ.get("REPRO_PARAM_DTYPE"):
        cfg = cfg.replace(param_dtype=os.environ["REPRO_PARAM_DTYPE"])
    if moe_impl and cfg.moe.num_experts:
        cfg = cfg.replace_moe(impl=moe_impl)
    if group_size and cfg.moe.num_experts:
        cfg = cfg.replace_moe(group_size=group_size)
    if remat is not None:
        cfg = cfg.replace(remat=remat)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(cfg, mesh, expert_axis=expert_axis)
    t0 = time.time()
    if shape.kind == "train":
        lowered, mf = _train_cell(cfg, shape, mesh, rules, expert_axis=expert_axis)
    elif shape.kind == "prefill":
        lowered, mf = _prefill_cell(cfg, shape, mesh, rules)
    else:
        lowered, mf = _decode_cell(cfg, shape, mesh, rules)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = cost_analysis_dict(compiled)
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    chips = mesh.size
    # Roofline terms use the analytic models (tests/test_costs.py validates
    # them against unrolled probes) because XLA's cost analysis counts scan
    # bodies once; collectives come from the trip-count-aware HLO parse.
    specs = get_family(cfg).specs(cfg)
    n_params = count_params(specs)
    a_flops = flops_for(cfg, shape)
    a_bytes = bytes_for(cfg, shape, n_params)
    rl = Roofline(
        flops=a_flops,
        bytes_accessed=a_bytes,
        collective_bytes=float(coll["total"]),
        chips=chips,
        model_flops=mf,
    )
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.temp_size_in_bytes),
            "fits_16gb": (mem.argument_size_in_bytes + mem.temp_size_in_bytes) < 16e9,
        },
        "collectives": coll,
        "roofline": rl.to_dict(),
        "raw_cost_analysis": {   # undercounts scan bodies — recorded for
            "flops": float(cost.get("flops", 0.0)),          # transparency
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "n_params": n_params,
        "op_histogram": op_histogram(hlo),
    }
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    return result


def cells(arch_filter: str, shape_filter: str, mesh_filter: str):
    archs = ARCH_IDS if arch_filter == "all" else [arch_filter]
    for arch in archs:
        cfg = get_config(arch)
        shapes = shapes_for(cfg)
        for shape in shapes:
            if shape_filter != "all" and shape.name != shape_filter:
                continue
            if mesh_filter in ("single", "both"):
                yield arch, shape.name, False
            if mesh_filter in ("multi", "both"):
                yield arch, shape.name, True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", choices=["all"] + ALL_IDS)
    ap.add_argument("--shape", default="all", choices=["all"] + list(SHAPES))
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    from repro.core.dispatch import available_dispatchers
    ap.add_argument("--moe-impl", default=None,
                    choices=[None, *available_dispatchers()])
    ap.add_argument("--expert-axis", default=None)
    ap.add_argument("--group-size", type=int, default=None)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun.json")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--tag", default=None, help="suffix results key (perf experiments)")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for arch, shape_name, multi in cells(args.arch, args.shape, args.mesh):
        key = f"{arch}|{shape_name}|{'multi' if multi else 'single'}"
        if args.tag:
            key += f"|{args.tag}"
        print(f"=== {key} ===", flush=True)
        try:
            res = run_cell(arch, shape_name, multi, moe_impl=args.moe_impl,
                           save_hlo=args.save_hlo, expert_axis=args.expert_axis,
                           group_size=args.group_size,
                           remat=False if args.no_remat else None)
            rl = res["roofline"]
            print(f"  compile {res['compile_s']}s | mem/dev "
                  f"{res['memory']['peak_bytes_per_device']/1e9:.2f}GB | "
                  f"t_comp {rl['t_compute']*1e3:.2f}ms t_mem {rl['t_memory']*1e3:.2f}ms "
                  f"t_coll {rl['t_collective']*1e3:.2f}ms -> {rl['dominant']}", flush=True)
        except Exception as e:  # noqa: BLE001 — record the failure
            res = {"arch": arch, "shape": shape_name,
                   "mesh": "2x16x16" if multi else "16x16",
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"  FAILED: {res['error']}", flush=True)
        results[key] = res
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
