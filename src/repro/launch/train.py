"""End-to-end training driver.

Runs any registered arch (full or smoke config) on the local devices with
the full production stack: sharded params (pjit), ZeRO-1 optimizer state,
checkpoint/restart (atomic + async), straggler watchdog, seekable data.

  PYTHONPATH=src python -m repro.launch.train --arch m6-base --smoke \
      --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt --routing prototype
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import TrainConfig
from repro.configs.registry import ALL_IDS, get_config, get_smoke_config
from repro.data.pipeline import make_pipeline
from repro.distributed.fault import StepWatchdog, run_with_restarts
from repro.distributed.sharding import make_rules, param_shardings, use_rules
from repro.launch.mesh import make_debug_mesh
from repro.models.registry import get_family
from repro.nn import init as init_params
from repro.optim import make_optimizer, warmup_constant
from repro.train.state import TrainState, init_train_state
from repro.train.trainer import make_train_step


def build(args):
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.routing and cfg.moe.num_experts:
        if args.routing == "prototype":
            cfg = cfg.replace_moe(routing="prototype",
                                  num_prototypes=args.k)
        else:  # any other registry key routes k-way via top_k
            cfg = cfg.replace_moe(routing=args.routing, top_k=args.k)
    if args.capacity:
        cfg = cfg.replace_moe(capacity_mode=args.capacity)
    if args.moe_impl and cfg.moe.num_experts:
        cfg = cfg.replace_moe(impl=args.moe_impl)
    if args.capacity_factor is not None and cfg.moe.num_experts:
        cfg = cfg.replace_moe(capacity_factor=parse_capacity_factor(args.capacity_factor))
    if args.aux_loss_coef is not None:
        cfg = cfg.replace_moe(aux_loss_coef=args.aux_loss_coef)
    return cfg


def parse_capacity_factor(value: str):
    """'none' => dropless (capacity_factor=None); otherwise a float gamma."""
    return None if value.lower() in ("none", "dropless", "inf") else float(value)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="m6-base", choices=ALL_IDS)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--optimizer", default=None, choices=[None, "adamw", "adafactor"])
    from repro.core.dispatch import available_dispatchers
    from repro.core.routers import available_routers
    ap.add_argument("--routing", default=None,
                    choices=[None, *available_routers()])
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--capacity", default=None, choices=[None, "k", "one"])
    ap.add_argument("--capacity-factor", default=None,
                    help="gamma, or 'none' for dropless (requires a "
                         "capacity-free --moe-impl such as 'dropless')")
    ap.add_argument("--moe-impl", default=None,
                    choices=[None, *available_dispatchers()])
    ap.add_argument("--aux-loss-coef", type=float, default=None)
    ap.add_argument("--grad-compression", default="none")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--log-file", default=None)
    ap.add_argument("--data", default=1, type=int, help="data mesh axis")
    ap.add_argument("--model", default=1, type=int, help="model mesh axis")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-restarts", type=int, default=3)
    # observability (repro.obs)
    ap.add_argument("--trace-out", default=None,
                    help="write per-train-step spans here: Chrome-trace "
                         "JSON (Perfetto), or span JSONL for .jsonl paths")
    ap.add_argument("--metrics-out", default=None,
                    help="write registry snapshots (one row per logged "
                         "step) as metrics JSONL")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler device trace of the train "
                         "loop into this directory")
    args = ap.parse_args(argv)

    cfg = build(args)
    fam = get_family(cfg)
    tc = TrainConfig(
        optimizer=args.optimizer or ("adafactor" if cfg.name == "m6-1t" else "adamw"),
        learning_rate=args.lr or (5e-3 if args.optimizer == "adafactor" else 8e-5),
        grad_compression=args.grad_compression,
        microbatches=args.microbatches,
        warmup_steps=min(500, args.steps // 4 + 1),
    )
    mesh = make_debug_mesh(args.data, args.model)
    rules = make_rules(cfg, mesh)

    specs = fam.specs(cfg)
    opt = make_optimizer(tc, warmup_constant(tc.learning_rate, tc.warmup_steps))
    step_fn = make_train_step(cfg, tc, opt)

    def wrapped(state, batch):
        with use_rules(rules):
            return step_fn(state, batch)

    p_shard = param_shardings(specs, rules)
    jit_step = jax.jit(wrapped, donate_argnums=(0,))

    pipeline = make_pipeline(cfg, args.batch, args.seq, seed=args.seed)
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    watchdog = StepWatchdog()
    logs = []

    from repro.obs import Observability
    obs = Observability(tracing=args.trace_out is not None)
    if args.metrics_out:
        obs.metrics_every = max(args.log_every, 1)

    def fresh_state():
        params = init_params(specs, jax.random.PRNGKey(args.seed))
        params = jax.device_put(params, p_shard)
        return init_train_state(params, opt, tc.grad_compression)

    def resume_step():
        if ckpt is None or ckpt.latest_step() is None:
            return 0
        return ckpt.latest_step()

    def loop(start_step: int) -> int:
        state = fresh_state()
        if ckpt is not None and start_step > 0:
            state = ckpt.restore(start_step, jax.eval_shape(lambda: state))
        t_tokens = args.batch * args.seq
        reg = obs.metrics
        for step in range(start_step, args.steps):
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in pipeline.batch_at(step).items()}
            with obs.tracer.span("train_step", cat="train", step=step,
                                 tokens=t_tokens):
                state, metrics = jit_step(state, batch)
            reg.counter("train_steps_total").inc()
            reg.counter("train_tokens_total").inc(t_tokens)
            if step % args.log_every == 0 or step == args.steps - 1:
                m = {k: float(np.mean(jax.device_get(v))) for k, v in metrics.items()}
                dt = time.time() - t0
                watchdog.observe(dt)
                m.update(step=step, step_time_s=round(dt, 3),
                         tokens_per_s=round(t_tokens / dt, 1))
                logs.append(m)
                for key in ("loss", "ce", "moe_cv", "moe_dropped_fraction",
                            "moe_aux_loss", "moe_z_loss"):
                    if key in m:
                        reg.gauge(f"train_{key}").set(m[key])
                reg.gauge("train_tokens_per_s").set(m["tokens_per_s"])
                reg.histogram("train_step_ms").observe(dt * 1e3)
                if args.metrics_out:
                    obs.metrics_row(step=step)
                print(f"step {step:5d} loss {m['loss']:.4f} ce {m['ce']:.4f} "
                      f"cv {m.get('moe_cv', 0):.3f} drop {m.get('moe_dropped_fraction', 0):.3f} "
                      f"({m['tokens_per_s']:.0f} tok/s)", flush=True)
            if ckpt is not None and step > 0 and step % args.ckpt_every == 0:
                ckpt.save_async(step, state)
        if ckpt is not None:
            ckpt.wait()
            ckpt.save(args.steps, state)
        return args.steps

    if args.profile_dir:
        jax.profiler.start_trace(args.profile_dir)
    try:
        with mesh:
            run_with_restarts(loop, resume_step, max_restarts=args.max_restarts)
    finally:
        if args.profile_dir:
            jax.profiler.stop_trace()

    if args.trace_out:
        if args.trace_out.endswith(".jsonl"):
            obs.tracer.write_jsonl(args.trace_out)
        else:
            obs.tracer.write_chrome_trace(args.trace_out)
    if args.metrics_out:
        obs.write_metrics_jsonl(args.metrics_out)
    if args.log_file:
        with open(args.log_file, "w") as f:
            json.dump(logs, f, indent=1)
    return logs


if __name__ == "__main__":
    main()
