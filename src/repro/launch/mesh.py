"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (v5e pod),
axes (data, model).  Multi-pod: 2 pods = 512 chips, axes (pod, data,
model) — the "pod" axis is extra data parallelism whose collectives cross
the inter-pod (DCI) links.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    # CI/test hook: scale the mesh down (e.g. REPRO_MESH_SINGLE=2,4).
    import os

    env = os.environ.get("REPRO_MESH_MULTI" if multi_pod else "REPRO_MESH_SINGLE")
    if env:
        shape = tuple(int(x) for x in env.split(","))
        assert len(shape) == len(axes), (shape, axes)
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many devices this process has."""
    return jax.make_mesh((data, model), ("data", "model"))
