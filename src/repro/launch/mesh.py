"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (v5e pod),
axes (data, model).  Multi-pod: 2 pods = 512 chips, axes (pod, data,
model) — the "pod" axis is extra data parallelism whose collectives cross
the inter-pod (DCI) links.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    # CI/test hook: scale the mesh down (e.g. REPRO_MESH_SINGLE=2,4).
    import os

    env = os.environ.get("REPRO_MESH_MULTI" if multi_pod else "REPRO_MESH_SINGLE")
    if env:
        shape = tuple(int(x) for x in env.split(","))
        assert len(shape) == len(axes), (shape, axes)
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many devices this process has."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_serve_mesh(spec):
    """Mesh for ``ServeConfig.mesh``: ((axis, size), ...) pairs.

    Unlike ``jax.make_mesh`` this tolerates *more* local devices than the
    mesh needs — it lays the mesh over the first prod(sizes) devices — so
    a (1, 1) parity cell runs on a laptop and a (2, 4) cell on the same
    8-virtual-device process as an (8, 1) one.
    """
    import numpy as np
    from jax.sharding import Mesh

    names = tuple(a for a, _ in spec)
    sizes = tuple(int(n) for _, n in spec)
    need = int(np.prod(sizes))
    devices = jax.devices()
    if len(devices) < need:
        raise ValueError(
            f"mesh {spec!r} needs {need} devices, have {len(devices)}")
    return Mesh(np.array(devices[:need]).reshape(sizes), names)
