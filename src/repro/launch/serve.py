"""Serving driver: static lockstep batching or continuous batching, over
synthetic prompts or a request trace.

  # static lockstep batch (the original smoke mode)
  PYTHONPATH=src python -m repro.launch.serve --arch olmoe-1b-7b --smoke \
      --engine static --batch 4 --prompt-len 16 --gen 32

  # continuous batching over a synthetic Poisson mixed-length trace
  PYTHONPATH=src python -m repro.launch.serve --arch olmoe-1b-7b --smoke \
      --engine continuous --requests 16 --qps 40

  # trace-driven (JSONL of {"prompt_len", "gen_len", "arrival_ms"})
  PYTHONPATH=src python -m repro.launch.serve --arch olmoe-1b-7b --smoke \
      --engine continuous --trace trace.jsonl
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ServeConfig, SLOConfig, SpecConfig
from repro.configs.registry import ALL_IDS, get_config, get_smoke_config
from repro.models.registry import get_family
from repro.nn import abstract, init as init_params
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import ServingEngine
from repro.serving.trace import (
    latency_line,
    load_trace,
    run_trace_static,
    static_max_len,
    slo_class_line,
    synthetic_multitenant,
    synthetic_priority,
    synthetic_trace,
)


def parse_mesh(text: str):
    """'data=2,expert=4' or bare '2,4' -> ServeConfig.mesh tuples."""
    sizes = {}
    parts = [p.strip() for p in text.split(",") if p.strip()]
    if all("=" in p for p in parts):
        for p in parts:
            name, _, n = p.partition("=")
            sizes[name.strip()] = int(n)
    else:
        if len(parts) > 2:
            raise SystemExit(f"--mesh {text!r}: at most data,expert sizes")
        for name, n in zip(("data", "expert"), parts):
            sizes[name] = int(n)
    unknown = set(sizes) - {"data", "expert"}
    if unknown:
        raise SystemExit(
            f"--mesh {text!r}: unknown axes {sorted(unknown)} "
            "(serving meshes have axes data, expert)")
    return (("data", sizes.get("data", 1)), ("expert", sizes.get("expert", 1)))


def _write_obs(engine, args) -> None:
    """Flush the engine's tracer / metrics registry to the requested
    output files (docs/observability.md documents both formats)."""
    if args.trace_out:
        if args.trace_out.endswith(".jsonl"):
            engine.obs.tracer.write_jsonl(args.trace_out)
        else:
            engine.obs.tracer.write_chrome_trace(args.trace_out)
        print(f"trace -> {args.trace_out} "
              f"({len(engine.obs.tracer.events())} events, "
              f"{engine.obs.tracer.dropped_events} dropped)")
    if args.metrics_out:
        engine.obs.write_metrics_jsonl(args.metrics_out)
        print(f"metrics -> {args.metrics_out}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b", choices=ALL_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", default="static", choices=["static", "continuous"])
    ap.add_argument("--batch", type=int, default=4,
                    help="static engine batch size (trace groups / smoke batch)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    # trace-driven mode
    ap.add_argument("--trace", default=None,
                    help="JSONL trace of {prompt_len, gen_len, arrival_ms}; "
                         "omit for a synthetic mixed-length Poisson trace")
    ap.add_argument("--requests", type=int, default=0,
                    help="synthesize a trace of this many requests (>0 "
                         "switches to trace mode without --trace)")
    ap.add_argument("--qps", type=float, default=50.0,
                    help="synthetic trace Poisson arrival rate")
    ap.add_argument("--trace-kind", default="mixed",
                    choices=["mixed", "multitenant", "priority"],
                    help="synthetic trace family: mixed-length Poisson, "
                         "multi-tenant shared-system-prompt (the workload "
                         "--prefix-cache targets), or bursty mixed-priority "
                         "overload with deadlines (the workload --slo-preempt "
                         "and the slo policies target)")
    ap.add_argument("--tenants", type=int, default=4,
                    help="multitenant/priority trace: distinct system prompts")
    ap.add_argument("--system-prompt-len", type=int, default=48,
                    help="multitenant trace: shared system-prompt length")
    ap.add_argument("--burst-qps", type=float, default=None,
                    help="priority trace: arrival rate during bursts "
                         "(default 4x --qps)")
    # continuous-batching shapes
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--kv-block", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--mesh", default=None,
                    help="shard the continuous engine over a (data, expert) "
                         "device mesh: 'data=2,expert=4' (or bare '2,4'). "
                         "Slots and KV block pools partition over the data "
                         "axis, expert FFN weights over the expert axis "
                         "(ragged all-to-all dropless dispatch)")
    from repro.serving.scheduler import available_policies
    ap.add_argument("--sched-policy", default="fcfs",
                    choices=available_policies(),
                    help="admission policy (fcfs | sjf | prefill_first)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="content-addressed block-level prefix caching: "
                         "admission binds cached prompt-prefix blocks and "
                         "skips their prefill (continuous engine only)")
    ap.add_argument("--slo-preempt", action="store_true",
                    help="SLO-aware preemption: let a higher-priority arrival "
                         "evict a running lower-priority request, swapping its "
                         "KV blocks to a host pool for later restore "
                         "(continuous engine only)")
    ap.add_argument("--slo-shed", action="store_true",
                    help="deadline-aware admission shedding: reject a queued "
                         "request at the door once its deadline is provably "
                         "unmeetable from the measured decode rate "
                         "(continuous engine only)")
    ap.add_argument("--host-blocks", type=int, default=None,
                    help="host swap pool size in KV blocks "
                         "(default: mirror the device pool)")
    from repro.quant import available_kv_quants
    ap.add_argument("--kv-quant", default="none",
                    choices=available_kv_quants(),
                    help="KV-cache pool representation: quantized pools store "
                         "int8 codes + per-block-per-head f32 scales, with "
                         "fused dequant in paged attention "
                         "(continuous engine only)")
    # speculative decoding (continuous engine only)
    from repro.serving.speculative import available_drafters
    ap.add_argument("--spec-drafter", default=None,
                    choices=[None, *available_drafters()],
                    help="enable speculative decoding with this drafter")
    ap.add_argument("--spec-gamma", type=int, default=4,
                    help="max draft tokens per slot per verify step")
    ap.add_argument("--spec-draft", default=None, choices=[None, *ALL_IDS],
                    help="draft model config id for --spec-drafter model "
                         "(smoke-sized; must share the target vocab)")
    ap.add_argument("--spec-draft-ckpt", default=None,
                    help="checkpoint dir for the draft model's params "
                         "(params-only restore; without it the draft model "
                         "is randomly initialised, which costs — not buys — "
                         "throughput)")
    from repro.core.dispatch import available_dispatchers
    ap.add_argument("--moe-impl", default=None,
                    choices=[None, *available_dispatchers()],
                    help="override the MoE execution backend for serving")
    ap.add_argument("--capacity-factor", default=None,
                    help="gamma, or 'none' for dropless serving")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    # observability (repro.obs; continuous engine only)
    ap.add_argument("--trace-out", default=None,
                    help="write request-lifecycle + engine-step spans here: "
                         "Chrome-trace JSON (open in Perfetto), or span "
                         "JSONL when the path ends in .jsonl")
    ap.add_argument("--metrics-out", default=None,
                    help="write registry snapshots as metrics JSONL "
                         "(periodic rows per --metrics-every + a final row)")
    ap.add_argument("--metrics-every", type=int, default=50,
                    help="snapshot the registry every N engine steps")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler device trace of the serve "
                         "run into this directory (view with TensorBoard "
                         "or Perfetto)")
    args = ap.parse_args(argv)

    mesh_spec = None
    if args.mesh is not None:
        if args.engine != "continuous":
            raise SystemExit("--mesh needs --engine continuous")
        mesh_spec = parse_mesh(args.mesh)

    obs = None
    if args.trace_out or args.metrics_out or args.profile_dir:
        if args.engine != "continuous":
            raise SystemExit("--trace-out/--metrics-out/--profile-dir need "
                             "--engine continuous")
        from repro.obs import Observability

        obs = Observability(tracing=args.trace_out is not None)
        if args.metrics_out:
            obs.metrics_every = max(args.metrics_every, 1)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.moe_impl and cfg.moe.num_experts:
        cfg = cfg.replace_moe(impl=args.moe_impl)
    if args.capacity_factor is not None and cfg.moe.num_experts:
        from repro.launch.train import parse_capacity_factor
        cfg = cfg.replace_moe(
            capacity_factor=parse_capacity_factor(args.capacity_factor))
    fam = get_family(cfg)
    specs = fam.specs(cfg)
    params = init_params(specs, jax.random.PRNGKey(args.seed))
    if args.ckpt_dir:
        # params-only restore: no throwaway optimizer, no TrainState —
        # the Checkpointer maps the params subtree out of a train.py
        # checkpoint (or a bare-params one) directly.
        ckpt = Checkpointer(args.ckpt_dir)
        restored, step = ckpt.restore_params_latest(abstract(specs))
        if restored is not None:
            params = restored
            print(f"restored params-only from checkpoint step {step}")

    spec = None
    draft_model = None
    if args.spec_drafter is not None:
        if args.engine != "continuous":
            raise SystemExit("--spec-drafter needs --engine continuous")
        spec = SpecConfig(drafter=args.spec_drafter, gamma=args.spec_gamma,
                          draft=args.spec_draft)
        if args.spec_draft_ckpt:
            if args.spec_drafter != "model":
                raise SystemExit("--spec-draft-ckpt needs --spec-drafter model")
            if args.spec_draft is None:
                raise SystemExit("--spec-draft-ckpt needs --spec-draft")
            dcfg = get_smoke_config(args.spec_draft) if args.smoke else (
                get_config(args.spec_draft))
            dparams = init_params(get_family(dcfg).specs(dcfg),
                                  jax.random.PRNGKey(args.seed + 1))
            restored, dstep = Checkpointer(args.spec_draft_ckpt) \
                .restore_params_latest(abstract(get_family(dcfg).specs(dcfg)))
            if restored is not None:
                dparams = restored
                print(f"restored draft params from checkpoint step {dstep}")
            draft_model = (dcfg, dparams)

    trace_mode = args.trace is not None or args.requests > 0

    if not trace_mode:
        # original smoke mode: one uniform batch
        max_len = args.prompt_len + args.gen + 1
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size)
        if args.engine == "static":
            engine = ServingEngine(cfg, params, max_len=max_len)
            toks, stats = engine.generate(prompts, args.gen,
                                          temperature=args.temperature,
                                          seed=args.seed)
        else:
            serve = ServeConfig(max_slots=args.max_slots,
                                kv_block_size=args.kv_block,
                                prefill_chunk=args.prefill_chunk,
                                max_len=max(args.max_len, max_len),
                                spec=spec, sched_policy=args.sched_policy,
                                kv_quant=args.kv_quant, mesh=mesh_spec)
            engine = ContinuousEngine(cfg, params, serve,
                                      temperature=args.temperature,
                                      seed=args.seed, draft_model=draft_model,
                                      obs=obs)
            if args.profile_dir:
                jax.profiler.start_trace(args.profile_dir)
            try:
                toks, stats = engine.generate(prompts, args.gen)
            finally:
                if args.profile_dir:
                    jax.profiler.stop_trace()
            _write_obs(engine, args)
        print("generated:", np.asarray(toks)[:, :16])
        print({k: round(float(v), 4) for k, v in stats.items()})
        return

    # trace-driven serving
    if args.trace is not None:
        requests = load_trace(args.trace, cfg.vocab_size, seed=args.seed)
    elif args.trace_kind == "multitenant":
        requests = synthetic_multitenant(
            args.requests, cfg.vocab_size, seed=args.seed, qps=args.qps,
            num_tenants=args.tenants,
            system_prompt_len=args.system_prompt_len)
    elif args.trace_kind == "priority":
        requests = synthetic_priority(
            args.requests, cfg.vocab_size, seed=args.seed, qps=args.qps,
            burst_qps=args.burst_qps, num_tenants=args.tenants,
            system_prompt_len=args.system_prompt_len if args.prefix_cache else 0)
    else:
        requests = synthetic_trace(args.requests, cfg.vocab_size,
                                   seed=args.seed, qps=args.qps)
    longest = max(r.total_len for r in requests)
    static_len = static_max_len(requests)
    print(f"serving {len(requests)} requests "
          f"({'trace ' + args.trace if args.trace else 'synthetic ' + args.trace_kind}), "
          f"engine={args.engine}")

    if args.engine == "static":
        engine = ServingEngine(cfg, params, max_len=static_len)
        _, stats = run_trace_static(engine, requests, args.batch,
                                    temperature=args.temperature,
                                    seed=args.seed)
    else:
        slo = (SLOConfig(preemption=args.slo_preempt,
                         host_blocks=args.host_blocks, shed=args.slo_shed)
               if (args.slo_preempt or args.slo_shed) else None)
        serve = ServeConfig(max_slots=args.max_slots,
                            kv_block_size=args.kv_block,
                            prefill_chunk=args.prefill_chunk,
                            max_len=max(args.max_len, longest),
                            spec=spec, sched_policy=args.sched_policy,
                            prefix_cache=args.prefix_cache, slo=slo,
                            kv_quant=args.kv_quant, mesh=mesh_spec)
        engine = ContinuousEngine(cfg, params, serve,
                                  temperature=args.temperature, seed=args.seed,
                                  draft_model=draft_model, obs=obs)

        def stream(st):
            head = st.generated[:8]
            print(f"  req {st.request.uid}: {len(st.generated)} tokens, "
                  f"latency {st.latency_ms():.0f}ms, first {head}")

        if args.profile_dir:
            jax.profiler.start_trace(args.profile_dir)
        try:
            _, stats = engine.run(requests, on_finish=stream)
        finally:
            if args.profile_dir:
                jax.profiler.stop_trace()
        _write_obs(engine, args)
        if spec is not None:
            print(f"speculative[{spec.drafter}]: acceptance "
                  f"{stats['acceptance_rate']:.2f}, "
                  f"{stats['spec_tokens_per_step']:.2f} tokens/verify-step")
        if args.prefix_cache:
            cs = engine.cache.stats
            print(f"prefix cache: {stats['cached_tokens']:.0f}/"
                  f"{stats['prompt_tokens']:.0f} prompt tokens cached "
                  f"({stats['cached_token_ratio']:.0%}), "
                  f"{cs['bound_blocks']} blocks bound shared, "
                  f"{cs['published_blocks']} published, "
                  f"{cs['cow_copies']} COW copies, "
                  f"{cs['evicted_blocks']} evicted")
        line = slo_class_line(stats)
        if line:
            print(line)
    print(latency_line(stats))


if __name__ == "__main__":
    main()
