"""Serving driver: load (or init) a model and serve batched requests.

  PYTHONPATH=src python -m repro.launch.serve --arch olmoe-1b-7b --smoke \
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.registry import ALL_IDS, get_config, get_smoke_config
from repro.models.registry import get_family
from repro.nn import abstract, init as init_params
from repro.serving.engine import ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b", choices=ALL_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    from repro.core.dispatch import available_dispatchers
    ap.add_argument("--moe-impl", default=None,
                    choices=[None, *available_dispatchers()],
                    help="override the MoE execution backend for serving")
    ap.add_argument("--capacity-factor", default=None,
                    help="gamma, or 'none' for dropless serving")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.moe_impl and cfg.moe.num_experts:
        cfg = cfg.replace_moe(impl=args.moe_impl)
    if args.capacity_factor is not None and cfg.moe.num_experts:
        from repro.launch.train import parse_capacity_factor
        cfg = cfg.replace_moe(
            capacity_factor=parse_capacity_factor(args.capacity_factor))
    fam = get_family(cfg)
    specs = fam.specs(cfg)
    params = init_params(specs, jax.random.PRNGKey(args.seed))
    if args.ckpt_dir:
        # restore params from a train.py checkpoint (TrainState layout,
        # default AdamW) — elastic across device topologies
        from repro.configs.base import TrainConfig
        from repro.optim import make_optimizer, warmup_constant
        from repro.train.state import init_train_state

        tc = TrainConfig()
        opt = make_optimizer(tc, warmup_constant(tc.learning_rate))
        template = jax.eval_shape(
            lambda p: init_train_state(p, opt, tc.grad_compression), abstract(specs))
        ckpt = Checkpointer(args.ckpt_dir)
        state, step = ckpt.restore_latest(template)
        if state is not None:
            params = state.params
            print(f"restored checkpoint step {step}")

    max_len = args.prompt_len + args.gen + 1
    engine = ServingEngine(cfg, params, max_len=max_len)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (args.batch, args.prompt_len),
                                 0, cfg.vocab_size)
    toks, stats = engine.generate(prompts, args.gen, temperature=args.temperature,
                                  seed=args.seed)
    print("generated:", np.asarray(toks)[:, :16])
    print({k: round(v, 4) for k, v in stats.items()})


if __name__ == "__main__":
    main()
