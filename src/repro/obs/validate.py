"""Schema validators for the observability output files.

Shared by the test-suite and the CI smoke step (``python -m
repro.obs.validate trace.json metrics.jsonl``): a trace must be a
well-formed Chrome-trace JSON whose async spans balance, and a metrics
file must be JSONL whose rows carry a flat ``metrics`` mapping of
finite numbers.  Both raise ``ValueError`` with a specific message on
the first violation and return a small summary dict on success.
"""
from __future__ import annotations

import json
import math
import sys
from typing import Dict, Sequence

_PHASES = {"X", "b", "e", "i", "M", "C"}


def validate_chrome_trace(path: str) -> Dict[str, int]:
    """Perfetto-loadability checks: top-level ``traceEvents`` list;
    every event has name/ph/ts; ``X`` events carry a non-negative
    ``dur``; ``b``/``e`` events carry an id and balance exactly (never
    more ends than begins, none left open) per ``(cat, id)``."""
    with open(path) as fh:
        doc = json.load(fh)
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        raise ValueError(f"{path}: not a Chrome trace "
                         "(missing 'traceEvents' list)")
    depth: Dict[tuple, int] = {}
    counts = {"X": 0, "b": 0, "e": 0, "i": 0}
    for i, ev in enumerate(doc["traceEvents"]):
        for field in ("name", "ph", "ts"):
            if field not in ev:
                raise ValueError(f"{path}: event {i} missing {field!r}")
        ph = ev["ph"]
        if ph not in _PHASES:
            raise ValueError(f"{path}: event {i} has unknown ph {ph!r}")
        if not isinstance(ev["ts"], (int, float)):
            raise ValueError(f"{path}: event {i} ts is not a number")
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                raise ValueError(f"{path}: X event {i} ({ev['name']}) "
                                 "needs a non-negative dur")
        if ph in ("b", "e"):
            if "id" not in ev:
                raise ValueError(f"{path}: async event {i} missing id")
            key = (ev.get("cat", ""), ev["id"])
            depth[key] = depth.get(key, 0) + (1 if ph == "b" else -1)
            if depth[key] < 0:
                raise ValueError(
                    f"{path}: async end without begin for {key}")
        if ph in counts:
            counts[ph] += 1
    open_spans = {k: d for k, d in depth.items() if d != 0}
    if open_spans:
        raise ValueError(f"{path}: unclosed async spans: "
                         f"{sorted(open_spans)[:5]}")
    counts["events"] = len(doc["traceEvents"])
    return counts


def validate_metrics_jsonl(path: str,
                           require: Sequence[str] = ()) -> Dict[str, int]:
    """Every line parses as a JSON object with a ``metrics`` dict of
    string → finite number; the *last* row must contain every metric
    name in ``require`` (matched as an exact series or as a name prefix
    before ``{``, so ``kv_blocks`` matches ``kv_blocks{shard=0,...}``)."""
    rows = []
    with open(path) as fh:
        for ln, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                row = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{ln}: not JSON ({e})") from None
            if not isinstance(row, dict) or not isinstance(
                    row.get("metrics"), dict):
                raise ValueError(f"{path}:{ln}: row needs a 'metrics' dict")
            for k, v in row["metrics"].items():
                if not isinstance(k, str):
                    raise ValueError(f"{path}:{ln}: non-string metric key")
                if not isinstance(v, (int, float)) or (
                        isinstance(v, float) and not math.isfinite(v)):
                    raise ValueError(
                        f"{path}:{ln}: metric {k!r} is not a finite number "
                        f"({v!r})")
            rows.append(row)
    if not rows:
        raise ValueError(f"{path}: no metric rows")
    last = rows[-1]["metrics"]
    for name in require:
        if name in last:
            continue
        if any(k.split("{", 1)[0] == name for k in last):
            continue
        raise ValueError(f"{path}: last row missing required metric "
                         f"{name!r}")
    return {"rows": len(rows), "series": len(last)}


def _main(argv: Sequence[str]) -> int:
    argv = list(argv)
    require: Sequence[str] = ()
    if "--require" in argv:                 # names after the flag, for .jsonl
        i = argv.index("--require")
        argv, require = argv[:i], tuple(argv[i + 1:])
    ok = True
    for path in argv:
        try:
            if path.endswith(".jsonl"):
                info = validate_metrics_jsonl(path, require=require)
            else:
                info = validate_chrome_trace(path)
            print(f"{path}: OK {info}")
        except ValueError as e:
            print(f"FAIL: {e}")
            ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(_main(sys.argv[1:]))
