"""A minimal in-process metrics registry: counters, gauges and
histograms with label sets, Prometheus text exposition and JSONL
snapshots.

This is the single source of truth for serving statistics — the
engine/scheduler/swap counters that used to live as ad-hoc dicts
(``spec_stats``, ``swap.stats``, snapshot-delta tuples in
``ContinuousEngine.run``) are registry series, and the legacy dict/int
attributes are thin read-through views over it.

Design points:

* **Names are Prometheus-style** (``snake_case``, ``_total`` suffix for
  counters); label values are stringified and keyed by a sorted
  ``(key, value)`` tuple so ``counter("x", a=1, b=2)`` and
  ``counter("x", b=2, a=1)`` address the same series.
* **Counters are monotonic.**  ``inc`` rejects negative deltas and
  ``set_to`` (for mirroring an external monotonic source, e.g. the
  prefix cache's own ``stats`` dict) rejects decreases — monotonicity is
  what makes the ``mark()``/``delta()`` per-run accounting sound.
* **``mark()``/``delta()``** replace the engine's old
  snapshot-the-dict-then-subtract bookkeeping: a mark is a frozen copy
  of every counter series; ``delta(mark, name)`` is "how much did this
  counter move since", summed over label sets unless one is given.
* No background threads, no locks: the serving engine is single-threaded
  host code, and a few dict updates per engine step is the entire cost.
"""
from __future__ import annotations

import bisect
import json
from typing import Dict, Iterable, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]

DEFAULT_BUCKETS = (1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0,
                   1000.0, 2500.0, 5000.0, 10000.0)


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _render(name: str, key: LabelKey) -> str:
    if not key:
        return name
    inner = ",".join(f"{k}={v}" for k, v in key)
    return f"{name}{{{inner}}}"


class _Metric:
    """One named metric: a family of series keyed by label set."""

    def __init__(self, name: str, kind: str, help: str = "",
                 buckets: Optional[Tuple[float, ...]] = None):
        self.name = name
        self.kind = kind                     # "counter" | "gauge" | "histogram"
        self.help = help
        self.buckets = tuple(buckets) if buckets else None
        self.series: Dict[LabelKey, object] = {}


class _Handle:
    """A metric bound to one label set — what ``registry.counter(...)``
    returns.  Cheap to construct per call site."""

    __slots__ = ("_metric", "_key")

    def __init__(self, metric: _Metric, key: LabelKey):
        self._metric = metric
        self._key = key

    @property
    def value(self) -> float:
        return float(self._metric.series.get(self._key, 0.0))

    # -- counter ------------------------------------------------------------

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(
                f"counter {self._metric.name} cannot decrease (inc {v})")
        self._metric.series[self._key] = (
            self._metric.series.get(self._key, 0.0) + v)

    def set_to(self, v: float) -> None:
        """Mirror an external monotonic total (e.g. a cache's own
        running counter) into this series.  Rejects decreases."""
        cur = self._metric.series.get(self._key, 0.0)
        if v < cur:
            raise ValueError(
                f"counter {self._metric.name} cannot decrease "
                f"({cur} -> {v})")
        self._metric.series[self._key] = float(v)

    # -- gauge --------------------------------------------------------------

    def set(self, v: float) -> None:
        self._metric.series[self._key] = float(v)

    def set_max(self, v: float) -> None:
        """High-water-mark gauge: keep the maximum of what was set."""
        cur = self._metric.series.get(self._key)
        if cur is None or v > cur:
            self._metric.series[self._key] = float(v)

    # -- histogram ----------------------------------------------------------

    def observe(self, v: float) -> None:
        st = self._metric.series.get(self._key)
        if st is None:
            st = {"count": 0, "sum": 0.0,
                  "buckets": [0] * len(self._metric.buckets)}
            self._metric.series[self._key] = st
        st["count"] += 1
        st["sum"] += float(v)
        i = bisect.bisect_left(self._metric.buckets, v)
        if i < len(self._metric.buckets):
            st["buckets"][i] += 1


class MetricsRegistry:
    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}

    # -- access -------------------------------------------------------------

    def _get(self, name: str, kind: str, help: str = "",
             buckets: Optional[Tuple[float, ...]] = None,
             labels: Dict[str, object] = {}) -> _Handle:
        m = self._metrics.get(name)
        if m is None:
            m = _Metric(name, kind, help, buckets)
            self._metrics[name] = m
        elif m.kind != kind:
            raise TypeError(
                f"metric {name!r} is a {m.kind}, requested as {kind}")
        return _Handle(m, _label_key(labels))

    def counter(self, name: str, help: str = "", **labels) -> _Handle:
        return self._get(name, "counter", help, labels=labels)

    def gauge(self, name: str, help: str = "", **labels) -> _Handle:
        return self._get(name, "gauge", help, labels=labels)

    def histogram(self, name: str, help: str = "",
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS,
                  **labels) -> _Handle:
        return self._get(name, "histogram", help, buckets, labels=labels)

    def get(self, name: str, **labels) -> float:
        """Current value of one counter/gauge series (0.0 if unset).
        Without labels, counters sum across their label sets."""
        m = self._metrics.get(name)
        if m is None:
            return 0.0
        if labels or m.kind == "gauge":
            v = m.series.get(_label_key(labels), 0.0)
            return float(v) if not isinstance(v, dict) else 0.0
        return float(sum(v for v in m.series.values()
                         if not isinstance(v, dict)))

    # -- per-run accounting --------------------------------------------------

    def mark(self) -> Dict[str, Dict[LabelKey, float]]:
        """Freeze every counter series — the baseline for ``delta``."""
        return {name: dict(m.series) for name, m in self._metrics.items()
                if m.kind == "counter"}

    def delta(self, mark: Dict[str, Dict[LabelKey, float]], name: str,
              **labels) -> float:
        """Counter movement since ``mark``: one series when labels are
        given, else summed across the metric's label sets."""
        m = self._metrics.get(name)
        if m is None:
            return 0.0
        base = mark.get(name, {})
        if labels:
            k = _label_key(labels)
            return float(m.series.get(k, 0.0)) - float(base.get(k, 0.0))
        return (sum(m.series.values()) - sum(base.values())) if m.series else 0.0

    # -- export --------------------------------------------------------------

    def snapshot(self) -> Dict[str, object]:
        """Flat JSON-friendly view: ``name{label=value,...} -> number``
        (histograms export ``_count``/``_sum``/``_bucket`` series)."""
        out: Dict[str, object] = {}
        for name, m in sorted(self._metrics.items()):
            for key, v in sorted(m.series.items()):
                if m.kind == "histogram":
                    out[_render(name + "_count", key)] = v["count"]
                    out[_render(name + "_sum", key)] = v["sum"]
                    for le, n in zip(m.buckets, v["buckets"]):
                        out[_render(name + "_bucket",
                                    key + (("le", repr(le)),))] = n
                else:
                    out[_render(name, key)] = v
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition (v0.0.4)."""
        lines = []
        for name, m in sorted(self._metrics.items()):
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            for key, v in sorted(m.series.items()):
                labels = ",".join(f'{k}="{val}"' for k, val in key)
                base = f"{name}{{{labels}}}" if labels else name
                if m.kind == "histogram":
                    cum = 0
                    for le, n in zip(m.buckets, v["buckets"]):
                        cum += n
                        ext = (key + (("le", repr(le)),))
                        bl = ",".join(f'{k}="{val}"' for k, val in ext)
                        lines.append(f"{name}_bucket{{{bl}}} {cum}")
                    inf = key + (("le", "+Inf"),)
                    bl = ",".join(f'{k}="{val}"' for k, val in inf)
                    lines.append(f"{name}_bucket{{{bl}}} {v['count']}")
                    lines.append(f"{base.replace(name, name + '_sum', 1)}"
                                 f" {v['sum']}")
                    lines.append(f"{base.replace(name, name + '_count', 1)}"
                                 f" {v['count']}")
                else:
                    lines.append(f"{base} {v}")
        return "\n".join(lines) + "\n"

    def jsonl_row(self, **extra) -> str:
        """One metrics-snapshot line: ``{"metrics": {...}, **extra}``."""
        row = dict(extra)
        row["metrics"] = self.snapshot()
        return json.dumps(row)


def write_jsonl(path: str, rows: Iterable[str]) -> None:
    with open(path, "w") as fh:
        for r in rows:
            fh.write(r + "\n")
