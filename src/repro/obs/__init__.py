"""End-to-end observability: span tracing, a metrics registry, and the
request-lifecycle bookkeeping that ties them together.

:class:`Observability` is the bundle the serving stack threads around —
one per :class:`~repro.serving.continuous.ContinuousEngine`, shared with
its :class:`~repro.serving.scheduler.Scheduler` and
:class:`~repro.serving.slo.swap.SwapManager` so every component
publishes into the same registry and trace.  The registry is always on
(a few dict updates per engine step); the tracer is opt-in
(``tracing=True`` / ``--trace-out``).

Span taxonomy, metric names and labels: ``docs/observability.md``.
"""
from __future__ import annotations

import json
from typing import Dict, Optional

from repro.obs.registry import MetricsRegistry
from repro.obs.tracer import SpanTracer

__all__ = ["MetricsRegistry", "SpanTracer", "Observability"]


class Observability:
    def __init__(self, *, tracing: bool = False, trace_capacity: int = 65536,
                 metrics: Optional[MetricsRegistry] = None):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = SpanTracer(capacity=trace_capacity, enabled=tracing)
        self._phase: Dict[int, str] = {}          # uid -> open phase span
        self._rows: list = []                     # buffered metrics JSONL rows
        self.metrics_every = 0                    # snapshot every N steps (0=off)

    # -- request lifecycle ---------------------------------------------------
    # One outer async span per request uid (cat="request") with nested
    # phase spans sharing the same id: queued -> prefill -> decode
    # [-> preempted -> prefill/decode ...] -> close.  The helpers keep
    # the open-phase table so callers only report transitions.

    def request_arrived(self, uid: int, *, prompt_len: int,
                        max_new_tokens: int) -> None:
        tr = self.tracer
        if tr.enabled:
            tr.begin("request", uid, "request", prompt_len=prompt_len,
                     max_new_tokens=max_new_tokens)
            tr.begin("request", uid, "queued")
        self._phase[uid] = "queued"

    def request_phase(self, uid: int, phase: str, **args) -> None:
        prev = self._phase.get(uid)
        if prev == phase:
            return
        tr = self.tracer
        if tr.enabled:
            if prev is not None:
                tr.end("request", uid, prev)
            tr.begin("request", uid, phase, **args)
        self._phase[uid] = phase

    def request_finished(self, uid: int) -> None:
        prev = self._phase.pop(uid, None)
        tr = self.tracer
        if tr.enabled:
            if prev is not None:
                tr.end("request", uid, prev)
            tr.end("request", uid, "request")

    # -- metrics JSONL sink --------------------------------------------------

    def metrics_row(self, **extra) -> None:
        """Buffer one registry snapshot as a JSONL row (``step=``,
        ``clock_ms=`` … go into the row head).  Rows are kept as dicts
        and serialized only at write time — snapshots sit on the
        serving hot path, JSON encoding does not need to."""
        row = dict(extra)
        row["metrics"] = self.metrics.snapshot()
        self._rows.append(row)

    def maybe_metrics_row(self, step: int) -> None:
        """Periodic snapshot hook the engine calls once per step."""
        if self.metrics_every and step > 0 and step % self.metrics_every == 0:
            self.metrics_row(step=step)

    def write_metrics_jsonl(self, path: str) -> None:
        """Write the buffered rows plus a final snapshot row."""
        rows = list(self._rows)
        final = {"final": True, "metrics": self.metrics.snapshot()}
        rows.append(final)
        with open(path, "w") as fh:
            for r in rows:
                fh.write(json.dumps(r) + "\n")
