"""Low-overhead span tracer emitting Chrome-trace events.

The tracer is a preallocated ring buffer of event dicts over a
monotonic clock (``time.perf_counter_ns``).  Three event shapes cover
the serving taxonomy (see ``docs/observability.md``):

* **Complete spans** (``ph="X"``) — synchronous work with a duration:
  one per engine step (``engine_step``, args carry the step kind and
  live/padded row split).
* **Async spans** (``ph="b"``/``"e"``, paired by ``(cat, id)``) — the
  request lifecycle: an outer ``request`` span per uid with nested
  phase spans (``queued`` → ``prefill`` → ``decode`` →
  ``preempted`` → …) sharing the same async id, which is exactly how
  Perfetto renders nesting.
* **Instants** (``ph="i"``) — point events: ``preempt``, ``restore``,
  ``recompile``.

When disabled every emit path is a constant-time no-op (one attribute
check); ``span()`` returns a shared null context manager, so
instrumentation can stay in place unconditionally.  The ring buffer
never grows: past ``capacity`` events the oldest are overwritten and
``dropped_events`` counts the loss.

Export: :meth:`write_chrome_trace` writes a Perfetto-loadable
``{"traceEvents": [...]}`` JSON; :meth:`write_jsonl` writes the same
events one per line.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional


class _Span:
    """Context manager for one ``ph="X"`` complete span.  ``args`` is
    mutable until exit — fill in values discovered mid-span."""

    __slots__ = ("_tr", "name", "cat", "args", "_t0")

    def __init__(self, tr: "SpanTracer", name: str, cat: str, args: Dict):
        self._tr = tr
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> None:
        t1 = time.perf_counter_ns()
        self._tr._emit({"name": self.name, "cat": self.cat, "ph": "X",
                        "ts": self._t0 / 1e3, "dur": (t1 - self._t0) / 1e3,
                        "pid": 0, "tid": 0, "args": self.args})


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return None


_NULL = _NullSpan()


class SpanTracer:
    def __init__(self, capacity: int = 65536, enabled: bool = False):
        if capacity < 1:
            raise ValueError("tracer capacity must be >= 1")
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        self._ring: List[Optional[Dict]] = [None] * self.capacity
        self._n = 0                       # total events ever emitted

    # -- emit ---------------------------------------------------------------

    def _emit(self, ev: Dict) -> None:
        self._ring[self._n % self.capacity] = ev
        self._n += 1

    def _ts(self) -> float:
        return time.perf_counter_ns() / 1e3          # microseconds

    def span(self, name: str, cat: str = "engine", **args):
        """``with tracer.span("engine_step", kind="mixed") as sp: ...``
        — ``sp`` is None when tracing is disabled."""
        if not self.enabled:
            return _NULL
        return _Span(self, name, cat, args)

    def begin(self, cat: str, id: object, name: str, **args) -> None:
        """Open an async span (``ph="b"``) under ``(cat, id)``."""
        if not self.enabled:
            return
        self._emit({"name": name, "cat": cat, "ph": "b", "id": str(id),
                    "ts": self._ts(), "pid": 0, "tid": 0, "args": args})

    def end(self, cat: str, id: object, name: str, **args) -> None:
        if not self.enabled:
            return
        self._emit({"name": name, "cat": cat, "ph": "e", "id": str(id),
                    "ts": self._ts(), "pid": 0, "tid": 0, "args": args})

    def instant(self, name: str, cat: str = "engine", **args) -> None:
        if not self.enabled:
            return
        self._emit({"name": name, "cat": cat, "ph": "i", "s": "t",
                    "ts": self._ts(), "pid": 0, "tid": 0, "args": args})

    # -- inspect / export ----------------------------------------------------

    @property
    def dropped_events(self) -> int:
        return max(0, self._n - self.capacity)

    def events(self) -> List[Dict]:
        """Buffered events, oldest first."""
        if self._n <= self.capacity:
            return [e for e in self._ring[:self._n]]
        start = self._n % self.capacity
        return self._ring[start:] + self._ring[:start]

    def chrome_trace(self) -> Dict:
        return {"traceEvents": self.events(),
                "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped_events}}

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.chrome_trace(), fh)

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as fh:
            for ev in self.events():
                fh.write(json.dumps(ev) + "\n")
