"""Mamba2 / SSD blocks (arXiv:2405.21060) — chunked scan for train/prefill
and an O(1) recurrent step for decode.

Follows the `ssd_minimal_discrete` reference: per-head scalar decay
``a = -exp(A_log)``, discretisation ``adt = exp(dt * a)``, state
``h[B,H,P,N]`` (P = head dim, N = d_state), shared B/C across heads
(n_groups = 1 for simplicity; zamba2 uses 1-2 groups).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.nn import ParamSpec, truncated_normal_init, zeros_init, ones_init


class Mamba2State(NamedTuple):
    h: jax.Array     # (B, H, P, N) SSM state
    conv: jax.Array  # (B, W-1, conv_dim) conv tail


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads or max(d_inner // 64, 1)
    P = d_inner // H
    N = cfg.ssm_state
    return d_inner, H, P, N


def mamba2_block_specs(cfg: ModelConfig):
    d = cfg.d_model
    d_inner, H, P, N = _dims(cfg)
    conv_dim = d_inner + 2 * N  # x, B, C go through the conv
    init = truncated_normal_init(cfg.initializer_range)
    wdt = jnp.dtype(cfg.param_dtype)
    return {
        "ln": L.norm_specs(cfg),
        # separate projections (not one fused in_proj) so each output dim
        # shards cleanly over the model axis (2*d_inner+2N+H rarely divides)
        "in_z": ParamSpec((d, d_inner), wdt, ("embed", "ssm_inner"), init),
        "in_x": ParamSpec((d, d_inner), wdt, ("embed", "ssm_inner"), init),
        "in_B": ParamSpec((d, N), wdt, ("embed", None), init),
        "in_C": ParamSpec((d, N), wdt, ("embed", None), init),
        "in_dt": ParamSpec((d, H), wdt, ("embed", None), init),
        "conv_w": ParamSpec((cfg.ssm_conv_width, conv_dim), wdt, (None, "ssm_inner"), init),
        "conv_b": ParamSpec((conv_dim,), jnp.float32, ("ssm_inner",), zeros_init),
        "A_log": ParamSpec((H,), jnp.float32, (None,), zeros_init),
        "dt_bias": ParamSpec((H,), jnp.float32, (None,), zeros_init),
        "D": ParamSpec((H,), jnp.float32, (None,), ones_init),
        "head_norm": ParamSpec((d_inner,), jnp.float32, ("ssm_inner",), ones_init),
        "out_proj": ParamSpec((d_inner, d), wdt, ("ssm_inner", "embed"), init),
    }


def _segsum(logd):
    """logd: (..., W). Returns (..., W, W) lower-tri cumulative sums:
    out[t, s] = sum_{s < r <= t} logd_r, -inf above diagonal."""
    W = logd.shape[-1]
    cs = jnp.cumsum(logd, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((W, W), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A_log, Bmat, Cmat, chunk: int):
    """Chunked SSD scan.

    x: (B,S,H,P) f32; dt: (B,S,H) (post-softplus); Bmat/Cmat: (B,S,N).
    Returns y: (B,S,H,P) and final state (B,H,P,N).
    """
    Bsz, S, H, P = x.shape
    N = Bmat.shape[-1]
    a = -jnp.exp(A_log)                        # (H,)
    W = min(chunk, S)
    pad = (W - S % W) % W
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
    NC = x.shape[1] // W
    xc = x.reshape(Bsz, NC, W, H, P).transpose(1, 0, 3, 2, 4)      # (NC,B,H,W,P)
    dtc = dt.reshape(Bsz, NC, W, H).transpose(1, 0, 3, 2)          # (NC,B,H,W)
    Bc = Bmat.reshape(Bsz, NC, W, N).transpose(1, 0, 2, 3)         # (NC,B,W,N)
    Cc = Cmat.reshape(Bsz, NC, W, N).transpose(1, 0, 2, 3)

    def body(h, xs):
        xb, dtb, Bb, Cb = xs                                       # per chunk
        logd = dtb * a[None, :, None]                              # (B,H,W)
        Lmat = jnp.exp(_segsum(logd))                              # (B,H,W,W)
        CB = jnp.einsum("bsn,btn->bst", Cb, Bb)                    # (B,W,W)
        scores = CB[:, None] * Lmat                                # (B,H,W,W)
        causal = jnp.tril(jnp.ones((W, W), bool))
        scores = jnp.where(causal, scores, 0.0)
        xdt = xb * dtb[..., None]                                  # (B,H,W,P)
        y_diag = jnp.einsum("bhst,bhtp->bhsp", scores, xdt)
        # inter-chunk: contribution of incoming state
        cum = jnp.cumsum(logd, axis=-1)                            # (B,H,W)
        y_off = jnp.einsum("bsn,bhpn->bhsp", Cb, h) * jnp.exp(cum)[..., None]
        # state update
        decay_to_end = jnp.exp(cum[..., -1:] - cum)                # (B,H,W)
        h_new = jnp.exp(cum[..., -1])[..., None, None] * h + jnp.einsum(
            "bhs,bhsp,bsn->bhpn", decay_to_end * dtb, xb, Bb)
        return h_new, y_diag + y_off

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    # checkpoint the chunk body (same rationale as xlstm's chunk scan)
    h_final, yc = jax.lax.scan(jax.checkpoint(body, prevent_cse=False),
                               h0, (xc, dtc, Bc, Cc))
    y = yc.transpose(1, 0, 3, 2, 4).reshape(Bsz, NC * W, H, P)
    return y[:, :S], h_final


def ssd_step(h, x, dt, A_log, Bvec, Cvec):
    """Single-token recurrence. h: (B,H,P,N); x: (B,H,P); dt: (B,H);
    Bvec/Cvec: (B,N). Returns (y (B,H,P), h_new)."""
    a = -jnp.exp(A_log)
    adt = jnp.exp(dt * a[None, :])                                # (B,H)
    h_new = adt[..., None, None] * h + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, x, Bvec)
    y = jnp.einsum("bhpn,bn->bhp", h_new, Cvec)
    return y, h_new


def mamba2_block_apply(params, x, cfg: ModelConfig, *,
                       state: Optional[Mamba2State] = None):
    """Returns (y, new_state)."""
    Bsz, S, d = x.shape
    d_inner, H, P, N = _dims(cfg)
    dt_act = x.dtype
    h = L.norm_apply(params["ln"], x, cfg)
    z = h @ params["in_z"].astype(dt_act)
    xs = h @ params["in_x"].astype(dt_act)
    Bm = h @ params["in_B"].astype(dt_act)
    Cm = h @ params["in_C"].astype(dt_act)
    dtm = h @ params["in_dt"].astype(dt_act)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    if state is not None:
        from repro.models.xlstm import _causal_conv
        conv_out, new_tail = _causal_conv(conv_in, params["conv_w"].astype(dt_act), state.conv)
    else:
        from repro.models.xlstm import _causal_conv
        conv_out, new_tail = _causal_conv(conv_in, params["conv_w"].astype(dt_act))
    conv_out = jax.nn.silu(conv_out + params["conv_b"].astype(dt_act))
    xs, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    dt32 = jax.nn.softplus(dtm.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)

    x4 = xs.reshape(Bsz, S, H, P).astype(jnp.float32)
    if state is None:
        y, h_final = ssd_chunked(x4, dt32, params["A_log"],
                                 Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                                 chunk=max(cfg.ssm_chunk, 16))
        new_state = None
    else:
        y1, h_new = ssd_step(state.h, x4[:, 0], dt32[:, 0], params["A_log"],
                             Bm[:, 0].astype(jnp.float32), Cm[:, 0].astype(jnp.float32))
        y = y1[:, None]
        new_state = Mamba2State(h_new, new_tail.astype(state.conv.dtype))

    y = y + x4 * params["D"][None, None, :, None]
    y = L.head_rmsnorm_apply(params["head_norm"].reshape(H, P), y, cfg.norm_eps)
    y = y.reshape(Bsz, S, d_inner).astype(dt_act)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(dt_act)
    return x + out, new_state


def mamba2_init_state(cfg: ModelConfig, batch: int, abstract: bool = False):
    d_inner, H, P, N = _dims(cfg)
    conv_dim = d_inner + 2 * N
    shapes = [(batch, H, P, N), (batch, cfg.ssm_conv_width - 1, conv_dim)]
    if abstract:
        return Mamba2State(*[jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes])
    return Mamba2State(*[jnp.zeros(s, jnp.float32) for s in shapes])
