"""Zamba2-style hybrid (arXiv:2411.15242): a Mamba2 backbone with a single
*shared* transformer block (attention + MLP, weights reused) applied every
``cfg.zamba_shared_period`` layers on ``concat(x, x0)`` (x0 = the original
embeddings), projected back to d_model and added to the residual stream.

Simplifications noted in DESIGN.md: per-application LoRA adapters on the
shared block are omitted; n_groups=1 for SSD B/C.
"""
from __future__ import annotations

import math
from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.metrics import empty_aux
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.attention import (
    KVCache,
    abstract_cache,
    attention_apply,
    attention_specs,
    init_cache,
)
from repro.models.mamba2 import (
    Mamba2State,
    mamba2_block_apply,
    mamba2_block_specs,
    mamba2_init_state,
)
from repro.nn import ParamSpec, truncated_normal_init
from repro.nn.spec import stack_specs


class ZambaState(NamedTuple):
    mamba: Mamba2State          # stacked (L, ...) per-layer states
    attn: KVCache               # stacked (n_shared, ...) KV caches


def _shared_cfg(cfg: ModelConfig) -> ModelConfig:
    return cfg.replace(d_model=2 * cfg.d_model, head_dim=2 * cfg.d_model // cfg.num_heads,
                       ffn_activation="gelu")


def _n_shared(cfg: ModelConfig) -> int:
    return math.ceil(cfg.num_layers / cfg.zamba_shared_period)


def zamba_specs(cfg: ModelConfig):
    scfg = _shared_cfg(cfg)
    init = truncated_normal_init(cfg.initializer_range)
    wdt = jnp.dtype(cfg.param_dtype)
    return {
        "embed": L.embedding_specs(cfg),
        "mamba": stack_specs(mamba2_block_specs(cfg), cfg.num_layers),
        "shared": {
            "ln_attn": L.norm_specs(scfg),
            "attn": attention_specs(scfg),
            "ln_ffn": L.norm_specs(scfg),
            "ffn": L.ffn_specs(scfg),
            "out": ParamSpec((2 * cfg.d_model, cfg.d_model), wdt, (None, "embed"), init),
        },
        "final_norm": L.norm_specs(cfg),
    }


def _shared_block(params, x, x0, cfg: ModelConfig, *, positions,
                  cache: Optional[KVCache] = None):
    scfg = _shared_cfg(cfg)
    dt = x.dtype
    y = jnp.concatenate([x, x0], axis=-1)
    h = L.norm_apply(params["ln_attn"], y, scfg)
    attn, new_cache = attention_apply(params["attn"], h, scfg,
                                      positions=positions, cache=cache)
    y = y + attn
    h = L.norm_apply(params["ln_ffn"], y, scfg)
    y = y + L.ffn_apply(params["ffn"], h, scfg)
    return x + y @ params["out"].astype(dt), new_cache


def _segments(cfg: ModelConfig) -> List[tuple]:
    p = cfg.zamba_shared_period
    segs = []
    for start in range(0, cfg.num_layers, p):
        segs.append((start, min(start + p, cfg.num_layers)))
    return segs


def zamba_apply(params, tokens, cfg: ModelConfig, *,
                state: Optional[ZambaState] = None):
    """Returns (logits, aux, new_state)."""
    decode = state is not None
    x = L.embedding_apply(params["embed"], tokens, cfg)
    x = shard(x, "batch", "seq", "embed")
    x0 = x
    B, S, _ = x.shape
    if decode:
        length = state.attn.length[0]
        positions = jnp.broadcast_to(length + jnp.arange(S)[None, :], (B, S))
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    new_mamba_states = []
    new_attn_caches = []

    def mamba_scan_body(h, bp):
        h, _ = mamba2_block_apply(bp, h, cfg)
        return h, None

    body = mamba_scan_body
    if cfg.remat and not decode:
        body = jax.checkpoint(body, prevent_cse=False)

    shared_fn = _shared_block
    if cfg.remat and not decode:
        shared_fn = jax.checkpoint(
            lambda sp, a, b: _shared_block(sp, a, b, cfg, positions=positions)[0],
            prevent_cse=False)

    p = cfg.zamba_shared_period
    n_full = cfg.num_layers // p
    rem = cfg.num_layers % p

    if not decode and cfg.scan_layers and n_full > 1:
        # Scan over (shared block + p mamba layers) segments: one loop
        # body instead of n_full unrolled shared applications — XLA reuses
        # the segment's backward buffers across iterations (-10GB/dev on
        # zamba2-7b train_4k; see EXPERIMENTS.md S Perf).
        full = jax.tree_util.tree_map(
            lambda a: a[: n_full * p].reshape((n_full, p) + a.shape[1:]),
            params["mamba"])

        def seg_body(h, seg_params):
            h = shared_fn(params["shared"], h, x0)
            h, _ = jax.lax.scan(body, h, seg_params)
            return h, None

        x, _ = jax.lax.scan(seg_body, x, full)
        if rem:
            x = shared_fn(params["shared"], x, x0)
            tail = jax.tree_util.tree_map(lambda a: a[n_full * p:], params["mamba"])
            x, _ = jax.lax.scan(body, x, tail)
        x = shard(x, "batch", "seq", "embed")
        x = L.norm_apply(params["final_norm"], x, cfg)
        logits = L.unembed_apply(params["embed"], x, cfg)
        return logits, empty_aux(), None

    for si, (start, stop) in enumerate(_segments(cfg)):
        cache = jax.tree_util.tree_map(lambda a: a[si], state.attn) if decode else None
        if cfg.remat and not decode:
            x, new_cache = shared_fn(params["shared"], x, x0), None
        else:
            x, new_cache = _shared_block(params["shared"], x, x0, cfg,
                                         positions=positions, cache=cache)
        if decode:
            new_attn_caches.append(new_cache)
        seg_params = jax.tree_util.tree_map(lambda a: a[start:stop], params["mamba"])
        if decode:
            for li in range(stop - start):
                bp = jax.tree_util.tree_map(lambda a: a[li], seg_params)
                st = jax.tree_util.tree_map(lambda a: a[start + li], state.mamba)
                x, ns = mamba2_block_apply(bp, x, cfg, state=st)
                new_mamba_states.append(ns)
        elif cfg.scan_layers:
            x, _ = jax.lax.scan(body, x, seg_params)
        else:  # probe mode: unrolled so cost_analysis counts every layer
            for li in range(stop - start):
                bp = jax.tree_util.tree_map(lambda a: a[li], seg_params)
                x, _ = body(x, bp)[0], None
        x = shard(x, "batch", "seq", "embed")

    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = L.unembed_apply(params["embed"], x, cfg)
    new_state = None
    if decode:
        new_state = ZambaState(
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_mamba_states),
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_attn_caches),
        )
    return logits, empty_aux(), new_state


def zamba_init_state(cfg: ModelConfig, batch: int, max_len: int,
                     abstract: bool = False) -> ZambaState:
    scfg = _shared_cfg(cfg)
    n = _n_shared(cfg)
    one_m = mamba2_init_state(cfg, batch, abstract)
    one_c = (abstract_cache if abstract else init_cache)(scfg, batch, max_len)
    if abstract:
        stack = lambda s, k: jax.ShapeDtypeStruct((k,) + s.shape, s.dtype)
    else:
        stack = lambda a, k: jnp.broadcast_to(a[None], (k,) + a.shape).copy()
    mamba = jax.tree_util.tree_map(lambda a: stack(a, cfg.num_layers), one_m)
    attn = jax.tree_util.tree_map(lambda a: stack(a, n), one_c)
    return ZambaState(mamba, attn)
