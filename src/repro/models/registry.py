"""Uniform per-family model API used by the trainer, server and dry-run.

Every family exposes:
  specs(cfg)                                   parameter ParamSpec tree
  forward(params, batch, cfg, ctx)             training forward; logits
                                               align with batch["labels"]
  init_state(cfg, batch, max_len, abstract)    decode-state template
  decode(params, tokens, state, cfg, ctx)      one-token serve step
  prefill(params, batch, cfg, max_len, ctx)    prompt -> (logits, state)

``ctx`` is an optional :class:`repro.core.context.MoEContext` built by
the caller (trainer / serving engine); families fill in token ids and
positions and thread it to their MoE layers.  Families without MoE
layers (xlstm / zamba) accept and ignore it.
  input_specs(cfg, shape)                      ShapeDtypeStruct batch for a
                                               ShapeConfig cell (dry-run)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as ED
from repro.models import transformer as TF
from repro.models import xlstm as XL
from repro.models import zamba as ZB


@dataclasses.dataclass(frozen=True)
class FamilyAPI:
    specs: Callable
    forward: Callable
    init_state: Callable
    decode: Callable
    prefill: Optional[Callable]
    input_specs: Callable
    decode_input_specs: Callable


def _tok_struct(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


# ---------------------------------------------------------------------------
# decoder_lm (also base for vlm / m6 which add prefix embeddings)
# ---------------------------------------------------------------------------

def _lm_forward(params, batch, cfg: ModelConfig, ctx=None):
    extra = batch.get("patch_embeds")
    logits, aux = TF.lm_apply(params, batch["tokens"], cfg, extra_embeds=extra,
                              ctx=ctx)
    if extra is not None:
        logits = logits[:, extra.shape[1]:]
    return logits, aux


def _lm_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    text = s - cfg.num_image_tokens
    specs = {"tokens": _tok_struct(b, text), "labels": _tok_struct(b, text)}
    if cfg.num_image_tokens:
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_image_tokens, cfg.d_model), cfg.activation_dtype)
    return specs


def _lm_init_state(cfg, batch, max_len, abstract=False):
    return TF.init_caches(cfg, batch, max_len, abstract=abstract)


def _lm_decode(params, tokens, state, cfg, ctx=None):
    return TF.decode_apply(params, tokens, state, cfg, ctx=ctx)


def _lm_prefill(params, batch, cfg, max_len, ctx=None):
    logits, caches, _ = TF.prefill_apply(params, batch["tokens"], cfg,
                                         max_len=max_len, ctx=ctx)
    return logits, caches


def _lm_decode_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    b = shape.global_batch
    state = TF.init_caches(cfg, b, shape.seq_len, abstract=True)
    return {"tokens": _tok_struct(b, 1), "state": state}


DECODER_LM = FamilyAPI(
    specs=TF.lm_specs,
    forward=_lm_forward,
    init_state=_lm_init_state,
    decode=_lm_decode,
    prefill=_lm_prefill,
    input_specs=_lm_input_specs,
    decode_input_specs=_lm_decode_input_specs,
)


# ---------------------------------------------------------------------------
# xlstm
# ---------------------------------------------------------------------------

def _xl_forward(params, batch, cfg, ctx=None):
    del ctx  # no MoE layers in the xlstm family
    logits, aux, _ = XL.xlstm_apply(params, batch["tokens"], cfg)
    return logits, aux


def _xl_init_state(cfg, batch, max_len, abstract=False):
    del max_len  # recurrent: O(1) state
    return XL.xlstm_init_states(cfg, batch, abstract)


def _xl_decode(params, tokens, state, cfg, ctx=None):
    del ctx
    logits, _, new_state = XL.xlstm_apply(params, tokens, cfg, states=state)
    return logits, new_state


def _xl_decode_input_specs(cfg, shape: ShapeConfig):
    b = shape.global_batch
    return {"tokens": _tok_struct(b, 1),
            "state": XL.xlstm_init_states(cfg, b, abstract=True)}


XLSTM = FamilyAPI(
    specs=XL.xlstm_specs,
    forward=_xl_forward,
    init_state=_xl_init_state,
    decode=_xl_decode,
    prefill=None,
    input_specs=lambda cfg, shape: {
        "tokens": _tok_struct(shape.global_batch, shape.seq_len),
        "labels": _tok_struct(shape.global_batch, shape.seq_len),
    },
    decode_input_specs=_xl_decode_input_specs,
)


# ---------------------------------------------------------------------------
# zamba (hybrid)
# ---------------------------------------------------------------------------

def _zb_forward(params, batch, cfg, ctx=None):
    del ctx  # no MoE layers in the zamba family
    logits, aux, _ = ZB.zamba_apply(params, batch["tokens"], cfg)
    return logits, aux


def _zb_init_state(cfg, batch, max_len, abstract=False):
    return ZB.zamba_init_state(cfg, batch, max_len, abstract)


def _zb_decode(params, tokens, state, cfg, ctx=None):
    del ctx
    logits, _, new_state = ZB.zamba_apply(params, tokens, cfg, state=state)
    return logits, new_state


def _zb_decode_input_specs(cfg, shape: ShapeConfig):
    b = shape.global_batch
    return {"tokens": _tok_struct(b, 1),
            "state": ZB.zamba_init_state(cfg, b, shape.seq_len, abstract=True)}


ZAMBA = FamilyAPI(
    specs=ZB.zamba_specs,
    forward=_zb_forward,
    init_state=_zb_init_state,
    decode=_zb_decode,
    prefill=None,
    input_specs=lambda cfg, shape: {
        "tokens": _tok_struct(shape.global_batch, shape.seq_len),
        "labels": _tok_struct(shape.global_batch, shape.seq_len),
    },
    decode_input_specs=_zb_decode_input_specs,
)


# ---------------------------------------------------------------------------
# encdec (seamless) — frames are stub frontend embeddings
# ---------------------------------------------------------------------------

def _ed_forward(params, batch, cfg, ctx=None):
    return ED.encdec_train_apply(params, batch["frames"], batch["tokens"], cfg,
                                 ctx=ctx)


def _ed_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    return {
        "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.activation_dtype),
        "tokens": _tok_struct(b, s),
        "labels": _tok_struct(b, s),
    }


def _ed_init_state(cfg, batch, max_len, abstract=False):
    assert abstract, "use encdec.init_state with real memory for concrete states"
    return ED.abstract_state(cfg, batch, max_len, max_len)


def _ed_decode(params, tokens, state, cfg, ctx=None):
    return ED.decode_step(params, tokens, state, cfg, ctx=ctx)


def _ed_decode_input_specs(cfg, shape: ShapeConfig):
    b = shape.global_batch
    return {"tokens": _tok_struct(b, 1),
            "state": ED.abstract_state(cfg, b, shape.seq_len, shape.seq_len)}


ENCDEC = FamilyAPI(
    specs=ED.encdec_specs,
    forward=_ed_forward,
    init_state=_ed_init_state,
    decode=_ed_decode,
    prefill=None,
    input_specs=_ed_input_specs,
    decode_input_specs=_ed_decode_input_specs,
)


FAMILIES = {
    "decoder_lm": DECODER_LM,
    "vlm": DECODER_LM,    # VLM/M6 = decoder LM + patch_embeds stub prefix
    "m6": DECODER_LM,
    "xlstm": XLSTM,
    "zamba": ZAMBA,
    "encdec": ENCDEC,
}


def get_family(cfg: ModelConfig) -> FamilyAPI:
    return FAMILIES[cfg.family]
