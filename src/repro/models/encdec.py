"""Encoder-decoder transformer (seamless-m4t backbone).

The speech/audio frontend is a STUB per the assignment: ``encode`` takes
precomputed frame embeddings (B, S_src, d_model).  The decoder is a
standard causal transformer with cross-attention; decode uses a KV cache
for self-attention plus precomputed cross-attention K/V.

When ``cfg.moe.num_experts > 0`` every *decoder* FFN is a MoE layer
(the encoder stays dense — its inputs are frontend frames, not tokens),
with the :class:`~repro.core.context.MoEContext` threaded through so
routing sees target-token identity and absolute decode positions.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.context import MoEContext
from repro.core.metrics import empty_aux
from repro.core.moe import moe_ffn_apply, moe_ffn_specs
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.attention import (
    KVCache,
    abstract_cache,
    attention_apply,
    attention_specs,
    init_cache,
    project_kv,
)
from repro.nn.spec import stack_specs


class EncDecState(NamedTuple):
    self_cache: KVCache     # stacked (L_dec, ...)
    cross_k: jax.Array      # (L_dec, B, S_src, H_kv, D)
    cross_v: jax.Array


def enc_block_specs(cfg: ModelConfig):
    return {
        "ln_attn": L.norm_specs(cfg),
        "attn": attention_specs(cfg),
        "ln_ffn": L.norm_specs(cfg),
        "ffn": L.ffn_specs(cfg),
    }


def dec_block_specs(cfg: ModelConfig):
    moe = cfg.moe.num_experts > 0
    return {
        "ln_self": L.norm_specs(cfg),
        "self_attn": attention_specs(cfg),
        "ln_cross": L.norm_specs(cfg),
        "cross_attn": attention_specs(cfg),
        "ln_ffn": L.norm_specs(cfg),
        # Decoder layers are uniform (stacked/scanned), so MoE applies to
        # every decoder FFN when experts are configured.
        "ffn": moe_ffn_specs(cfg) if moe else L.ffn_specs(cfg),
    }


def encdec_specs(cfg: ModelConfig):
    n_enc = cfg.num_encoder_layers or cfg.num_layers
    return {
        "embed": L.embedding_specs(cfg),
        "encoder": stack_specs(enc_block_specs(cfg), n_enc),
        "enc_norm": L.norm_specs(cfg),
        "decoder": stack_specs(dec_block_specs(cfg), cfg.num_layers),
        "final_norm": L.norm_specs(cfg),
    }


def encode(params, frames, cfg: ModelConfig):
    """frames: (B, S_src, d_model) precomputed frontend embeddings."""
    B, S, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = frames.astype(cfg.activation_dtype)
    x = shard(x, "batch", "seq", "embed")

    def body(h, bp):
        a = L.norm_apply(bp["ln_attn"], h, cfg)
        attn, _ = attention_apply(bp["attn"], a, cfg, positions=positions, causal=False)
        h = h + attn
        f = L.norm_apply(bp["ln_ffn"], h, cfg)
        h = h + L.ffn_apply(bp["ffn"], f, cfg)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x = _scan_or_unroll(body, x, params["encoder"], cfg)
    return L.norm_apply(params["enc_norm"], x, cfg)


def _scan_or_unroll(body, x, stacked, cfg):
    """lax.scan normally; python-unrolled when cfg.scan_layers=False
    (probe mode: makes cost_analysis count every layer)."""
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, stacked)
        return x
    n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    for i in range(n):
        bp = jax.tree_util.tree_map(lambda a: a[i], stacked)
        x, _ = body(x, bp)
    return x


def _dec_block(bp, h, memory_kv, cfg, *, positions, cache=None,
               ctx: Optional[MoEContext] = None):
    a = L.norm_apply(bp["ln_self"], h, cfg)
    attn, new_cache = attention_apply(bp["self_attn"], a, cfg,
                                      positions=positions, cache=cache)
    h = h + attn
    c = L.norm_apply(bp["ln_cross"], h, cfg)
    cross, _ = attention_apply(bp["cross_attn"], c, cfg, positions=positions,
                               kv=memory_kv)
    h = h + cross
    f = L.norm_apply(bp["ln_ffn"], h, cfg)
    if cfg.moe.num_experts > 0:
        ffn, aux = moe_ffn_apply(bp["ffn"], f, cfg, ctx=ctx)
    else:
        ffn, aux = (L.ffn_apply(bp["ffn"], f, cfg),
                    empty_aux(cfg.moe.num_experts))
    h = h + ffn
    return h, aux, new_cache


def _sum_layer_aux(aux):
    """Stacked per-layer aux -> totals for _loss keys (scan ys layout)."""
    out = dict(aux)
    for k in list(out):
        if k.endswith("_loss"):
            out[k] = jnp.sum(out[k])
    return out


def decode_train(params, tokens, memory, cfg: ModelConfig,
                 ctx: Optional[MoEContext] = None):
    """Teacher-forcing decoder forward. memory: encoder output.
    Returns (logits, aux)."""
    x = L.embedding_apply(params["embed"], tokens, cfg)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    ctx = (ctx or MoEContext()).with_tokens(tokens, positions)
    x = shard(x, "batch", "seq", "embed")

    def body(h, bp):
        mem_kv = project_kv(bp["cross_attn"], memory, cfg)
        h, aux, _ = _dec_block(bp, h, mem_kv, cfg, positions=positions, ctx=ctx)
        return h, aux

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, aux = _scan_or_unroll_aux(body, x, params["decoder"], cfg)
    x = L.norm_apply(params["final_norm"], x, cfg)
    return L.unembed_apply(params["embed"], x, cfg), _sum_layer_aux(aux)


def _scan_or_unroll_aux(body, x, stacked, cfg):
    """Like :func:`_scan_or_unroll` but collects per-layer aux dicts."""
    if cfg.scan_layers:
        return jax.lax.scan(body, x, stacked)
    n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    auxes = []
    for i in range(n):
        bp = jax.tree_util.tree_map(lambda a: a[i], stacked)
        x, aux = body(x, bp)
        auxes.append(aux)
    aux = {k: jnp.stack([a[k] for a in auxes]) for k in auxes[0]}
    return x, aux


def encdec_train_apply(params, frames, tokens, cfg: ModelConfig,
                       ctx: Optional[MoEContext] = None):
    memory = encode(params, frames, cfg)
    logits, aux = decode_train(params, tokens, memory, cfg, ctx=ctx)
    return logits, aux


def init_state(params, memory, cfg: ModelConfig, max_len: int) -> EncDecState:
    """Precompute cross K/V for all decoder layers + empty self caches."""

    def body(_, bp):
        k, v = project_kv(bp["cross_attn"], memory, cfg)
        return None, (k, v)

    _, (ck, cv) = jax.lax.scan(body, None, params["decoder"])
    B = memory.shape[0]
    one = init_cache(cfg, B, max_len)
    caches = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape).copy(), one)
    return EncDecState(caches, ck, cv)


def abstract_state(cfg: ModelConfig, batch: int, src_len: int, max_len: int) -> EncDecState:
    hd = cfg.resolved_head_dim
    one = abstract_cache(cfg, batch, max_len)
    caches = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((cfg.num_layers,) + s.shape, s.dtype), one)
    kv = jax.ShapeDtypeStruct(
        (cfg.num_layers, batch, src_len, cfg.num_kv_heads, hd), cfg.activation_dtype)
    return EncDecState(caches, kv, kv)


def decode_step(params, tokens, state: EncDecState, cfg: ModelConfig,
                ctx: Optional[MoEContext] = None):
    """tokens: (B, 1). Returns (logits, new_state).

    As in the decoder-LM family, the MoE context carries the absolute
    decode positions and current token ids so MoE routing matches
    teacher-forcing behaviour."""
    x = L.embedding_apply(params["embed"], tokens, cfg)
    B, S, _ = x.shape
    length = state.self_cache.length[0]
    positions = jnp.broadcast_to(length + jnp.arange(S)[None, :], (B, S))
    ctx = (ctx or MoEContext()).with_tokens(tokens, positions)

    def body(h, scanned):
        bp, cache, ck, cv = scanned
        h, _, new_cache = _dec_block(bp, h, (ck, cv), cfg, positions=positions,
                                     cache=cache, ctx=ctx)
        return h, new_cache

    x, new_caches = jax.lax.scan(
        body, x, (params["decoder"], state.self_cache, state.cross_k, state.cross_v))
    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = L.unembed_apply(params["embed"], x, cfg)
    return logits, EncDecState(new_caches, state.cross_k, state.cross_v)
