"""Multi-head attention with GQA, qk-norm, RoPE and a KV cache.

Reference (pure jnp) path used everywhere; the Pallas flash kernel in
``repro.kernels.flash_attention`` is an optional drop-in for the causal
full-sequence case (``use_flash=True``); numerics are tested against this
reference.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn import ParamSpec, ones_init
from repro.configs.base import ModelConfig
from repro.models.layers import (
    apply_rope,
    dense_specs,
    dense_apply,
    head_rmsnorm_apply,
    rope,
)


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_max, H_kv, D)
    v: jax.Array  # (B, S_max, H_kv, D)
    length: jax.Array  # scalar int32: number of valid positions


def attention_specs(cfg: ModelConfig, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    specs = {
        "wq": dense_specs(cfg, d, cfg.num_heads * hd, ("embed", "heads"), bias=cfg.qkv_bias),
        "wk": dense_specs(cfg, d, cfg.num_kv_heads * hd, ("embed", "kv_heads"), bias=cfg.qkv_bias),
        "wv": dense_specs(cfg, d, cfg.num_kv_heads * hd, ("embed", "kv_heads"), bias=cfg.qkv_bias),
        "wo": dense_specs(cfg, cfg.num_heads * hd, d, ("heads", "embed")),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), jnp.float32, (None,), ones_init)
        specs["k_norm"] = ParamSpec((hd,), jnp.float32, (None,), ones_init)
    return specs


def _project_qkv(params, x, cfg: ModelConfig, positions):
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    q = dense_apply(params["wq"], x, cfg).reshape(B, -1, cfg.num_heads, hd)
    k = dense_apply(params["wk"], x, cfg).reshape(B, -1, cfg.num_kv_heads, hd)
    v = dense_apply(params["wv"], x, cfg).reshape(B, -1, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = head_rmsnorm_apply(params["q_norm"], q, cfg.norm_eps)
        k = head_rmsnorm_apply(params["k_norm"], k, cfg.norm_eps)
    if cfg.pos_embed == "rope":
        sin, cos = rope(positions, hd, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    return q, k, v


def _sdpa_reference(q, k, v, cfg: ModelConfig, mask) -> jax.Array:
    """Materialised-scores attention. q:(B,S,Hq,D) k/v:(B,T,Hkv,D)."""
    B, S, Hq, D = q.shape
    T = k.shape[1]
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    scale = D ** -0.5
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * scale
    if cfg.attn_logit_softcap > 0:
        c = cfg.attn_logit_softcap
        scores = jnp.tanh(scores / c) * c
    if mask is not None:
        scores = jnp.where(mask, scores, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, Hq, D).astype(q.dtype)


_CHUNK_THRESHOLD = 1 << 21  # S*T above this -> chunked path under "auto"


def _sdpa(q, k, v, cfg: ModelConfig, mask, *, causal_offset=None) -> jax.Array:
    """Grouped SDPA with automatic chunked (flash-semantics) dispatch.

    ``mask`` is only honoured by the reference path; the chunked path
    handles causal masking itself via ``causal_offset`` (None => full
    bidirectional, array/int => causal with query offset).
    """
    B, S, Hq, D = q.shape
    T = k.shape[1]
    Hkv = k.shape[2]
    impl = cfg.attention_impl
    if impl == "auto":
        impl = "chunked" if (S * T > _CHUNK_THRESHOLD) else "reference"
    if impl != "chunked":
        return _sdpa_reference(q, k, v, cfg, mask)
    from repro.models.chunked_attention import chunked_attention

    qg = q.reshape(B, S, Hkv, Hq // Hkv, D)
    causal = causal_offset is not None
    static_off = causal_offset if isinstance(causal_offset, int) else None
    dyn_off = None if isinstance(causal_offset, (int, type(None))) else causal_offset
    out = chunked_attention(qg, k, v, causal, static_off, cfg.attention_block,
                            cfg.attn_logit_softcap, q_offset=dyn_off)
    return out.reshape(B, S, Hq, D)


def causal_mask(S: int, T: int, offset: int = 0):
    """mask[s, t] = t <= s + offset, broadcast to (1,1,1,S,T)."""
    rows = jnp.arange(S)[:, None] + offset
    cols = jnp.arange(T)[None, :]
    return (cols <= rows)[None, None, None, :, :]


def attention_apply(
    params,
    x,
    cfg: ModelConfig,
    *,
    positions,
    causal: bool = True,
    cache: Optional[KVCache] = None,
    use_flash: bool = False,
    kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    kv_positions=None,
) -> Tuple[jax.Array, Optional[KVCache]]:
    """Returns (output, updated_cache).

    * train/prefill: ``cache is None`` -> full self-attention over x.
      If a cache template is wanted, call ``init_cache`` + prefill path in
      the serving engine instead.
    * decode: ``cache`` holds K/V for past positions; x is (B, 1, d).
    * cross-attention: pass precomputed ``kv=(k, v)`` (already headed).
    """
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim

    if kv is not None:  # cross-attention: queries from x, fixed memory kv
        q = dense_apply(params["wq"], x, cfg).reshape(B, S, cfg.num_heads, hd)
        if cfg.qk_norm:
            q = head_rmsnorm_apply(params["q_norm"], q, cfg.norm_eps)
        k, v = kv
        out = _sdpa(q, k, v, cfg, mask=None)
        return dense_apply(params["wo"], out.reshape(B, S, -1), cfg), None

    q, k, v = _project_qkv(params, x, cfg, positions)

    if cache is not None:
        # Decode (or chunked prefill): append k/v at cache.length.
        idx = cache.length
        new_k = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), idx, axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), idx, axis=1)
        new_cache = KVCache(new_k, new_v, cache.length + S)
        T = cache.k.shape[1]
        valid = jnp.arange(T)[None, :] <= (idx + jnp.arange(S)[:, None])
        mask = valid[None, None, None, :, :]
        out = _sdpa(q, new_k, new_v, cfg, mask, causal_offset=idx)
    else:
        new_cache = None
        if use_flash:
            from repro.kernels.flash_attention import ops as fa_ops

            out = fa_ops.flash_attention(q, k, v, causal=causal)
        else:
            mask = causal_mask(S, S) if causal else None
            out = _sdpa(q, k, v, cfg, mask, causal_offset=0 if causal else None)

    y = dense_apply(params["wo"], out.reshape(B, S, -1), cfg)
    return y, new_cache


def project_kv(params, memory, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder memory."""
    B, T, _ = memory.shape
    hd = cfg.resolved_head_dim
    k = dense_apply(params["wk"], memory, cfg).reshape(B, T, cfg.num_kv_heads, hd)
    v = dense_apply(params["wv"], memory, cfg).reshape(B, T, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        k = head_rmsnorm_apply(params["k_norm"], k, cfg.norm_eps)
    return k, v


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> KVCache:
    hd = cfg.resolved_head_dim
    dtype = dtype or cfg.activation_dtype
    shape = (batch, max_len, cfg.num_kv_heads, hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype), jnp.zeros((), jnp.int32))


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> KVCache:
    hd = cfg.resolved_head_dim
    dtype = dtype or cfg.activation_dtype
    shape = (batch, max_len, cfg.num_kv_heads, hd)
    return KVCache(
        jax.ShapeDtypeStruct(shape, dtype),
        jax.ShapeDtypeStruct(shape, dtype),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
