"""Decoder-only transformer LM: dense or MoE FFN, optional MoE attention.

Layers are stacked and executed with ``jax.lax.scan`` (HLO size O(1) in
depth — required to compile 64-layer configs with 512 virtual devices),
with optional rematerialisation.  Supports three entry points:

* ``lm_apply``      — full-sequence forward (training / loss).
* ``prefill_apply`` — full-sequence forward that also fills a KV cache.
* ``decode_apply``  — single-token step against a KV cache.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.context import MoEContext
from repro.core.metrics import empty_aux
from repro.core.moe import moe_ffn_apply, moe_ffn_specs
from repro.core.moe_attention import moe_attention_apply, moe_attention_specs
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.attention import (
    KVCache,
    abstract_cache,
    attention_apply,
    attention_specs,
    init_cache,
)
from repro.nn.spec import stack_specs


def _is_moe_layer(cfg: ModelConfig, layer_idx: int) -> bool:
    return cfg.moe.num_experts > 0 and (layer_idx % cfg.moe_layer_period == 0)


def block_specs(cfg: ModelConfig, moe_layer: bool):
    specs = {
        "ln_attn": L.norm_specs(cfg),
        "ln_ffn": L.norm_specs(cfg),
    }
    if cfg.moe.moe_attention and moe_layer:
        specs["attn"] = moe_attention_specs(cfg)
    else:
        specs["attn"] = attention_specs(cfg)
    if moe_layer:
        specs["ffn"] = moe_ffn_specs(cfg)
    else:
        specs["ffn"] = L.ffn_specs(cfg)
    return specs


def block_apply(params, x, cfg: ModelConfig, *, positions, moe_layer: bool,
                cache: Optional[KVCache] = None, use_flash: bool = False,
                ctx: Optional[MoEContext] = None):
    """Pre-norm block. Returns (x, aux, new_cache).

    ``ctx`` is the MoE side-channel (token ids, absolute positions, PRNG,
    step, train flag) threaded to routers and dispatchers; dense layers
    ignore it.
    """
    h = L.norm_apply(params["ln_attn"], x, cfg)
    if cfg.moe.moe_attention and moe_layer and cache is None:
        attn_out, attn_aux = moe_attention_apply(params["attn"], h, cfg,
                                                 positions=positions, ctx=ctx)
        new_cache = None
    else:
        attn_out, new_cache = attention_apply(
            params["attn"], h, cfg, positions=positions, cache=cache, use_flash=use_flash)
        attn_aux = None
    x = x + attn_out
    x = shard(x, "batch", "seq", "embed")

    h = L.norm_apply(params["ln_ffn"], x, cfg)
    if moe_layer:
        ffn_out, aux = moe_ffn_apply(params["ffn"], h, cfg, ctx=ctx)
        if attn_aux is not None:
            aux = {k: aux[k] + attn_aux[k] if k.endswith("_loss") else aux[k]
                   for k in aux}
    else:
        ffn_out, aux = (L.ffn_apply(params["ffn"], h, cfg),
                        empty_aux(cfg.moe.num_experts))
    x = x + ffn_out
    x = shard(x, "batch", "seq", "embed")
    return x, aux, new_cache


def lm_specs(cfg: ModelConfig):
    uniform = cfg.moe.num_experts == 0 or cfg.moe_layer_period == 1
    specs = {
        "embed": L.embedding_specs(cfg),
        "final_norm": L.norm_specs(cfg),
    }
    if cfg.pos_embed == "learned":
        from repro.nn import ParamSpec, truncated_normal_init

        specs["pos_embed"] = ParamSpec(
            (cfg.max_seq_len, cfg.d_model), jnp.dtype(cfg.param_dtype),
            (None, "embed"), truncated_normal_init(cfg.initializer_range))
    if cfg.scan_layers and uniform:
        specs["blocks"] = stack_specs(block_specs(cfg, _is_moe_layer(cfg, 0)), cfg.num_layers)
    else:
        specs["blocks"] = [block_specs(cfg, _is_moe_layer(cfg, i)) for i in range(cfg.num_layers)]
    if not cfg.tie_embeddings:
        specs["unembed"] = L.embedding_specs(cfg)
    return specs


def _run_blocks(params, x, cfg: ModelConfig, *, positions, caches=None,
                use_flash: bool = False, ctx: Optional[MoEContext] = None):
    """Run all layers; returns (x, aux_stacked, new_caches).

    ``ctx`` is layer-invariant, so under scan it rides in the body
    closure (broadcast), not through xs.
    """
    uniform = cfg.moe.num_experts == 0 or cfg.moe_layer_period == 1
    decode = caches is not None

    if isinstance(params["blocks"], list):  # unrolled (mixed layer kinds)
        auxes, new_caches = [], []
        for i, bp in enumerate(params["blocks"]):
            c = caches_index(caches, i) if decode else None
            x, aux, nc = block_apply(bp, x, cfg, positions=positions,
                                     moe_layer=_is_moe_layer(cfg, i), cache=c,
                                     use_flash=use_flash, ctx=ctx)
            auxes.append(aux)
            new_caches.append(nc)
        aux = {k: sum(a[k] for a in auxes) if k.endswith("_loss")
               else jnp.stack([a[k] for a in auxes]) for k in auxes[0]}
        nc = stack_caches(new_caches) if decode else None
        return x, aux, nc

    moe_layer = _is_moe_layer(cfg, 0)

    if decode:
        # Caches flow through scan xs/ys (layer-sliced): GSPMD keeps each
        # layer's K/V sharded in place; a carry-based in-place update was
        # tried and triggered pathological per-layer resharding (see
        # EXPERIMENTS.md S Perf).
        def body(h, scanned):
            bp, layer_cache = scanned
            h, aux, new_cache = block_apply(bp, h, cfg, positions=positions,
                                            moe_layer=moe_layer, cache=layer_cache,
                                            use_flash=use_flash, ctx=ctx)
            return h, (aux, new_cache)

        x, (aux, new_caches) = jax.lax.scan(body, x, (params["blocks"], caches))
    else:
        def body(h, bp):
            h, aux, _ = block_apply(bp, h, cfg, positions=positions,
                                    moe_layer=moe_layer, cache=None,
                                    use_flash=use_flash, ctx=ctx)
            return h, aux

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, aux = jax.lax.scan(body, x, params["blocks"])
        new_caches = None
    aux = dict(aux)
    for k in list(aux):
        if k.endswith("_loss"):
            aux[k] = jnp.sum(aux[k])
    return x, aux, new_caches


def caches_index(caches, i):
    if caches is None:
        return None
    return jax.tree_util.tree_map(lambda a: a[i], caches)


def stack_caches(cache_list):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *cache_list)


def lm_apply(params, tokens, cfg: ModelConfig, *, positions=None,
             use_flash: bool = False, extra_embeds: Optional[jax.Array] = None,
             ctx: Optional[MoEContext] = None):
    """tokens: (B, S) int32 -> (logits (B,S,V_pad), aux).

    ``extra_embeds``: optional (B, P, d_model) prefix embeddings (image
    patches / audio frames for the VLM / audio / M6 stubs) prepended to
    the token embeddings.  ``ctx`` carries caller-side MoE context
    (train flag / step / PRNG); token ids and positions are filled here,
    with prefix rows marked identity-unknown (-1).
    """
    x = L.embedding_apply(params["embed"], tokens, cfg)
    prefix = 0
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        prefix = extra_embeds.shape[1]
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    ctx = (ctx or MoEContext()).with_tokens(tokens, positions, prefix_len=prefix)
    if cfg.pos_embed == "learned":
        x = x + params["pos_embed"][:S].astype(x.dtype)[None]
    x = shard(x, "batch", "seq", "embed")
    x, aux, _ = _run_blocks(params, x, cfg, positions=positions,
                            use_flash=use_flash, ctx=ctx)
    x = L.norm_apply(params["final_norm"], x, cfg)
    unembed = params.get("unembed", params["embed"])
    logits = L.unembed_apply(unembed, x, cfg)
    logits = shard(logits, "batch", "seq", "vocab")
    return logits, aux


def init_caches(cfg: ModelConfig, batch: int, max_len: int, abstract: bool = False):
    fn = abstract_cache if abstract else init_cache
    one = fn(cfg, batch, max_len)
    if abstract:
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((cfg.num_layers,) + s.shape, s.dtype), one)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape).copy(), one)


def decode_apply(params, tokens, caches, cfg: ModelConfig,
                 ctx: Optional[MoEContext] = None):
    """tokens: (B, 1) -> (logits (B,1,V_pad), new_caches).

    The MoE context carries the *absolute* decode positions (from the
    cache length) and the current token ids, so content/identity routing
    is consistent between prefill and decode.
    """
    x = L.embedding_apply(params["embed"], tokens, cfg)
    B, S, _ = x.shape
    length = caches.length[0] if hasattr(caches, "length") else caches[0].length
    positions = jnp.broadcast_to(length + jnp.arange(S)[None, :], (B, S))
    ctx = (ctx or MoEContext()).with_tokens(tokens, positions)
    if cfg.pos_embed == "learned":
        pos_tab = params["pos_embed"].astype(x.dtype)
        x = x + jax.lax.dynamic_slice_in_dim(pos_tab, length, S, axis=0)[None]
    x = shard(x, "batch", "seq", "embed")
    x, aux, new_caches = _run_blocks(params, x, cfg, positions=positions,
                                     caches=caches, ctx=ctx)
    x = L.norm_apply(params["final_norm"], x, cfg)
    unembed = params.get("unembed", params["embed"])
    logits = L.unembed_apply(unembed, x, cfg)
    return logits, new_caches


def prefill_apply(params, tokens, cfg: ModelConfig, *, max_len: int,
                  use_flash: bool = False, ctx: Optional[MoEContext] = None):
    """Full forward + build KV caches for subsequent decode.

    Implemented as full-sequence attention followed by writing K/V into a
    fresh cache (single pass, no chunking — chunked prefill lives in
    ``repro.serving.engine``).
    """
    caches = init_caches(cfg, tokens.shape[0], max_len)
    caches = jax.tree_util.tree_map(lambda a: a, caches)
    # reuse decode path with S = seq_len: dynamic_update at index 0
    x = L.embedding_apply(params["embed"], tokens, cfg)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    ctx = (ctx or MoEContext()).with_tokens(tokens, positions)
    x = shard(x, "batch", "seq", "embed")
    x, aux, new_caches = _run_blocks(params, x, cfg, positions=positions,
                                     caches=caches, ctx=ctx)
    x = L.norm_apply(params["final_norm"], x, cfg)
    unembed = params.get("unembed", params["embed"])
    logits = L.unembed_apply(unembed, x, cfg)
    return logits, new_caches, aux
