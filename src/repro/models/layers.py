"""Shared neural-net layers: norms, embeddings, RoPE, dense/GLU FFN.

Every module is a pair of pure functions:
  ``<name>_specs(cfg, ...) -> ParamSpec tree``
  ``<name>_apply(params, x, ...) -> array``
Mixed precision: parameters are stored in ``cfg.param_dtype`` and cast to
``cfg.dtype`` at use; norms and routers compute in float32.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn import ParamSpec, ones_init, zeros_init, truncated_normal_init
from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_specs(cfg: ModelConfig, dim: Optional[int] = None):
    d = dim or cfg.d_model
    specs = {"scale": ParamSpec((d,), jnp.float32, ("embed",), ones_init)}
    if cfg.norm == "layernorm":
        specs["bias"] = ParamSpec((d,), jnp.float32, ("embed",), zeros_init)
    return specs


def norm_apply(params, x, cfg: ModelConfig):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * params["scale"] + params["bias"]
    else:  # rmsnorm
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + cfg.norm_eps) * params["scale"]
    return y.astype(dtype)


def head_rmsnorm_apply(scale, x, eps: float):
    """Per-head RMSNorm over the last (head_dim) axis (qwen3 qk-norm)."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------

def padded_vocab(vocab_size: int, multiple: int = 256) -> int:
    """Pad vocab to a multiple so the table shards evenly over `model`."""
    return -(-vocab_size // multiple) * multiple


def embedding_specs(cfg: ModelConfig):
    v = padded_vocab(cfg.vocab_size)
    init = truncated_normal_init(cfg.initializer_range)
    return {"table": ParamSpec((v, cfg.d_model), jnp.dtype(cfg.param_dtype), ("vocab", "embed"), init)}


def embedding_apply(params, token_ids, cfg: ModelConfig):
    table = params["table"].astype(cfg.activation_dtype)
    return jnp.take(table, token_ids, axis=0)


def unembed_apply(params, x, cfg: ModelConfig):
    """Logits over the *padded* vocab; padded entries are masked to -inf."""
    table = params["table"].astype(cfg.activation_dtype)
    logits = jnp.einsum("...d,vd->...v", x, table)
    v_pad = table.shape[0]
    if v_pad != cfg.vocab_size:
        mask = jnp.arange(v_pad) < cfg.vocab_size
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    return logits


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """Return (sin, cos) of shape positions.shape + (head_dim//2,)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (..., S, H, D); sin/cos: (..., S, D//2) broadcast over heads."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    s = sin[..., None, :]
    c = cos[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# Dense projections / FFN
# ---------------------------------------------------------------------------

def dense_specs(cfg: ModelConfig, d_in: int, d_out: int, axes=("embed", "mlp"), bias: bool = False):
    init = truncated_normal_init(cfg.initializer_range)
    specs = {"kernel": ParamSpec((d_in, d_out), jnp.dtype(cfg.param_dtype), axes, init)}
    if bias:
        specs["bias"] = ParamSpec((d_out,), jnp.float32, (axes[1],), zeros_init)
    return specs


def dense_apply(params, x, cfg: ModelConfig):
    w = params["kernel"].astype(cfg.activation_dtype)
    y = x @ w
    if "bias" in params:
        y = y + params["bias"].astype(y.dtype)
    return y


def _activation(name: str, x, gate=None):
    if name == "swiglu":
        return jax.nn.silu(x) * gate
    if name == "geglu":
        return jax.nn.gelu(x) * gate
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown activation {name}")


def ffn_specs(cfg: ModelConfig, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    specs = {
        "up": dense_specs(cfg, cfg.d_model, d_ff, ("embed", "mlp")),
        "down": dense_specs(cfg, d_ff, cfg.d_model, ("mlp", "embed")),
    }
    if cfg.ffn_activation in ("swiglu", "geglu"):
        specs["gate"] = dense_specs(cfg, cfg.d_model, d_ff, ("embed", "mlp"))
    return specs


def ffn_apply(params, x, cfg: ModelConfig):
    up = dense_apply(params["up"], x, cfg)
    gate = None
    if "gate" in params:
        # Note: HF convention names the silu() input "gate"; we match math,
        # not naming: act(gate_proj(x)) * up_proj(x).
        gate = up
        up = dense_apply(params["gate"], x, cfg)
    h = _activation(cfg.ffn_activation, up, gate)
    return dense_apply(params["down"], h, cfg)
