"""Chunked online-softmax attention in pure XLA (jnp) with a flash-style
custom VJP — the TPU-adaptation of FlashAttention semantics for paths the
Pallas kernel does not cover (CPU compile, dry-run, grad).

Memory is O(S * block) instead of O(S^2): forward scans KV blocks with
running (max, denom, acc); backward saves only (q, k, v, out, lse) and
recomputes probabilities per block (dq in the scan carry; dk/dv as
per-block outputs).  Numerics match the reference within fp tolerance
(tests/test_attention.py, including grads).

Layout: grouped-query form q (B, S, Hkv, G, D); k/v (B, T, Hkv, D).
``q_offset`` supports the KV-cache path (queries start at cache length).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _blocks(T: int, target: int) -> int:
    b = min(target, T)
    while T % b:
        b -= 1
    return b


def _scores(qg, kb, softcap: float):
    # qg: (B,S,H,G,D) f32 pre-scaled; kb: (B,bkv,H,D)
    s = jnp.einsum("bshgd,bthd->bhgst", qg, kb)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    return s  # (B,H,G,S,bkv)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def chunked_attention(qg, k, v, causal: bool, q_offset_static: Optional[int],
                      block: int = 512, softcap: float = 0.0,
                      q_offset: Optional[jax.Array] = None):
    out, _ = _fwd_impl(qg, k, v, causal, q_offset_static, block, softcap, q_offset)
    return out


def _offset(q_offset_static, q_offset):
    if q_offset is not None:
        return q_offset
    return jnp.asarray(q_offset_static or 0, jnp.int32)


def _fwd_impl(qg, k, v, causal, q_offset_static, block, softcap, q_offset):
    B, S, H, G, D = qg.shape
    T = k.shape[1]
    bkv = _blocks(T, block)
    n = T // bkv
    off = _offset(q_offset_static, q_offset)
    q32 = qg.astype(jnp.float32) * (D ** -0.5)
    rows = off + jnp.arange(S)                                   # (S,)

    kb = k.astype(jnp.float32).reshape(B, n, bkv, H, D).transpose(1, 0, 2, 3, 4)
    vb = v.astype(jnp.float32).reshape(B, n, bkv, H, D).transpose(1, 0, 2, 3, 4)

    def body(carry, xs):
        m, l, acc = carry
        kblk, vblk, idx = xs
        s = _scores(q32, kblk, softcap)                          # (B,H,G,S,bkv)
        if causal:
            cols = idx * bkv + jnp.arange(bkv)
            mask = cols[None, :] <= rows[:, None]                # (S,bkv)
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhgst,bthd->bhgsd", p, vblk)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, G, S), jnp.float32)
    a0 = jnp.zeros((B, H, G, S, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, jnp.arange(n)))
    l = jnp.maximum(l, 1e-30)
    out = (acc / l[..., None]).transpose(0, 3, 1, 2, 4)          # (B,S,H,G,D)
    lse = m + jnp.log(l)                                         # (B,H,G,S)
    return out.astype(qg.dtype), lse


def _fwd_vjp(qg, k, v, causal, q_offset_static, block, softcap, q_offset):
    out, lse = _fwd_impl(qg, k, v, causal, q_offset_static, block, softcap, q_offset)
    return out, (qg, k, v, out, lse, q_offset)


def _bwd_vjp(causal, q_offset_static, block, softcap, res, dout):
    qg, k, v, out, lse, q_offset = res
    B, S, H, G, D = qg.shape
    T = k.shape[1]
    bkv = _blocks(T, block)
    n = T // bkv
    off = _offset(q_offset_static, q_offset)
    scale = D ** -0.5
    q32 = qg.astype(jnp.float32) * scale
    do32 = dout.astype(jnp.float32)
    o32 = out.astype(jnp.float32)
    delta = jnp.sum(do32 * o32, axis=-1).transpose(0, 2, 3, 1)   # (B,H,G,S)
    rows = off + jnp.arange(S)

    kb = k.astype(jnp.float32).reshape(B, n, bkv, H, D).transpose(1, 0, 2, 3, 4)
    vb = v.astype(jnp.float32).reshape(B, n, bkv, H, D).transpose(1, 0, 2, 3, 4)
    doh = do32.transpose(0, 2, 3, 1, 4)                          # (B,H,G,S,D)

    def body(dq, xs):
        kblk, vblk, idx = xs
        s = _scores(q32, kblk, 0.0)
        if softcap > 0:
            t = jnp.tanh(s / softcap)
            s_capped = t * softcap
            dcap = 1.0 - jnp.square(t)                           # d(capped)/d(s)
        else:
            s_capped = s
            dcap = None
        if causal:
            cols = idx * bkv + jnp.arange(bkv)
            mask = cols[None, :] <= rows[:, None]
            s_capped = jnp.where(mask, s_capped, NEG_INF)
        p = jnp.exp(s_capped - lse[..., None])                   # (B,H,G,S,bkv)
        dv_blk = jnp.einsum("bhgst,bhgsd->bthd", p, doh)
        dp = jnp.einsum("bhgsd,bthd->bhgst", doh, vblk)
        ds = p * (dp - delta[..., None])
        if dcap is not None:
            ds = ds * dcap
        dq_blk = jnp.einsum("bhgst,bthd->bshgd", ds, kblk) * scale
        dk_blk = jnp.einsum("bhgst,bshgd->bthd", ds, q32)
        return dq + dq_blk, (dk_blk, dv_blk)

    dq0 = jnp.zeros((B, S, H, G, D), jnp.float32)
    dq, (dk, dv) = jax.lax.scan(body, dq0, (kb, vb, jnp.arange(n)))
    dk = dk.transpose(1, 0, 2, 3, 4).reshape(B, T, H, D)
    dv = dv.transpose(1, 0, 2, 3, 4).reshape(B, T, H, D)
    return (dq.astype(qg.dtype), dk.astype(k.dtype), dv.astype(v.dtype), None)


chunked_attention.defvjp(_fwd_vjp, _bwd_vjp)
