"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, recurrent), per-block pattern configurable via
``cfg.xlstm_slstm_period`` (every k-th block is sLSTM; 0 = all mLSTM).

mLSTM uses the stabilised parallel (quadratic) form for train/prefill and
an O(1) recurrent step for decode (the `long_500k` cell).  sLSTM is a
`lax.scan` over time.  Both keep gate math in float32.

Simplifications vs the reference CUDA implementation (documented in
DESIGN.md): no causal-conv skip inside the mLSTM block's qk path is
*kept* (conv4), learnable per-head gate biases included, block-diagonal
recurrent gates for sLSTM with one block per head.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.metrics import empty_aux
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.nn import ParamSpec, truncated_normal_init, zeros_init, ones_init
from repro.nn.spec import stack_specs


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

class MLSTMState(NamedTuple):
    c: jax.Array   # (B, H, D, D) matrix memory
    n: jax.Array   # (B, H, D) normaliser
    m: jax.Array   # (B, H) stabiliser
    conv: jax.Array  # (B, W-1, D_inner) conv tail


def _mlstm_dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model if cfg.ssm_expand else 2 * cfg.d_model
    heads = cfg.num_heads
    dh = d_inner // heads
    return d_inner, heads, dh


def mlstm_block_specs(cfg: ModelConfig):
    d = cfg.d_model
    d_inner, H, dh = _mlstm_dims(cfg)
    init = truncated_normal_init(cfg.initializer_range)
    wdt = jnp.dtype(cfg.param_dtype)
    w = 4  # causal conv width
    return {
        "ln": L.norm_specs(cfg),
        "up_x": ParamSpec((d, d_inner), wdt, ("embed", "ssm_inner"), init),
        "up_z": ParamSpec((d, d_inner), wdt, ("embed", "ssm_inner"), init),
        "conv_w": ParamSpec((w, d_inner), wdt, (None, "ssm_inner"), init),
        "wq": ParamSpec((d_inner, d_inner), wdt, ("ssm_inner", None), init),
        "wk": ParamSpec((d_inner, d_inner), wdt, ("ssm_inner", None), init),
        "wv": ParamSpec((d_inner, d_inner), wdt, ("ssm_inner", None), init),
        "w_igate": ParamSpec((d_inner, H), jnp.float32, ("ssm_inner", None), init),
        "w_fgate": ParamSpec((d_inner, H), jnp.float32, ("ssm_inner", None), init),
        "b_igate": ParamSpec((H,), jnp.float32, (None,), zeros_init),
        "b_fgate": ParamSpec((H,), jnp.float32, (None,), ones_init),
        "head_norm": ParamSpec((d_inner,), jnp.float32, ("ssm_inner",), ones_init),
        "down": ParamSpec((d_inner, d), wdt, ("ssm_inner", "embed"), init),
    }


def _causal_conv(x, w, tail=None):
    """x: (B,S,D), w: (W,D) depthwise causal conv; tail: (B,W-1,D) history."""
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(W))
    new_tail = xp[:, -(W - 1):, :] if W > 1 else tail
    return out, new_tail


def _mlstm_parallel(q, k, v, igate, fgate):
    """Stabilised parallel mLSTM (paper App. B). q,k,v: (B,H,S,D);
    igate/fgate: (B,H,S) pre-activations (f through log-sigmoid)."""
    S = q.shape[2]
    logf = jax.nn.log_sigmoid(fgate)                    # (B,H,S)
    F = jnp.cumsum(logf, axis=-1)                       # (B,H,S)
    # D[t,s] = F_t - F_s + i_s for s<=t
    Dmat = F[..., :, None] - F[..., None, :] + igate[..., None, :]
    causal = jnp.tril(jnp.ones((S, S), bool))
    Dmat = jnp.where(causal, Dmat, -jnp.inf)
    m = jnp.max(Dmat, axis=-1, keepdims=True)           # (B,H,S,1)
    m = jnp.maximum(m, -1e30)                           # guard all -inf rows
    Dexp = jnp.exp(Dmat - m)
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k) * scale * Dexp
    norm = jnp.maximum(jnp.abs(jnp.sum(scores, axis=-1, keepdims=True)),
                       jnp.exp(-m))
    h = jnp.einsum("bhst,bhtd->bhsd", scores / norm, v)
    return h


def _mlstm_chunked(q, k, v, igate, fgate, chunk: int = 256):
    """Chunkwise-parallel stabilised mLSTM: O(S*W) memory instead of O(S^2).

    q,k,v: (B,H,S,D) f32; igate/fgate: (B,H,S) pre-activations.
    Equivalent to `_mlstm_parallel` (tested); used for long sequences.
    """
    B, H, S, D = q.shape
    W = min(chunk, S)
    if S % W != 0:  # pad to a chunk multiple (keeps semantics: padded gates
        pad = W - S % W  # get igate = -inf so they contribute nothing)
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        igate = jnp.pad(igate, ((0, 0), (0, 0), (0, pad)), constant_values=-1e30)
        fgate = jnp.pad(fgate, ((0, 0), (0, 0), (0, pad)), constant_values=30.0)
    NC = q.shape[2] // W
    resh = lambda a: a.reshape(B, H, NC, W, -1).transpose(2, 0, 1, 3, 4)
    qc, kc, vc = resh(q), resh(k), resh(v)                       # (NC,B,H,W,D)
    ic = igate.reshape(B, H, NC, W).transpose(2, 0, 1, 3)        # (NC,B,H,W)
    fc = fgate.reshape(B, H, NC, W).transpose(2, 0, 1, 3)
    scale = D ** -0.5

    def body(carry, xs):
        C, n, m = carry                                          # (B,H,D,D),(B,H,D),(B,H)
        qb, kb, vb, ib, fb = xs
        logf = jax.nn.log_sigmoid(fb)                            # (B,H,W)
        lF = jnp.cumsum(logf, axis=-1)                           # inclusive
        # intra-chunk decay matrix
        Dmat = lF[..., :, None] - lF[..., None, :] + ib[..., None, :]
        causal = jnp.tril(jnp.ones((W, W), bool))
        Dmat = jnp.where(causal, Dmat, -jnp.inf)
        m_intra = jnp.max(Dmat, axis=-1)                         # (B,H,W)
        m_inter = lF + m[..., None]
        m_t = jnp.maximum(jnp.maximum(m_intra, m_inter), -1e30)  # (B,H,W)
        Dexp = jnp.exp(Dmat - m_t[..., None])
        scores = jnp.einsum("bhsd,bhtd->bhst", qb, kb) * scale * Dexp
        inter_w = jnp.exp(m_inter - m_t)[..., None]              # (B,H,W,1)
        num = jnp.einsum("bhst,bhtd->bhsd", scores, vb) + \
            inter_w * jnp.einsum("bhsd,bhde->bhse", qb * scale, C)
        den_intra = jnp.sum(scores, axis=-1)
        den_inter = jnp.einsum("bhsd,bhd->bhs", qb * scale, n) * inter_w[..., 0]
        den = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_t))
        h = num / den[..., None]
        # state update to end of chunk
        lF_W = lF[..., -1:]                                      # (B,H,1)
        m_new = jnp.maximum(lF_W[..., 0] + m, jnp.max(lF_W - lF + ib, axis=-1))
        kw = jnp.exp(lF_W - lF + ib - m_new[..., None])          # (B,H,W)
        C_new = jnp.exp(lF_W[..., 0] + m - m_new)[..., None, None] * C + \
            jnp.einsum("bhs,bhsd,bhse->bhde", kw, kb, vb)
        n_new = jnp.exp(lF_W[..., 0] + m - m_new)[..., None] * n + \
            jnp.einsum("bhs,bhsd->bhd", kw, kb)
        return (C_new, n_new, m_new), h

    C0 = jnp.zeros((B, H, D, D), jnp.float32)
    n0 = jnp.zeros((B, H, D), jnp.float32)
    m0 = jnp.full((B, H), 0.0, jnp.float32)
    # checkpoint the chunk body: bwd recomputes per-chunk decay/score
    # tensors instead of saving NC copies (see EXPERIMENTS.md S Perf)
    _, hs = jax.lax.scan(jax.checkpoint(body, prevent_cse=False),
                         (C0, n0, m0), (qc, kc, vc, ic, fc))
    hs = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, NC * W, D)
    return hs[:, :, :S]


def mlstm_block_apply(params, x, cfg: ModelConfig, *, state: Optional[MLSTMState] = None):
    """Returns (y, new_state). state != None -> single-step decode."""
    B, S, _ = x.shape
    d_inner, H, dh = _mlstm_dims(cfg)
    dt = x.dtype
    h = L.norm_apply(params["ln"], x, cfg)
    xb = h @ params["up_x"].astype(dt)
    zb = h @ params["up_z"].astype(dt)
    conv_tail = state.conv if state is not None else None
    xc, new_tail = _causal_conv(xb, params["conv_w"].astype(dt), conv_tail)
    xc = jax.nn.silu(xc)
    q = (xc @ params["wq"].astype(dt)).reshape(B, S, H, dh)
    k = (xc @ params["wk"].astype(dt)).reshape(B, S, H, dh)
    v = (xb @ params["wv"].astype(dt)).reshape(B, S, H, dh)
    ig = (xc.astype(jnp.float32) @ params["w_igate"] + params["b_igate"])  # (B,S,H)
    fg = (xc.astype(jnp.float32) @ params["w_fgate"] + params["b_fgate"])

    if state is None:
        qh = jnp.transpose(q, (0, 2, 1, 3)).astype(jnp.float32)
        kh = jnp.transpose(k, (0, 2, 1, 3)).astype(jnp.float32)
        vh = jnp.transpose(v, (0, 2, 1, 3)).astype(jnp.float32)
        igh = jnp.transpose(ig, (0, 2, 1))
        fgh = jnp.transpose(fg, (0, 2, 1))
        if S > max(cfg.ssm_chunk, 1) * 2:
            hout = _mlstm_chunked(qh, kh, vh, igh, fgh, chunk=max(cfg.ssm_chunk, 64))
        else:
            hout = _mlstm_parallel(qh, kh, vh, igh, fgh)
        hout = jnp.transpose(hout, (0, 2, 1, 3)).reshape(B, S, d_inner)
        new_state = None
    else:
        # O(1) recurrent step (S == 1)
        q1 = q[:, 0].astype(jnp.float32) * (dh ** -0.5)   # (B,H,D)
        k1 = k[:, 0].astype(jnp.float32)
        v1 = v[:, 0].astype(jnp.float32)
        ig1, fg1 = ig[:, 0], fg[:, 0]                      # (B,H)
        logf = jax.nn.log_sigmoid(fg1)
        m_new = jnp.maximum(logf + state.m, ig1)
        fprime = jnp.exp(logf + state.m - m_new)[..., None]
        iprime = jnp.exp(ig1 - m_new)[..., None]
        c_new = fprime[..., None] * state.c + iprime[..., None] * (
            k1[..., :, None] * v1[..., None, :])           # (B,H,D,D)
        n_new = fprime * state.n + iprime * k1
        num = jnp.einsum("bhd,bhde->bhe", q1, c_new)
        den = jnp.maximum(jnp.abs(jnp.sum(q1 * n_new, axis=-1, keepdims=True)),
                          jnp.exp(-m_new)[..., None])
        hout = (num / den).reshape(B, 1, d_inner)
        new_state = MLSTMState(c_new, n_new, m_new, new_tail)

    hout = L.head_rmsnorm_apply(
        params["head_norm"].reshape(H, dh), hout.reshape(B, S, H, dh).astype(jnp.float32),
        cfg.norm_eps).reshape(B, S, d_inner).astype(dt)
    out = (hout * jax.nn.silu(zb)) @ params["down"].astype(dt)
    return x + out, new_state


def mlstm_init_state(cfg: ModelConfig, batch: int, abstract: bool = False):
    d_inner, H, dh = _mlstm_dims(cfg)
    shapes = {
        "c": (batch, H, dh, dh), "n": (batch, H, dh), "m": (batch, H),
        "conv": (batch, 3, d_inner),
    }
    mk = (lambda s: jax.ShapeDtypeStruct(s, jnp.float32)) if abstract else (
        lambda s: jnp.zeros(s, jnp.float32))
    return MLSTMState(mk(shapes["c"]), mk(shapes["n"]), mk(shapes["m"]), mk(shapes["conv"]))


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

class SLSTMState(NamedTuple):
    c: jax.Array   # (B, D_in)
    n: jax.Array
    h: jax.Array
    m: jax.Array


def slstm_block_specs(cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    init = truncated_normal_init(cfg.initializer_range)
    wdt = jnp.dtype(cfg.param_dtype)
    pf = int(d * 4 / 3) // 8 * 8 or 8  # gated-FFN projection factor 4/3
    return {
        "ln": L.norm_specs(cfg),
        "w_gates": ParamSpec((d, 4 * d), wdt, ("embed", None), init),
        # block-diagonal recurrent weights: one (dh, 4*dh) block per head
        "r_gates": ParamSpec((H, dh, 4 * dh), wdt, (None, None, None), init),
        "b_gates": ParamSpec((4 * d,), jnp.float32, (None,), zeros_init),
        "head_norm": ParamSpec((d,), jnp.float32, ("embed",), ones_init),
        "ln_ffn": L.norm_specs(cfg),
        "ffn_up": ParamSpec((d, 2 * pf), wdt, ("embed", "mlp"), init),
        "ffn_down": ParamSpec((pf, d), wdt, ("mlp", "embed"), init),
    }


def _slstm_cell(params, xt, state: SLSTMState, cfg: ModelConfig):
    """One timestep. xt: (B, D) f32."""
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    B = xt.shape[0]
    hprev = state.h.reshape(B, H, dh)
    rec = jnp.einsum("bhd,hde->bhe", hprev, params["r_gates"].astype(jnp.float32))
    gates = xt @ params["w_gates"].astype(jnp.float32)
    gates = gates.reshape(B, H, 4 * dh) + rec + params["b_gates"].reshape(H, 4 * dh)
    i_t, f_t, z_t, o_t = jnp.split(gates, 4, axis=-1)      # (B,H,dh) each
    z = jnp.tanh(z_t)
    o = jax.nn.sigmoid(o_t)
    logf = jax.nn.log_sigmoid(f_t)
    m_prev = state.m.reshape(B, H, dh)
    m_new = jnp.maximum(logf + m_prev, i_t)
    iprime = jnp.exp(i_t - m_new)
    fprime = jnp.exp(logf + m_prev - m_new)
    c_new = fprime * state.c.reshape(B, H, dh) + iprime * z
    n_new = fprime * state.n.reshape(B, H, dh) + iprime
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    flat = lambda a: a.reshape(B, d)
    return SLSTMState(flat(c_new), flat(n_new), flat(h_new), flat(m_new))


def slstm_block_apply(params, x, cfg: ModelConfig, *, state: Optional[SLSTMState] = None):
    B, S, d = x.shape
    dt = x.dtype
    h = L.norm_apply(params["ln"], x, cfg).astype(jnp.float32)
    st = state if state is not None else slstm_init_state(cfg, B)

    def step(carry, xt):
        new = _slstm_cell(params, xt, carry, cfg)
        return new, new.h

    if S == 1:
        st = _slstm_cell(params, h[:, 0], st, cfg)
        hs = st.h[:, None]
    else:
        st, hs = jax.lax.scan(step, st, jnp.transpose(h, (1, 0, 2)))
        hs = jnp.transpose(hs, (1, 0, 2))

    hs = L.head_rmsnorm_apply(params["head_norm"].reshape(cfg.num_heads, d // cfg.num_heads),
                              hs.reshape(B, S, cfg.num_heads, -1), cfg.norm_eps)
    hs = hs.reshape(B, S, d).astype(dt)
    x = x + hs
    # gated FFN (GeGLU, 4/3 factor)
    g = L.norm_apply(params["ln_ffn"], x, cfg)
    ug = g @ params["ffn_up"].astype(dt)
    u, gate = jnp.split(ug, 2, axis=-1)
    x = x + (jax.nn.gelu(gate) * u) @ params["ffn_down"].astype(dt)
    return x, (st if state is not None else None)


def slstm_init_state(cfg: ModelConfig, batch: int, abstract: bool = False):
    d = cfg.d_model
    mk = (lambda: jax.ShapeDtypeStruct((batch, d), jnp.float32)) if abstract else (
        lambda: jnp.zeros((batch, d), jnp.float32))
    return SLSTMState(mk(), mk(), mk(), mk())


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def _is_slstm(cfg: ModelConfig, i: int) -> bool:
    p = cfg.xlstm_slstm_period
    return p > 0 and (i % p == p - 1)


def xlstm_specs(cfg: ModelConfig):
    specs = {
        "embed": L.embedding_specs(cfg),
        "final_norm": L.norm_specs(cfg),
        "blocks": [
            slstm_block_specs(cfg) if _is_slstm(cfg, i) else mlstm_block_specs(cfg)
            for i in range(cfg.num_layers)
        ],
    }
    return specs


def xlstm_apply(params, tokens, cfg: ModelConfig, *, states=None):
    """states: list of per-block states (decode) or None (train/prefill).
    Returns (logits, aux, new_states)."""
    x = L.embedding_apply(params["embed"], tokens, cfg)
    x = shard(x, "batch", "seq", "embed")
    new_states = []
    for i, bp in enumerate(params["blocks"]):
        st = states[i] if states is not None else None
        fn = slstm_block_apply if _is_slstm(cfg, i) else mlstm_block_apply
        if cfg.remat and states is None:
            fn = jax.checkpoint(fn, prevent_cse=False,
                                static_argnums=(2,))
        x, ns = fn(bp, x, cfg, state=st)
        new_states.append(ns)
        x = shard(x, "batch", "seq", "embed")
    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = L.unembed_apply(params["embed"], x, cfg)
    return logits, empty_aux(), (new_states if states is not None else None)


def xlstm_init_states(cfg: ModelConfig, batch: int, abstract: bool = False):
    return [
        slstm_init_state(cfg, batch, abstract) if _is_slstm(cfg, i)
        else mlstm_init_state(cfg, batch, abstract)
        for i in range(cfg.num_layers)
    ]
