"""Train state pytree."""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array            # scalar int32
    error_feedback: Any = None  # int8-compression residual (or None)


def init_train_state(params, optimizer, grad_compression: str = "none") -> TrainState:
    opt_state = optimizer.init(params)
    ef = None
    if grad_compression == "int8":
        ef = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return TrainState(params, opt_state, jnp.zeros((), jnp.int32), ef)
