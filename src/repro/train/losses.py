"""Loss functions: causal-LM cross entropy (+ MoE auxiliary losses)."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def cross_entropy(logits: jax.Array, labels: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Mean token CE over labels >= 0 (negative labels are masked).

    logits: (B, S, V) — may be over a padded vocab; padded entries were
    already masked to -inf upstream.  Returns (loss, n_tokens).
    """
    valid = labels >= 0
    safe_labels = jnp.maximum(labels, 0)
    logits32 = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits32, axis=-1)
    # select-by-mask instead of take_along_axis: keeps the (sharded) vocab
    # axis a plain reduction under GSPMD (no gather -> no logits all-gather)
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_ids == safe_labels[..., None], logits32, 0.0),
                   axis=-1)
    nll = (logz - gold) * valid
    n = jnp.maximum(jnp.sum(valid), 1)
    return jnp.sum(nll) / n, n


def total_loss(logits, labels, aux: Dict) -> Tuple[jax.Array, Dict]:
    ce, n = cross_entropy(logits, labels)
    loss = ce + aux.get("moe_aux_loss", 0.0) + aux.get("moe_z_loss", 0.0)
    metrics = {
        "loss": loss,
        "ce": ce,
        "log_ppl": ce,                      # the paper reports training log-PPL
        "tokens": n,
        "moe_aux_loss": aux.get("moe_aux_loss", jnp.zeros((), jnp.float32)),
    }
    for k in ("moe_cv", "moe_dropped_fraction"):
        if k in aux:
            metrics[k] = aux[k]             # per-layer traces (L,)
    return loss, metrics
