"""The train step: forward/backward, grad-accumulation, clipping,
compression, optimizer update.  Pure function of (state, batch) — jit /
pjit it with the shardings from `repro.distributed.sharding`.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.context import MoEContext
from repro.models.registry import get_family
from repro.optim.api import Optimizer
from repro.optim.clip import clip_by_global_norm
from repro.optim.compression import compress_grads
from repro.train.losses import total_loss
from repro.train.state import TrainState


def make_loss_fn(cfg: ModelConfig):
    fam = get_family(cfg)

    def loss_fn(params, batch, ctx: Optional[MoEContext] = None):
        logits, aux = fam.forward(params, batch, cfg, ctx=ctx)
        loss, metrics = total_loss(logits, batch["labels"], aux)
        return loss, metrics

    return loss_fn


def _split_microbatches(batch: Dict, n: int) -> Dict:
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.sharding import active_rules

    rules = active_rules()

    def f(x):
        y = x.reshape((n, x.shape[0] // n) + x.shape[1:])
        if rules is not None:
            dp = rules.acts.get("batch")
            size = rules.axis_size(dp)
            if dp is not None and y.shape[1] % size == 0:
                y = jax.lax.with_sharding_constraint(
                    y, NamedSharding(rules.mesh, P(None, dp)))
        return y

    return jax.tree_util.tree_map(f, batch)


def make_train_step(cfg: ModelConfig, tc: TrainConfig, optimizer: Optimizer) -> Callable:
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        # The MoE side-channel: routers/dispatchers see the step, a
        # step-folded PRNG key and the train flag; families add token
        # ids and positions from the batch.
        ctx = MoEContext(
            rng=jax.random.fold_in(jax.random.PRNGKey(tc.seed), state.step),
            step=state.step, is_training=True)
        if tc.microbatches > 1:
            mb = _split_microbatches(batch, tc.microbatches)

            def acc(carry, one):
                g_acc, m_acc = carry
                (loss, metrics), grads = grad_fn(state.params, one, ctx)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, grads)
                m_acc = jax.tree_util.tree_map(jnp.add, m_acc,
                                               {"loss": loss, "ce": metrics["ce"]})
                return (g_acc, m_acc), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            m0 = {"loss": jnp.zeros((), jnp.float32), "ce": jnp.zeros((), jnp.float32)}
            (grads, msum), _ = jax.lax.scan(acc, (g0, m0), mb)
            grads = jax.tree_util.tree_map(lambda g: g / tc.microbatches, grads)
            metrics = {k: v / tc.microbatches for k, v in msum.items()}
        else:
            (loss, metrics), grads = grad_fn(state.params, batch, ctx)

        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip_norm)
        grads, ef = compress_grads(grads, tc.grad_compression, state.error_feedback)
        updates, new_opt = optimizer.update(grads, state.opt_state, state.params, state.step)
        new_params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
            state.params, updates)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        new_state = TrainState(new_params, new_opt, state.step + 1, ef)
        return new_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig) -> Callable:
    loss_fn = make_loss_fn(cfg)

    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch, MoEContext(is_training=False))
        return metrics

    return eval_step
