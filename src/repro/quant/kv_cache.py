"""Quantized paged KV-cache variants.

The :class:`_QuantPools` mixin swaps a paged cache's device pools for
int8 *code* pools plus per-(layer, block, kv_head) float32 absmax
*scale* pools indexed by the same block table
(``value = policy.decode(code) * scale`` — see
:mod:`repro.quant.policy`).  Everything host-side — allocator, block
tables, reservations, refcounts, the whole invariant suite — is
representation-blind and inherited unchanged; only pool allocation,
copy-on-write, and byte accounting know about the scales:

* :class:`QuantizedPagedKVCache` — the plain paged cache over int8
  pools.
* :class:`QuantizedPrefixCachingKVCache` — the prefix-caching variant;
  its COW detach copies the old block's scale rows alongside its code
  rows, so a detached copy decodes identically.  Chain-hash identity is
  untouched: prefix hashes are over int32 tokens, never K/V bytes, so
  warm-prefix reuse returns the quantized block bytes *exactly* as
  published.

The sharded composition lives in
:class:`repro.serving.kv_cache.ShardedPagedKVCache`, which instantiates
these as detached per-shard sub-caches and stacks the int8 + scale
pools itself.  Selection from ``ServeConfig.kv_quant`` happens in
:func:`repro.serving.kv_cache.make_kv_cache`.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig, ServeConfig
from repro.quant.policy import get_kv_quant
from repro.serving.kv_cache import PagedKVCache
from repro.serving.prefix_cache import PrefixCachingKVCache

__all__ = ["QuantizedPagedKVCache", "QuantizedPrefixCachingKVCache"]


class _QuantPools:
    """Pool-representation mixin: int8 codes + f32 scales."""

    def _alloc_pools(self, cfg: ModelConfig, serve: ServeConfig) -> None:
        self.policy = get_kv_quant(serve.kv_quant)
        assert self.policy.quantized, (
            "quantized cache built with kv_quant='none'; use make_kv_cache")
        hd = cfg.resolved_head_dim
        rows = self.num_blocks + 1          # + garbage block
        pool_shape = (cfg.num_layers, rows, cfg.num_kv_heads,
                      self.block_size, hd)
        self.k_pool = jnp.zeros(pool_shape, self.policy.pool_dtype)
        self.v_pool = jnp.zeros(pool_shape, self.policy.pool_dtype)
        self.k_scales = jnp.zeros(
            (cfg.num_layers, rows, cfg.num_kv_heads), jnp.float32)
        self.v_scales = jnp.zeros_like(self.k_scales)

    @property
    def block_bytes(self) -> int:
        """int8 codes (itemsize 1) plus the f32 scale rows, K + V."""
        cfg = self.cfg
        codes = cfg.num_kv_heads * self.block_size * cfg.resolved_head_dim
        scales = cfg.num_kv_heads * 4
        return 2 * cfg.num_layers * (codes + scales)

    def check_conservation(self) -> None:
        super().check_conservation()
        # Scale-pool / code-pool bijection: every pool row has exactly
        # one scale row under the same (layer, block) key — the block
        # table indexes both with the same ids.
        if self.k_pool is not None:
            assert self.k_scales.shape == self.k_pool.shape[:2] + (
                self.k_pool.shape[2],), (self.k_scales.shape,
                                         self.k_pool.shape)
            assert self.v_scales.shape == self.k_scales.shape


class QuantizedPagedKVCache(_QuantPools, PagedKVCache):
    """:class:`~repro.serving.kv_cache.PagedKVCache` over int8 pools."""


class QuantizedPrefixCachingKVCache(_QuantPools, PrefixCachingKVCache):
    """:class:`~repro.serving.prefix_cache.PrefixCachingKVCache` over
    int8 pools.  Published blocks are immutable codes + an immutable
    scale: the triple write-guard (bound / refcount > 1 / published)
    protects the scale rows exactly as it protects the code rows, so a
    double-write of a published block's scale raises before any device
    update."""

    def _cow_replace(self, slot: int, k: int) -> None:
        held = self._slot_blocks[slot]
        old = held[k]
        super()._cow_replace(slot, k)
        new = held[k]
        if new != old:
            # the copy must decode identically: codes alone are
            # meaningless without the block's scale rows
            self.k_scales = self.k_scales.at[:, new].set(self.k_scales[:, old])
            self.v_scales = self.v_scales.at[:, new].set(self.v_scales[:, old])
