"""Quantized KV-cache subsystem.

``repro.quant.policy`` defines the :class:`KVQuantPolicy` registry
(``none`` | ``int8`` | ``fp8``) and the scale-maintaining pool-write
primitive; ``repro.quant.kv_cache`` provides the quantized
paged-cache variants (plain / prefix-caching) that
``repro.serving.kv_cache.make_kv_cache`` selects from
``ServeConfig.kv_quant``.  Layout, rewrite rule, and composition notes:
``docs/serving.md`` "Quantized KV cache".
"""
from repro.quant.policy import (            # noqa: F401
    KVQuantPolicy,
    available_kv_quants,
    check_quant_roundtrip,
    get_kv_quant,
    quant_write_kv,
    register_kv_quant,
)

__all__ = [
    "KVQuantPolicy", "available_kv_quants", "check_quant_roundtrip",
    "get_kv_quant", "quant_write_kv", "register_kv_quant",
]
