"""KV-cache quantization policies: per-block-per-head absmax scaling.

A :class:`KVQuantPolicy` describes how a paged K/V pool stores its
tokens: the device pool holds small integer *codes* (int8 for every
quantized policy — ``fp8`` stores float8_e4m3fn bit patterns in an int8
carrier so the pool works on backends without native fp8 pools) plus a
per-(layer, block, kv_head) float32 *scale* pool indexed by the same
block table.  A stored value decodes as ``decode(code) * scale``.

Scales are absmax: for each (block, head) the scale is
``max|value| / qmax`` over every token row the block has ever held, so
quantize/dequantize error is bounded elementwise by
:meth:`KVQuantPolicy.error_bound` (scale/2 for int8 — half a
quantization step; scale * 16 for fp8 — half a ulp at the top e4m3
binade).  Partial-block appends may *grow* a block's absmax; the write
primitive :func:`quant_write_kv` then rescales the block's existing
codes to the new scale before writing the new rows (the rewrite rule:
scales are monotone non-decreasing over a block's fill lifetime, and
the error bound always holds against the *current* scale).

Registry mirrors the router/dispatcher registries: policies are
singletons looked up by name (``none`` | ``int8`` | ``fp8``) and hash
by identity, so they can ride in ``jit``'s static args.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "KVQuantPolicy", "register_kv_quant", "get_kv_quant",
    "available_kv_quants", "quant_write_kv", "check_quant_roundtrip",
]

# Guard for divisions by a block scale: all-zero blocks have scale 0.
_TINY = 1e-30


class KVQuantPolicy:
    """One KV quantization scheme.

    Attributes
    ----------
    name: registry key.
    quantized: False only for the ``none`` passthrough policy.
    qmax: largest representable magnitude of the code space; the scale
        for a block is ``absmax / qmax``.
    pool_dtype: device dtype of the code pool (int8 for all quantized
        policies).
    """

    def __init__(self, name: str, *, quantized: bool, qmax: float,
                 encode: Optional[Callable] = None,
                 decode: Optional[Callable] = None,
                 error_ulps: float = 0.5):
        self.name = name
        self.quantized = quantized
        self.qmax = qmax
        self._encode = encode
        self._decode = decode
        # Elementwise bound in units of the scale: int8's uniform grid
        # gives 0.5 (half a step of size `scale`); fp8's top binade has
        # step 32 (e4m3 mantissa=3 at 256..448), i.e. 16 ulps-of-scale.
        self.error_ulps = error_ulps
        self.pool_dtype = jnp.int8

    # Policies are singletons: identity hash/eq lets a policy ride in
    # jit static_argnames without defining dataclass equality.
    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return self is other

    def __repr__(self):
        return f"KVQuantPolicy({self.name!r})"

    def encode(self, u):
        """Scaled values -> int8 codes (u is value / scale)."""
        return self._encode(u)

    def decode(self, codes):
        """int8 codes -> float32 scaled values."""
        return self._decode(codes)

    def error_bound(self, scale):
        """Elementwise |dequant - value| bound for a block with `scale`."""
        return scale * self.error_ulps


_REGISTRY: Dict[str, KVQuantPolicy] = {}


def register_kv_quant(policy: KVQuantPolicy) -> KVQuantPolicy:
    _REGISTRY[policy.name] = policy
    return policy


def get_kv_quant(name: str) -> KVQuantPolicy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown kv_quant {name!r}; registered: "
            f"{', '.join(sorted(_REGISTRY))}") from None


def available_kv_quants() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# -- built-in policies -------------------------------------------------------

def _int8_encode(u):
    return jnp.clip(jnp.round(u), -127.0, 127.0).astype(jnp.int8)


def _int8_decode(codes):
    return codes.astype(jnp.float32)


def _fp8_encode(u):
    # e4m3 saturates at +-448; values beyond cast to nan, so clip first.
    c = jnp.clip(u.astype(jnp.float32), -448.0, 448.0)
    return jax.lax.bitcast_convert_type(
        c.astype(jnp.float8_e4m3fn), jnp.int8)


def _fp8_decode(codes):
    return jax.lax.bitcast_convert_type(
        codes, jnp.float8_e4m3fn).astype(jnp.float32)


NONE = register_kv_quant(KVQuantPolicy("none", quantized=False, qmax=0.0))
INT8 = register_kv_quant(KVQuantPolicy(
    "int8", quantized=True, qmax=127.0,
    encode=_int8_encode, decode=_int8_decode, error_ulps=0.5))
# "fp8" simulated via e4m3 bit patterns in an int8 pool: bitwise the
# real fp8 representation, decodable on CPU (tests/interpret) and TPU.
FP8 = register_kv_quant(KVQuantPolicy(
    "fp8", quantized=True, qmax=448.0,
    encode=_fp8_encode, decode=_fp8_decode, error_ulps=16.0))


# -- pool write primitive ----------------------------------------------------

def quant_write_kv(codes_pool, scales, x, write_blocks, write_offsets,
                   *, policy: KVQuantPolicy):
    """Scatter new token rows into a quantized pool, maintaining scales.

    Args:
      codes_pool: (P, Hkv, bs, D) int8 code pool for one layer.
      scales:     (P, Hkv) float32 per-block-per-head absmax scales.
      x:          (N, Hkv, D) new rows (one token per row).
      write_blocks, write_offsets: (N,) int32 destination coordinates.
      policy: a quantized :class:`KVQuantPolicy`.

    Returns ``(codes_pool, scales)`` updated.

    Scale maintenance (the partial-block rewrite rule):
      * A block is *fresh* iff some row writes offset 0 this step — the
        allocator hands out blocks empty and rows fill sequentially, so
        offset 0 is always the first write a block ever sees.  Fresh
        blocks restart their scale from 0 (stale scale from a previous
        tenant must not inflate the bound).
      * Each touched block's new scale is max(old-or-0, absmax of its
        incoming rows / qmax) — scatter-max handles several rows
        landing in one block.
      * If the scale grew, the block's *existing* codes are rescaled
        (decode at old scale, re-encode at new scale) before the new
        rows are written.  When the scale did *not* grow the rewrite is
        a lossless identity (decode -> divide by the same scale ->
        re-encode reproduces the codes bit-for-bit), so error only
        compounds on actual growth: a resident token's error against
        the current scale is <= ``(1 + g) * error_bound(scale)`` where
        ``g`` is the number of scale growths since it was written —
        at most ``block_size * error_bound`` over a block's lifetime,
        and exactly ``error_bound`` for a freshly written row.
    """
    qmax = policy.qmax
    x32 = x.astype(jnp.float32)
    # Per-row per-head requested scale.
    s_req = jnp.max(jnp.abs(x32), axis=-1) / qmax            # (N, Hkv)
    fresh = jnp.zeros(scales.shape[:1], bool).at[write_blocks].max(
        write_offsets == 0)                                  # (P,)
    s_pool0 = jnp.where(fresh[:, None], 0.0, scales)         # (P, Hkv)
    new_scales = s_pool0.at[write_blocks].max(s_req)         # (P, Hkv)

    # Rescale the existing codes of every touched block.  Duplicate
    # write_blocks rows compute identical content, so the unordered
    # scatter is deterministic; fresh blocks have s_pool0 == 0 and
    # their codes collapse to 0 before the new rows land.
    old = codes_pool[write_blocks]                           # (N, Hkv, bs, D)
    vals = policy.decode(old) * s_pool0[write_blocks][..., None, None]
    s_new_b = jnp.maximum(new_scales[write_blocks], _TINY)   # (N, Hkv)
    resc = policy.encode(vals / s_new_b[..., None, None])
    codes_pool = codes_pool.at[write_blocks].set(resc)

    # Write the new rows at the (possibly grown) block scale.
    codes_pool = codes_pool.at[write_blocks, :, write_offsets].set(
        policy.encode(x32 / jnp.maximum(
            new_scales[write_blocks], _TINY)[..., None]))
    return codes_pool, new_scales


# -- property checker --------------------------------------------------------

def check_quant_roundtrip(x, policy: KVQuantPolicy, *, atol: float = 1e-6):
    """Assert per-block absmax quantize/dequantize error stays within
    :meth:`KVQuantPolicy.error_bound` elementwise.

    ``x`` is any float array treated as one block: scale = absmax/qmax
    over the whole array, every element must round-trip to within
    ``error_bound(scale)`` (+ ``atol`` slack for f32 arithmetic).
    Returns ``(dequant, scale, max_err)`` for further inspection.
    """
    x32 = jnp.asarray(x, jnp.float32)
    scale = float(jnp.max(jnp.abs(x32))) / policy.qmax
    s = max(scale, _TINY)
    codes = policy.encode(x32 / s)
    deq = policy.decode(codes) * s
    err = jnp.abs(deq - x32)
    max_err = float(jnp.max(err)) if x32.size else 0.0
    bound = float(policy.error_bound(scale)) + atol
    assert max_err <= bound, (
        f"{policy.name}: round-trip error {max_err} exceeds bound {bound} "
        f"(scale={scale})")
    return deq, scale, max_err
