"""SLO-aware admission policies.

Registered into the same registry as fcfs/sjf/prefill_first
(:mod:`repro.serving.scheduler` imports this module at the bottom of
its definition, so every ``ServeConfig`` validation sees them).  All
three degrade gracefully on plain traffic: with uniform priorities and
no deadlines they reduce to arrival order, so the engine's
policy-invariance tests hold for them too.

* ``priority_strict`` — admit the most urgent class first (HIGH before
  NORMAL before LOW), arrival order within a class.  Pairs with
  preemption (``SLOConfig.preemption``): a HIGH arrival that cannot be
  admitted evicts a lower-class victim.  LOW can starve under sustained
  HIGH load — that is the contract, not a bug.
* ``edf`` — earliest effective deadline first (``deadline_ms``, or
  derived from ``slo_tokens_per_s``); deadline-less requests sort last
  (+inf), arrival order among themselves.  Minimizes lateness when the
  system is feasible; degrades to fcfs when nobody states a deadline.
* ``cache_aware`` — prefer the request with the most *warm* prompt
  tokens: prefix-cache index hits for queued requests, restorable
  context for preempted ones.  Warm admissions prefill in O(blocks)
  table writes instead of O(tokens) compute, so under overload this
  maximizes prefill throughput; ties (including all-cold queues) fall
  back to arrival order.
"""
from __future__ import annotations

from repro.serving.request import Status
from repro.serving.scheduler import AdmissionPolicy, register_policy


@register_policy
class PriorityStrictPolicy(AdmissionPolicy):
    name = "priority_strict"

    def pick(self, waiting, clock_ms, fits, sched=None):
        best = best_key = None
        for i, st in enumerate(waiting):
            r = st.request
            if r.arrival_ms > clock_ms or not fits(st):
                continue
            key = (int(r.priority), r.arrival_ms, r.uid)
            if best is None or key < best_key:
                best, best_key = i, key
        return best


@register_policy
class EDFPolicy(AdmissionPolicy):
    name = "edf"

    def pick(self, waiting, clock_ms, fits, sched=None):
        best = best_key = None
        for i, st in enumerate(waiting):
            r = st.request
            if r.arrival_ms > clock_ms or not fits(st):
                continue
            d = r.effective_deadline_ms
            key = (d if d is not None else float("inf"), r.arrival_ms, r.uid)
            if best is None or key < best_key:
                best, best_key = i, key
        return best


@register_policy
class CacheAwarePolicy(AdmissionPolicy):
    name = "cache_aware"

    def pick(self, waiting, clock_ms, fits, sched=None):
        cache = getattr(sched, "kv_cache", None)
        best = best_key = None
        for i, st in enumerate(waiting):
            r = st.request
            if r.arrival_ms > clock_ms or not fits(st):
                continue
            warm = 0
            if cache is not None:
                if st.status is Status.PREEMPTED:
                    # a preempted request's whole context is warm: its
                    # blocks restore by re-bind or host upload
                    warm = st.swap_record.context_len
                else:
                    warm = cache.warm_prefix_tokens(r.prompt)
            key = (-warm, r.arrival_ms, r.uid)
            if best is None or key < best_key:
                best, best_key = i, key
        return best
