"""Host-side KV block pool for preemption swap-out/swap-in.

Preempting a running request frees its slot and device KV blocks for a
higher-priority request; to resume later without recomputing the whole
context, the victim's block *contents* move to a preallocated host-side
numpy pool and its block-table row is snapshotted into a
:class:`SwapRecord`.  The copy is **refcount-aware**: blocks the slot
merely *binds* from the prefix cache (shared, read-only — table indices
``[0, bound)``) are not copied at all; the record keeps their chain
hashes, and restore re-binds whichever physical block the
:class:`~repro.serving.prefix_cache.PrefixIndex` currently maps each
hash to (content-equal by construction).  Only the slot's *owned*
blocks go device→host.

Restore is the mirror image: re-bind every leading recorded hash that is
still published, upload the remaining host copies into freshly
allocated device blocks, and hand the engine a resume position.  If a
re-bindable prefix block was evicted from the index in the meantime
(a *hole*), the host copies past it are useless on their own — KV at
position ``p`` is only meaningful with all positions before it — so
restore stops at the hole and the engine recomputes the tail by
resume-prefill from the request's confirmed token stream.  Either way
the resumed request is token-identical to an un-preempted run: the
re-bound/uploaded blocks hold exactly the K/V a fresh prefill of those
tokens at those absolute positions would write.

Conservation: a host block is held by exactly one live record; device
and host accounting never overlap (swap-out frees device blocks in the
same step it fills host blocks), so a swapped block counts against
neither the device free list nor any reservation — the extended
scheduler invariant checks exactly that.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.serving.kv_cache import BlockAllocator, PagedKVCache


@dataclasses.dataclass
class SwapRecord:
    """Everything needed to rebuild one preempted slot.

    ``hashes`` covers the slot's committed *full* blocks (bound prefix +
    owned-and-published), in table order; ``host_of`` maps table index
    ``k`` to the host block holding its copy, for every owned block
    (``k >= skip``).  The partial trailing block (if any) has a host
    copy but no hash — it is never publishable.
    """

    uid: int
    total_len: int                    # worst-case footprint to re-reserve
    context_len: int                  # KV positions written at swap-out
    num_blocks: int                   # device blocks held at swap-out
    skip: int                         # leading bound (shared) blocks, not copied
    hashes: List[bytes]               # chain hash per committed full block
    host_of: Dict[int, int]           # table index -> host block id


class SwapManager:
    """Preallocated host-side numpy K/V pools + a free-list allocator
    over them.  Shapes mirror the device pools but host-block-major:
    ``(host_blocks, num_layers, Hkv, block_size, head_dim)``, so one
    record's blocks copy as a single fancy-index slice each way."""

    def __init__(self, cache: PagedKVCache, host_blocks: Optional[int] = None,
                 metrics=None):
        self.host_blocks = int(host_blocks) if host_blocks else cache.num_blocks
        layers, _, hkv, bs, hd = cache.k_pool.shape
        dtype = np.dtype(cache.k_pool.dtype)      # bf16 via ml_dtypes;
        shape = (self.host_blocks, layers, hkv, bs, hd)   # int8 when quantized
        self._k_host = np.zeros(shape, dtype)
        self._v_host = np.zeros(shape, dtype)
        # Quantized caches carry per-(layer, block, head) scale rows; the
        # host copy holds them verbatim so swap-out -> restore is a byte
        # identity — blocks are never re-quantized in flight.
        self._quantized = cache.k_scales is not None
        if self._quantized:
            self._k_scale_host = np.zeros((self.host_blocks, layers, hkv),
                                          np.float32)
            self._v_scale_host = np.zeros_like(self._k_scale_host)
        self.allocator = BlockAllocator(self.host_blocks)
        self.records: Dict[int, SwapRecord] = {}  # uid -> live record
        if metrics is None:
            from repro.obs import MetricsRegistry

            metrics = MetricsRegistry()    # standalone use (tests, tools)
        self.metrics = metrics
        self._prewarm(cache)

    @property
    def stats(self) -> Dict[str, int]:
        """Legacy dict view over the registry counters."""
        m = self.metrics
        return {"swap_outs": int(m.get("swap_outs_total")),
                "swap_ins": int(m.get("swap_ins_total")),
                "swapped_blocks": int(m.get("swap_swapped_blocks_total")),
                "restored_blocks": int(m.get("swap_restored_blocks_total"))}

    @staticmethod
    def _pad_width(cache: PagedKVCache) -> int:
        return cache.block_table.shape[1]

    def _prewarm(self, cache: PagedKVCache) -> None:
        """Compile the fixed-width gather/scatter kernels now, at
        construction, so the ~50ms-per-kernel XLA cost never lands
        inside a serving step (the first preemption would otherwise
        stall by ~0.2s)."""
        idx = np.zeros(self._pad_width(cache), dtype=np.int64)
        kh = np.moveaxis(np.asarray(cache.k_pool[:, idx]), 1, 0)
        vh = np.moveaxis(np.asarray(cache.v_pool[:, idx]), 1, 0)
        # writes block 0's own content back to block 0 — a no-op by value
        cache.k_pool = cache.k_pool.at[:, idx].set(
            jnp.asarray(np.moveaxis(kh, 0, 1)))
        cache.v_pool = cache.v_pool.at[:, idx].set(
            jnp.asarray(np.moveaxis(vh, 0, 1)))
        if self._quantized:
            ksh = np.moveaxis(np.asarray(cache.k_scales[:, idx]), 1, 0)
            cache.k_scales = cache.k_scales.at[:, idx].set(
                jnp.asarray(np.moveaxis(ksh, 0, 1)))
            vsh = np.moveaxis(np.asarray(cache.v_scales[:, idx]), 1, 0)
            cache.v_scales = cache.v_scales.at[:, idx].set(
                jnp.asarray(np.moveaxis(vsh, 0, 1)))

    # -- capacity ------------------------------------------------------------

    def can_store(self, n_blocks: int) -> bool:
        return self.allocator.can_alloc(n_blocks)

    @property
    def used_host_blocks(self) -> int:
        return self.allocator.allocated_count

    # -- device -> host ------------------------------------------------------

    def store(self, cache: PagedKVCache, *, uid: int, total_len: int,
              context_len: int, blocks: Sequence[int], skip: int,
              hashes: Sequence[bytes]) -> SwapRecord:
        """Copy ``blocks[skip:]`` (the slot's owned blocks) to host and
        return the record.  Caller still owns the device blocks — it
        frees them via the cache immediately after."""
        if uid in self.records:
            raise RuntimeError(f"request {uid} already has a live swap record")
        copy_ks = list(range(skip, len(blocks)))
        host_ids = self.allocator.alloc(len(copy_ks))
        if copy_ks:
            # Pad the gather to the fixed per-slot width: XLA caches the
            # kernel on the index vector's *shape*, so a variable-length
            # gather recompiles (~50ms) on every new block count.  The
            # pad entries repeat a real block and are sliced off after
            # the transfer.
            dev = [blocks[k] for k in copy_ks]
            n = len(dev)
            idx = np.asarray(dev + dev[:1] * (self._pad_width(cache) - n),
                             dtype=np.int64)
            # (L, n, Hkv, bs, D) -> host-block-major (n, L, Hkv, bs, D)
            self._k_host[host_ids] = np.moveaxis(
                np.asarray(cache.k_pool[:, idx]), 1, 0)[:n]
            self._v_host[host_ids] = np.moveaxis(
                np.asarray(cache.v_pool[:, idx]), 1, 0)[:n]
            if self._quantized:
                self._k_scale_host[host_ids] = np.moveaxis(
                    np.asarray(cache.k_scales[:, idx]), 1, 0)[:n]
                self._v_scale_host[host_ids] = np.moveaxis(
                    np.asarray(cache.v_scales[:, idx]), 1, 0)[:n]
        rec = SwapRecord(uid=uid, total_len=total_len,
                         context_len=context_len, num_blocks=len(blocks),
                         skip=skip, hashes=list(hashes),
                         host_of=dict(zip(copy_ks, host_ids)))
        self.records[uid] = rec
        self.metrics.counter("swap_outs_total").inc()
        self.metrics.counter("swap_swapped_blocks_total").inc(len(copy_ks))
        return rec

    # -- host -> device ------------------------------------------------------

    def load(self, cache: PagedKVCache,
             pairs: Sequence[Tuple[int, int]]) -> None:
        """Upload host blocks into device blocks: ``pairs`` is
        ``[(host_id, device_id), ...]``."""
        if not pairs:
            return
        n = len(pairs)
        # Same fixed-width trick as ``store``: pad the scatter by
        # repeating the first pair.  Duplicate scatter indices all carry
        # that pair's host content, so the overlap is value-identical
        # and the write order does not matter.
        padded = list(pairs) + [pairs[0]] * (self._pad_width(cache) - n)
        host_ids = np.asarray([h for h, _ in padded], dtype=np.int64)
        dev_ids = np.asarray([d for _, d in padded], dtype=np.int64)
        k = jnp.asarray(np.moveaxis(self._k_host[host_ids], 0, 1))
        v = jnp.asarray(np.moveaxis(self._v_host[host_ids], 0, 1))
        cache.k_pool = cache.k_pool.at[:, dev_ids].set(k)
        cache.v_pool = cache.v_pool.at[:, dev_ids].set(v)
        if self._quantized:
            # scale rows ride along verbatim — no re-quantization on
            # restore, the uploaded bytes decode exactly as stored
            ks = jnp.asarray(np.moveaxis(self._k_scale_host[host_ids], 0, 1))
            vs = jnp.asarray(np.moveaxis(self._v_scale_host[host_ids], 0, 1))
            cache.k_scales = cache.k_scales.at[:, dev_ids].set(ks)
            cache.v_scales = cache.v_scales.at[:, dev_ids].set(vs)
        self.metrics.counter("swap_ins_total").inc()
        self.metrics.counter("swap_restored_blocks_total").inc(n)

    def release(self, rec: SwapRecord) -> None:
        """Return the record's host blocks (after restore, or when the
        request is dropped while preempted)."""
        if self.records.get(rec.uid) is not rec:
            raise RuntimeError(f"release of stale swap record for {rec.uid}")
        if rec.host_of:
            self.allocator.free(list(rec.host_of.values()))
        del self.records[rec.uid]

    # -- invariants ----------------------------------------------------------

    def check_conservation(self) -> None:
        """Host allocator conservation plus record/host-block bijection:
        every allocated host block is held by exactly one live record."""
        self.allocator.check_conservation()
        used: set = set()
        for rec in self.records.values():
            ids = set(rec.host_of.values())
            assert len(ids) == len(rec.host_of), rec.uid
            assert not (ids & used), f"host block shared across records"
            assert all(k >= rec.skip for k in rec.host_of), rec.uid
            used |= ids
        assert len(used) == self.allocator.allocated_count, (
            len(used), self.allocator.allocated_count)
