"""SLO-aware scheduling: priority classes, preemption with KV
swap-to-host, and deadline/cache-aware admission policies.

Importing :mod:`repro.serving.scheduler` registers the policies in this
package (``priority_strict``, ``edf``, ``cache_aware``) alongside the
base fcfs/sjf/prefill_first entries; :class:`SwapManager` is the
host-side block pool preempted requests' KV lives in while they wait.
"""
from repro.serving.slo.swap import SwapManager, SwapRecord

__all__ = ["SwapManager", "SwapRecord"]
