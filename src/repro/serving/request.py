"""Request model for the continuous-batching engine.

A :class:`Request` is what a client submits: prompt tokens, a generation
budget, and an arrival time (milliseconds on the serving clock — 0 for
"already here", or trace-driven Poisson arrivals).  A
:class:`RequestState` is the scheduler's view of one admitted request:
which decode slot it occupies, how far prefill has progressed, and what
has been generated so far.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

import numpy as np


class Status(enum.Enum):
    QUEUED = "queued"      # waiting for a slot / KV blocks
    PREFILL = "prefill"    # admitted; prompt chunks still being ingested
    DECODE = "decode"      # one token per engine step
    FINISHED = "finished"  # evicted; slot and blocks returned


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (prompt_len,) int32 token ids
    max_new_tokens: int
    arrival_ms: float = 0.0
    eos_id: Optional[int] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, dtype=np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError(f"request {self.uid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.uid}: max_new_tokens must be >= 1")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)

    @property
    def total_len(self) -> int:
        """Upper bound on context positions this request can occupy."""
        return self.prompt_len + self.max_new_tokens


@dataclasses.dataclass
class RequestState:
    request: Request
    slot: int = -1
    status: Status = Status.QUEUED
    prefill_pos: int = 0             # prompt tokens already ingested
    cached_tokens: int = 0           # prompt tokens served from the prefix cache
    generated: List[int] = dataclasses.field(default_factory=list)
    admitted_ms: float = 0.0
    admit_seq: int = -1              # admission order (scheduler FCFS tiebreak)
    first_token_ms: Optional[float] = None
    finished_ms: Optional[float] = None

    @property
    def last_token(self) -> int:
        """Token to feed next in decode (the most recent sample)."""
        return self.generated[-1]

    @property
    def context_len(self) -> int:
        """KV positions written so far: prompt prefix + all generated
        tokens that have been fed back (every sample except the newest)."""
        if self.status is Status.PREFILL:
            return self.prefill_pos
        return self.request.prompt_len + max(len(self.generated) - 1, 0)

    def done(self) -> bool:
        if len(self.generated) >= self.request.max_new_tokens:
            return True
        eos = self.request.eos_id
        return eos is not None and len(self.generated) > 0 and self.generated[-1] == eos

    def latency_ms(self) -> Optional[float]:
        if self.finished_ms is None:
            return None
        return self.finished_ms - self.request.arrival_ms
