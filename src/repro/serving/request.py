"""Request model for the continuous-batching engine.

A :class:`Request` is what a client submits: prompt tokens, a generation
budget, an arrival time (milliseconds on the serving clock — 0 for
"already here", or trace-driven Poisson arrivals), and — for SLO-aware
scheduling (``repro.serving.slo``) — a :class:`Priority` class plus an
optional latency target (an absolute ``deadline_ms`` or a
``slo_tokens_per_s`` rate the deadline is derived from).  A
:class:`RequestState` is the scheduler's view of one admitted request:
which decode slot it occupies, how far prefill has progressed, what has
been generated so far, and (under preemption) the host-side swap record
its KV blocks live in while it is off-device.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

import numpy as np


class Priority(enum.IntEnum):
    """Request priority class: lower value = more urgent.  The int
    ordering is what policies and victim selection compare, so a plain
    ``int`` works anywhere a Priority does."""

    HIGH = 0
    NORMAL = 1
    LOW = 2


class Status(enum.Enum):
    QUEUED = "queued"        # waiting for a slot / KV blocks
    PREFILL = "prefill"      # admitted; prompt (or resume) chunks being ingested
    DECODE = "decode"        # one token per engine step
    PREEMPTED = "preempted"  # evicted mid-flight; KV swapped to host, requeued
    FINISHED = "finished"    # evicted; slot and blocks returned
    SHED = "shed"            # rejected at the door: deadline provably unmeetable


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (prompt_len,) int32 token ids
    max_new_tokens: int
    arrival_ms: float = 0.0
    eos_id: Optional[int] = None
    # SLO model (repro.serving.slo): priority class, and at most one way
    # of stating a latency target — an absolute completion deadline, or
    # a sustained token rate the deadline is derived from.
    priority: Priority = Priority.NORMAL
    deadline_ms: Optional[float] = None
    slo_tokens_per_s: Optional[float] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, dtype=np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError(f"request {self.uid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.uid}: max_new_tokens must be >= 1")
        try:
            if isinstance(self.priority, str):
                self.priority = Priority[self.priority.upper()]
            elif not isinstance(self.priority, Priority):
                self.priority = Priority(self.priority)
        except KeyError:
            raise ValueError(
                f"request {self.uid}: unknown priority {self.priority!r}; "
                f"expected one of {[p.name.lower() for p in Priority]}"
            ) from None
        if self.slo_tokens_per_s is not None and self.slo_tokens_per_s <= 0:
            raise ValueError(
                f"request {self.uid}: slo_tokens_per_s must be > 0")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)

    @property
    def total_len(self) -> int:
        """Upper bound on context positions this request can occupy."""
        return self.prompt_len + self.max_new_tokens

    @property
    def effective_deadline_ms(self) -> Optional[float]:
        """The completion deadline the SLO implies: ``deadline_ms`` when
        given, else arrival + the time the worst-case generation takes
        at ``slo_tokens_per_s``, else None (no deadline)."""
        if self.deadline_ms is not None:
            return self.deadline_ms
        if self.slo_tokens_per_s is not None:
            return self.arrival_ms + 1e3 * self.max_new_tokens / self.slo_tokens_per_s
        return None


@dataclasses.dataclass
class RequestState:
    request: Request
    slot: int = -1
    status: Status = Status.QUEUED
    prefill_pos: int = 0             # context tokens already ingested
    cached_tokens: int = 0           # prompt tokens served from the prefix cache
    generated: List[int] = dataclasses.field(default_factory=list)
    admitted_ms: float = 0.0
    admit_seq: int = -1              # admission order (scheduler FCFS tiebreak)
    first_token_ms: Optional[float] = None
    finished_ms: Optional[float] = None
    # SLO scheduling (repro.serving.slo)
    preemptions: int = 0             # times this request was swapped out
    swap_record: Optional[object] = None  # SwapRecord while PREEMPTED

    @property
    def last_token(self) -> int:
        """Token to feed next in decode (the most recent sample)."""
        return self.generated[-1]

    @property
    def confirmed_tokens(self) -> np.ndarray:
        """The token stream behind every KV position this request can
        have written: the prompt plus every generated token that has
        been fed back (all samples except the newest).  This is the
        prefill *stream* too — a restored preempted request re-ingests
        (or re-binds) exactly these tokens, which is why resume is
        token-identical to an un-preempted run."""
        if self.generated:
            return np.concatenate(
                [self.request.prompt,
                 np.asarray(self.generated[:-1], np.int32)])
        return self.request.prompt

    @property
    def prefill_target(self) -> int:
        """Context length at which prefill completes and decode starts:
        the prompt length for a fresh request, the full confirmed stream
        for a preempted request resuming mid-decode."""
        return int(self.confirmed_tokens.size)

    @property
    def context_len(self) -> int:
        """KV positions written so far: prompt prefix + all generated
        tokens that have been fed back (every sample except the newest)."""
        if self.status is Status.PREFILL:
            return self.prefill_pos
        return self.request.prompt_len + max(len(self.generated) - 1, 0)

    def done(self) -> bool:
        if len(self.generated) >= self.request.max_new_tokens:
            return True
        eos = self.request.eos_id
        return eos is not None and len(self.generated) > 0 and self.generated[-1] == eos

    def latency_ms(self) -> Optional[float]:
        if self.finished_ms is None:
            return None
        return self.finished_ms - self.request.arrival_ms

    def slack_ms(self, clock_ms: float) -> float:
        """Time remaining until the request's effective deadline
        (+inf when it has none); negative once the deadline is missed."""
        d = self.request.effective_deadline_ms
        return float("inf") if d is None else d - clock_ms

    def met_deadline(self) -> Optional[bool]:
        """True/False once finished and a deadline exists, else None."""
        d = self.request.effective_deadline_ms
        if d is None or self.finished_ms is None:
            return None
        return self.finished_ms <= d
