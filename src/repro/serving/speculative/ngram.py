"""Prompt-lookup (n-gram) self-drafting.

The cheapest possible drafter: no parameters, no second model.  For
each speculating slot, find the most recent earlier occurrence of the
context's trailing n-gram (longest match first, ``n = max_ngram .. 1``)
and propose the tokens that followed it.  Generation that quotes or
extends its own prompt — code completion, summarisation, retrieval, and
(usefully for synthetic benchmarks) the repetition loops greedy
decoding falls into — gets near-free accepted tokens; novel text just
degrades to ordinary decoding, because a wrong draft costs one verify
row, never correctness.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.serving.speculative import register_drafter
from repro.serving.speculative.base import DraftItem


def lookup_continuation(context: np.ndarray, max_tokens: int,
                        max_ngram: int) -> np.ndarray:
    """Longest-suffix prompt lookup over ``context``; returns up to
    ``max_tokens`` proposed continuation tokens (possibly empty)."""
    context = np.asarray(context).reshape(-1)
    L = context.size
    if max_tokens <= 0 or L < 2:
        return np.empty(0, np.int32)
    for n in range(min(max_ngram, L - 1), 0, -1):
        suffix = context[L - n:]
        windows = np.lib.stride_tricks.sliding_window_view(context, n)
        # candidate starts s <= L - n - 1: strictly earlier than the
        # suffix occurrence itself, so a continuation token exists
        matches = np.flatnonzero((windows[:L - n] == suffix).all(axis=1))
        if matches.size:
            # prefer the most recent match whose continuation can fill
            # the whole draft budget (a match near the context's end
            # would truncate the proposal to a token or two — fatal for
            # cyclic generations, where every period is a match); fall
            # back to the earliest, i.e. longest-continuation, match
            full = matches[matches + n + max_tokens <= L]
            s = int(full[-1]) if full.size else int(matches[0])
            return context[s + n: s + n + max_tokens].astype(np.int32)
    return np.empty(0, np.int32)


@register_drafter
class NgramDrafter:
    name = "ngram"

    def __init__(self, spec, target_cfg, serve, *, seed: int = 0,
                 draft_model=None):
        del target_cfg, serve, seed, draft_model  # stateless, paramless
        self.max_ngram = spec.max_ngram

    def propose(self, items: List[DraftItem]) -> List[np.ndarray]:
        return [lookup_continuation(it.context, it.max_tokens, self.max_ngram)
                for it in items]
