"""Draft-model drafter: a small model proposes greedy continuations.

The draft model is any registered config sharing the target's vocab
(``SpecConfig.draft`` names it; tests and benchmarks may hand an
explicit ``(cfg, params)`` pair instead).  Proposal runs as **one**
jit'd function of static shape ``(max_slots, max_len)``: the slot
contexts are right-padded into a token matrix and the draft model runs
``gamma`` full causal forwards, each appending its argmax next token at
the per-slot frontier.  Right padding is invisible under causal
attention, so logits at the frontier are exact for any mix of context
lengths — and because the drafter is *stateless* (the context arrives
fresh every call), slot reuse and speculative rollback can never
desynchronize it.  A KV-cached draft state (one forward per draft
token instead of ``gamma`` full passes) is the ROADMAP follow-on; at
serving scale the verify step dominates and the target-model step
count, not drafter FLOPs, is what speculation buys down.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.context import MoEContext
from repro.models.registry import get_family
from repro.serving.speculative import register_drafter
from repro.serving.speculative.base import DraftItem


@register_drafter
class ModelDrafter:
    name = "model"

    def __init__(self, spec, target_cfg, serve, *, seed: int = 0,
                 draft_model: Optional[Tuple] = None):
        if draft_model is not None:
            dcfg, dparams = draft_model
        else:
            if spec.draft is None:
                raise ValueError(
                    "the model drafter needs SpecConfig.draft (a registered "
                    "config id) or an explicit draft_model=(cfg, params)")
            from repro.configs.registry import get_smoke_config

            dcfg = get_smoke_config(spec.draft)
            dparams = None
        if dcfg.vocab_size != target_cfg.vocab_size:
            raise ValueError(
                f"draft model vocab {dcfg.vocab_size} != target vocab "
                f"{target_cfg.vocab_size}: speculative decoding verifies "
                f"draft token ids against target logits, the vocabs must "
                f"be shared")
        W = serve.max_len
        if dcfg.max_seq_len < W:
            dcfg = dcfg.replace(max_seq_len=W)
        fam = get_family(dcfg)
        if fam.prefill is None:
            raise ValueError(
                f"model drafter needs a full-forward (transformer-like) "
                f"family, got {dcfg.family!r}")
        if dparams is None:
            from repro.nn import init as init_params

            dparams = init_params(fam.specs(dcfg),
                                  jax.random.PRNGKey(seed ^ 0x5BEC))
        self.cfg = dcfg
        self.params = dparams
        gamma = spec.gamma
        ctx = MoEContext(is_training=False)

        def draft_fn(p, tokens, ctx_len):
            # tokens: (S, W) right-padded contexts; ctx_len: (S,) valid
            # lengths (0 = idle row).  gamma greedy continuations each.
            outs = []
            for i in range(gamma):
                logits, _ = fam.forward(p, {"tokens": tokens}, dcfg, ctx=ctx)
                idx = jnp.clip(ctx_len + i - 1, 0, W - 1)
                lg = jnp.take_along_axis(
                    logits.astype(jnp.float32), idx[:, None, None], axis=1)[:, 0]
                nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                outs.append(nxt)
                # append at the frontier; columns >= W simply never match
                # (draft budgets are clamped so accepted tokens always fit,
                # the tail of an over-long draft is sliced off host-side)
                col = ctx_len + i
                tokens = jnp.where(jnp.arange(W)[None, :] == col[:, None],
                                   nxt[:, None], tokens)
            return jnp.stack(outs, axis=1)        # (S, gamma)

        self._fn = jax.jit(draft_fn)
        self._S, self._W = serve.max_slots, W

    def propose(self, items: List[DraftItem]) -> List[np.ndarray]:
        S, W = self._S, self._W
        tokens = np.zeros((S, W), np.int32)
        ctx_len = np.zeros(S, np.int32)
        for i, it in enumerate(items):
            c = np.asarray(it.context, np.int32).reshape(-1)[-W:]
            tokens[i, :c.size] = c
            ctx_len[i] = c.size
        out = np.asarray(self._fn(self.params, jnp.asarray(tokens),
                                  jnp.asarray(ctx_len)))
        return [out[i, :it.max_tokens].astype(np.int32)
                for i, it in enumerate(items)]
