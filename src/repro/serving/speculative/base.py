"""Drafter contract for the speculative-decoding subsystem.

A drafter proposes cheap continuation tokens for decode slots; the
engine verifies all of them in one step (see
``repro.serving.continuous``) and the acceptance rule
(``speculative.accept``) guarantees correctness whatever the drafter
proposes.  The contract is deliberately host-side and batch-oriented:

* :meth:`Drafter.propose` receives one :class:`DraftItem` per
  *speculating* decode slot — the slot id, the slot's full known
  context (prompt + every generated token, including the newest sample
  that has not yet been written to the KV cache), and the per-slot
  draft budget (``gamma`` clamped to the request's remaining
  generation budget, so draft KV writes never pass ``total_len - 1``
  and the admission-time block reservation covers in-flight drafts).
* It returns one int32 array per item, of length ``<= max_tokens``
  (shorter — including empty — simply means less speculation for that
  slot this step; the engine degrades to ordinary one-token decoding).
* Proposals are *greedy/deterministic* draft tokens: acceptance treats
  the draft distribution as a point mass, which keeps the
  rejection-sampling rule exact for any drafter (a distribution-matched
  draft sampler is a ROADMAP follow-on).

Drafters may keep jit caches and params, but no per-request state: the
context arrives fresh every call, so slot reuse and speculative
rollback can never desynchronize a drafter.
"""
from __future__ import annotations

import dataclasses
from typing import List, Protocol, runtime_checkable

import numpy as np


@dataclasses.dataclass(frozen=True)
class DraftItem:
    """One speculating slot's view for a drafter."""

    slot: int               # decode slot id (for drafters that key stats)
    context: np.ndarray     # (L,) int32: prompt + all generated tokens
    max_tokens: int         # draft budget for this slot this step (>= 1)


@runtime_checkable
class Drafter(Protocol):
    name: str

    def propose(self, items: List[DraftItem]) -> List[np.ndarray]:
        """Return up to ``item.max_tokens`` int32 draft tokens per item."""
        ...
