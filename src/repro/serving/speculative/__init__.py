"""Pluggable speculative-decoding drafters.

``SpecConfig.drafter`` is a key into this registry (mirroring the
router / dispatcher / admission-policy registries).  Built-ins:

* ``ngram`` — prompt-lookup self-drafting: propose the continuation of
  the most recent earlier occurrence of the slot's current context
  suffix (prompt + generated).  No parameters, no extra model — free
  draft tokens wherever generation repeats its own context.
* ``model`` — a small draft model (any registered config sharing the
  target's vocab) proposes greedy continuations via a single jit'd
  full-context forward of static shape ``(max_slots, max_len)``.

A drafter only ever *proposes*; the engine scores all proposals through
one verify step and the acceptance rule (``speculative.accept``) keeps
greedy outputs token-identical to non-speculative decoding and
temperature > 0 outputs distributed exactly as the target model.
Drafters are therefore free to be wrong — a bad drafter costs
throughput, never correctness.

Adding a drafter::

    from repro.serving.speculative import register_drafter

    @register_drafter
    class MyDrafter:
        name = "mine"
        def __init__(self, spec, target_cfg, serve, *, seed=0,
                     draft_model=None): ...
        def propose(self, items):  # List[DraftItem] -> List[np.ndarray]
            ...

Registration must happen before a ``SpecConfig(drafter="mine")`` is
constructed (config validation consults this registry).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Type

from repro.serving.speculative.base import Drafter, DraftItem  # noqa: F401

_REGISTRY: Dict[str, Type] = {}


def register_drafter(cls: Type) -> Type:
    """Class decorator: register a Drafter class under cls.name.

    Unlike routers (stateless singletons), drafters are stateful — the
    model drafter owns params and jit caches — so the registry holds
    *classes* and :func:`make_drafter` instantiates per engine."""
    name = getattr(cls, "name", None)
    if not name or not isinstance(name, str):
        raise ValueError(f"drafter class {cls!r} needs a string `name` attribute")
    _REGISTRY[name] = cls
    return cls


def get_drafter_cls(name: str) -> Type:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown drafter {name!r}; registered drafters: "
            f"{', '.join(available_drafters())}"
        ) from None


def available_drafters() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_drafter(spec, target_cfg, serve, *, seed: int = 0,
                 draft_model: Optional[Tuple] = None) -> Drafter:
    """Instantiate ``spec.drafter`` for one engine.  ``draft_model`` is
    an optional ``(ModelConfig, params)`` override for the model drafter
    (tests/benchmarks hand in tiny configs directly; ``SpecConfig.draft``
    names a registered config otherwise)."""
    return get_drafter_cls(spec.drafter)(spec, target_cfg, serve, seed=seed,
                                         draft_model=draft_model)


# Built-ins self-register on import.
from repro.serving.speculative import model, ngram  # noqa: E402,F401

__all__ = [
    "Drafter", "DraftItem", "register_drafter", "get_drafter_cls",
    "available_drafters", "make_drafter",
]
