"""Acceptance rules for speculative decoding.

One verify step scores ``g + 1`` rows for a slot: row ``j`` holds the
target model's logits for the token *after* context position ``c + j``
(row 0 re-feeds the newest sampled token, rows ``1..g`` feed the draft).
Given the draft ``d[0..g-1]`` (``d[j]`` sits at position ``c + j + 1``
and was predicted by row ``j``):

* **Greedy** (temperature 0): accept the longest prefix with
  ``d[j] == argmax(row j)``; emit ``argmax(row 0..n)`` — the ``n``
  accepted drafts plus one bonus token.  Every emitted token is exactly
  the argmax the non-speculative engine would have produced at that
  position, so greedy speculative output is provably token-identical.
* **Rejection sampling** (temperature > 0, Leviathan et al. 2023 /
  Chen et al. 2023 specialised to deterministic drafts): with the
  draft treated as a point-mass proposal ``q = onehot(d[j])``, accept
  ``d[j]`` with probability ``p[d[j]]``; on rejection sample from the
  residual ``p`` with ``d[j]`` zeroed and renormalised; if every draft
  survives, sample the bonus token from the last row.  Marginally each
  emitted token is distributed exactly as ``p`` — the target
  distribution is preserved for *any* drafter.

Randomness is host-side and keyed per ``(engine seed, slot, absolute
position)`` (``numpy`` Philox via ``SeedSequence``), so temperature > 0
acceptance is reproducible under slot reuse and independent across
slots — the same discipline the engine's on-device per-row sampling
keys follow.
"""
from __future__ import annotations

from typing import Callable, List, Tuple

import numpy as np


def softmax_rows(logits: np.ndarray, temperature: float) -> np.ndarray:
    """Row-wise softmax of ``logits / temperature`` in float64 (host-side
    acceptance math should not add its own rounding to the comparison)."""
    z = logits.astype(np.float64) / float(temperature)
    z -= z.max(axis=-1, keepdims=True)
    e = np.exp(z)
    return e / e.sum(axis=-1, keepdims=True)


def accept_greedy_ids(draft: np.ndarray,
                      argmax_rows: np.ndarray) -> Tuple[List[int], int]:
    """Greedy acceptance from per-row argmax token ids (what the verify
    step ships at temperature 0 — (g+1,) int32s, not (g+1, V) logits).
    Returns (emitted tokens, number of accepted draft tokens)."""
    g = int(np.asarray(draft).size)
    n = 0
    while n < g and int(draft[n]) == int(argmax_rows[n]):
        n += 1
    return [int(argmax_rows[j]) for j in range(n + 1)], n


def accept_greedy(draft: np.ndarray,
                  logits_rows: np.ndarray) -> Tuple[List[int], int]:
    """Returns (emitted tokens, number of accepted draft tokens)."""
    return accept_greedy_ids(draft, np.argmax(logits_rows, axis=-1))


def accept_rejection(draft: np.ndarray, logits_rows: np.ndarray,
                     temperature: float,
                     rng_for_row: Callable[[int], np.random.Generator],
                     ) -> Tuple[List[int], int]:
    """Rejection-sampling acceptance against a point-mass draft.

    ``rng_for_row(j)`` yields the deterministic generator for row ``j``
    (absolute position ``c + j``); the accept test and any residual
    sample for that row both draw from it.
    """
    probs = softmax_rows(logits_rows, temperature)
    V = probs.shape[-1]
    emitted: List[int] = []
    g = int(np.asarray(draft).size)
    for j in range(g):
        d = int(draft[j])
        rng = rng_for_row(j)
        if rng.random() < probs[j, d]:
            emitted.append(d)
            continue
        residual = probs[j].copy()
        residual[d] = 0.0
        s = residual.sum()
        if s <= 0.0:
            # p was (numerically) a point mass on d; rejection of a
            # sure token is a float artifact — emit it
            emitted.append(d)
            continue
        emitted.append(int(rng.choice(V, p=residual / s)))
        return emitted, j
    rng = rng_for_row(g)
    emitted.append(int(rng.choice(V, p=probs[g])))
    return emitted, g
