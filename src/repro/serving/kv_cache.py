"""Block-based paged KV cache for continuous-batching transformer serving.

Instead of one dense ``(B, max_len, Hkv, D)`` slab per batch, K/V live in
a shared pool of fixed-size blocks:

    k_pool, v_pool : (num_layers, P, Hkv, block_size, D)

where ``P = num_blocks + 1`` — the last block is a *garbage* block that
masked (inactive) rows write into, so the jit'd step never needs a
dynamic write mask.  Each decode slot owns an ordered list of pool
blocks; the ``(max_slots, blocks_per_slot)`` block table maps a slot's
logical context position ``p`` to pool coordinates
``(table[slot, p // bs], p % bs)``.  Attention reads straight through
the table (:func:`repro.kernels.decode_attention.paged_decode_attention`),
so blocks never need to be contiguous and freeing is defrag-free: a
freed block goes back on the free list and can be handed to any slot.

The allocator is host-side (plain Python): allocation happens at
admission, outside jit, and only the table *contents* change shape-free
between steps.  Pool layout is head-major ``(..., Hkv, bs, D)`` so the
Pallas kernel DMAs contiguous ``(bs, D)`` tiles per (block, head) and
the per-step write is a single advanced-index scatter.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig


class BlockAllocator:
    """Free-list allocator over ``num_blocks`` block ids with leak and
    double-free detection (serving runs for ever; a leaked block is a
    slow OOM, a double-freed one is silent cross-request corruption)."""

    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._allocated: set = set()

    @property
    def free_count(self) -> int:
        return len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"KV pool exhausted: requested {n} blocks, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        self._allocated.update(out)
        return out

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            if b not in self._allocated:
                raise RuntimeError(f"double-free of KV block {b}")
            self._allocated.remove(b)
            self._free.append(b)

    def check_conservation(self) -> None:
        assert len(self._free) + len(self._allocated) == self.num_blocks, (
            len(self._free), len(self._allocated), self.num_blocks)
        assert not (set(self._free) & self._allocated)


class PagedKVCache:
    """Device block pools + host block table for one model.

    ``slot`` lifecycle: :meth:`allocate_slot` at admission reserves every
    block the request can ever touch (``ceil(total_len / bs)``), so a
    running request can never hit an out-of-blocks condition mid-flight;
    :meth:`free_slot` at eviction returns them.  Stale pool contents need
    no zeroing — attention masks by per-row length, and a reused block is
    overwritten before the slot's length grows past it.
    """

    def __init__(self, cfg: ModelConfig, serve: ServeConfig):
        self.cfg = cfg
        self.serve = serve
        self.block_size = serve.kv_block_size
        self.num_blocks = serve.resolved_num_blocks
        self.garbage_block = self.num_blocks          # index P-1, never allocated
        self.allocator = BlockAllocator(self.num_blocks)
        hd = cfg.resolved_head_dim
        pool_shape = (cfg.num_layers, self.num_blocks + 1, cfg.num_kv_heads,
                      self.block_size, hd)
        dtype = cfg.activation_dtype
        self.k_pool = jnp.zeros(pool_shape, dtype)
        self.v_pool = jnp.zeros(pool_shape, dtype)
        # host-side table; unassigned entries point at the garbage block
        # (always a valid pool index, always masked by length)
        self.block_table = np.full((serve.max_slots, serve.blocks_per_slot),
                                   self.garbage_block, dtype=np.int32)
        self._slot_blocks: Dict[int, List[int]] = {}

    def blocks_needed(self, total_len: int) -> int:
        return -(-total_len // self.block_size)

    def can_allocate_slot(self, total_len: int) -> bool:
        return self.allocator.can_alloc(self.blocks_needed(total_len))

    def allocate_slot(self, slot: int, total_len: int) -> None:
        assert slot not in self._slot_blocks, f"slot {slot} already allocated"
        blocks = self.allocator.alloc(self.blocks_needed(total_len))
        self._slot_blocks[slot] = blocks
        self.block_table[slot, :] = self.garbage_block
        self.block_table[slot, :len(blocks)] = blocks

    def free_slot(self, slot: int) -> None:
        self.allocator.free(self._slot_blocks.pop(slot))
        self.block_table[slot, :] = self.garbage_block

    def write_coords(self, slot: int, position: int) -> Tuple[int, int]:
        """Pool (block, offset) for logical ``position`` of ``slot``."""
        b, o = divmod(position, self.block_size)
        return int(self.block_table[slot, b]), o

    def update_pools(self, k_pool: jax.Array, v_pool: jax.Array) -> None:
        """Adopt the step function's donated-output pools."""
        self.k_pool = k_pool
        self.v_pool = v_pool
