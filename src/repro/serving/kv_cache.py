"""Block-based paged KV cache for continuous-batching transformer serving.

Instead of one dense ``(B, max_len, Hkv, D)`` slab per batch, K/V live in
a shared pool of fixed-size blocks:

    k_pool, v_pool : (num_layers, P, Hkv, block_size, D)

where ``P = num_blocks + 1`` — the last block is a *garbage* block that
masked (inactive) rows write into, so the jit'd step never needs a
dynamic write mask.  Each decode slot owns an ordered list of pool
blocks; the ``(max_slots, blocks_per_slot)`` block table maps a slot's
logical context position ``p`` to pool coordinates
``(table[slot, p // bs], p % bs)``.  Attention reads straight through
the table (:func:`repro.kernels.decode_attention.paged_decode_attention`),
so blocks never need to be contiguous and freeing is defrag-free: a
freed block goes back on the free list and can be handed to any slot.

Blocks are **reserved** at admission but **allocated on demand**:
:meth:`allocate_slot` records the request's worst-case footprint
(``ceil(total_len / bs)`` blocks) against the pool without touching the
free list, and :meth:`ensure_capacity` pulls physical blocks as the
slot's written length actually grows.  Reservation accounting keeps the
original no-mid-flight-starvation guarantee — admission only succeeds
while ``sum(reserved) + new <= num_blocks``, so a running slot's growth
can never find the free list empty — while on-demand allocation means a
slot holds only the blocks behind its *current* length.  That is what
makes speculative-decoding rollback cheap: rejected draft positions are
undone by :meth:`truncate_slot`, which rewinds the slot's length and
returns any block that no longer backs a written position to the free
list (no copying — the table indirection already decouples logical
position from storage).

The allocator is host-side (plain Python): allocation happens at
admission/growth, outside jit, and only the table *contents* change
shape-free between steps.  Pool layout is head-major ``(..., Hkv, bs, D)``
so the Pallas kernel DMAs contiguous ``(bs, D)`` tiles per (block, head)
and the per-step write is a single advanced-index scatter.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig


class BlockAllocator:
    """Free-list allocator over ``num_blocks`` block ids with leak and
    double-free detection (serving runs for ever; a leaked block is a
    slow OOM, a double-freed one is silent cross-request corruption)."""

    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._allocated: set = set()

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def allocated_count(self) -> int:
        return len(self._allocated)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"KV pool exhausted: requested {n} blocks, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        self._allocated.update(out)
        return out

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            if b not in self._allocated:
                raise RuntimeError(f"double-free of KV block {b}")
            self._allocated.remove(b)
            self._free.append(b)

    def check_conservation(self) -> None:
        assert len(self._free) + len(self._allocated) == self.num_blocks, (
            len(self._free), len(self._allocated), self.num_blocks)
        assert not (set(self._free) & self._allocated)


class PagedKVCache:
    """Device block pools + host block table for one model.

    ``slot`` lifecycle: :meth:`allocate_slot` at admission *reserves*
    every block the request can ever touch (``ceil(total_len / bs)``,
    which bounds in-flight speculative draft positions too — the engine
    clamps per-slot drafts to the remaining generation budget, so a
    draft row never writes past ``total_len - 1``); :meth:`ensure_capacity`
    allocates physical blocks as the written length grows;
    :meth:`truncate_slot` rewinds it (speculative rollback);
    :meth:`free_slot` at eviction returns blocks and reservation alike.
    Stale pool contents need no zeroing — attention masks by per-row
    length, and a reused position is overwritten before the slot's
    length grows past it.
    """

    num_shards = 1   # ShardedPagedKVCache overrides; schedulers branch on it
    # Per-block scale pools: None for full-precision caches; the
    # quantized variants (repro.quant.kv_cache) allocate (L, P, Hkv)
    # float32 absmax scales indexed by the same block ids as the pools.
    k_scales = None
    v_scales = None

    def __init__(self, cfg: ModelConfig, serve: ServeConfig):
        self.cfg = cfg
        self.serve = serve
        self.block_size = serve.kv_block_size
        self.num_blocks = serve.resolved_num_blocks
        self.garbage_block = self.num_blocks          # index P-1, never allocated
        self.allocator = BlockAllocator(self.num_blocks)
        self._alloc_pools(cfg, serve)
        # host-side table; unassigned entries point at the garbage block
        # (always a valid pool index, always masked by length)
        self.block_table = np.full((serve.max_slots, serve.blocks_per_slot),
                                   self.garbage_block, dtype=np.int32)
        self._slot_blocks: Dict[int, List[int]] = {}
        self._slot_reserved: Dict[int, int] = {}      # worst-case block count
        self.reserved_total = 0

    def _alloc_pools(self, cfg: ModelConfig, serve: ServeConfig) -> None:
        """Create the device pools.  The quantized variants override
        this with int8 code pools plus float32 scale pools."""
        hd = cfg.resolved_head_dim
        pool_shape = (cfg.num_layers, self.num_blocks + 1, cfg.num_kv_heads,
                      self.block_size, hd)
        dtype = cfg.activation_dtype
        self.k_pool = jnp.zeros(pool_shape, dtype)
        self.v_pool = jnp.zeros(pool_shape, dtype)

    @property
    def block_bytes(self) -> int:
        """Device bytes one KV block costs across all layers (K + V).
        Computed from the config (not the live pools) so detached
        sub-caches report it too."""
        cfg = self.cfg
        per_entry = (cfg.num_kv_heads * self.block_size
                     * cfg.resolved_head_dim)
        return 2 * cfg.num_layers * per_entry * cfg.activation_dtype.itemsize

    def blocks_needed(self, total_len: int) -> int:
        return -(-total_len // self.block_size)

    @property
    def max_request_blocks(self) -> int:
        """Largest worst-case footprint any single request may reserve.
        The whole pool here; one *shard's* pool under a sharded cache
        (a request lives entirely on the shard that owns its slot)."""
        return self.num_blocks

    def can_allocate_slot_on(self, slot: int, total_len: int, prompt=None) -> bool:
        """Admission gate for a *specific* slot.  All slots draw on the
        one pool here, so the slot is irrelevant; the sharded cache
        routes to the allocator of the shard owning ``slot``."""
        return self.can_allocate_slot(total_len, prompt=prompt)

    def row_table(self, slot: int) -> np.ndarray:
        """Block-table row the jit'd step should attend through for
        ``slot`` — pool-local ids here, *shard-local* ids under a
        sharded cache (each shard_map body indexes its own pool slice)."""
        return self.block_table[slot]

    def detach_pools(self) -> None:
        """Drop this cache's device pools.  Used by the sharded cache,
        which owns one stacked global pool and keeps sub-caches for host
        accounting (tables, allocators, reservations) only."""
        self.k_pool = self.v_pool = None
        self.k_scales = self.v_scales = None

    def can_allocate_slot(self, total_len: int, prompt=None) -> bool:
        """Admission gate: does the pool have unreserved room for this
        request's worst-case footprint?  Gating on *reservations* (not
        the free list) preserves the no-starvation invariant under
        on-demand allocation: every admitted slot can always grow to its
        reserved bound.  ``prompt`` is ignored here; the prefix-caching
        subclass matches it against cached blocks and charges only the
        unshared footprint."""
        return (self.reserved_total + self.blocks_needed(total_len)
                <= self.num_blocks)

    def allocate_slot(self, slot: int, total_len: int, prompt=None) -> int:
        """Reserve ``slot``'s worst-case footprint.  Returns the number
        of prompt tokens already backed by cached KV blocks — always 0
        here; ``PrefixCachingKVCache`` binds matched blocks and returns
        how much prefill can be skipped."""
        assert slot not in self._slot_reserved, f"slot {slot} already allocated"
        need = self.blocks_needed(total_len)
        if self.reserved_total + need > self.num_blocks:
            raise RuntimeError(
                f"KV pool over-reserved: slot {slot} needs {need} blocks, "
                f"{self.num_blocks - self.reserved_total} unreserved")
        self._slot_reserved[slot] = need
        self.reserved_total += need
        self._slot_blocks[slot] = []
        self.block_table[slot, :] = self.garbage_block
        return 0

    def commit(self, slot: int, tokens) -> None:
        """Confirm the token contents behind ``slot``'s written
        positions.  A no-op without prefix caching; the prefix-caching
        subclass publishes newly full blocks into its content index."""

    def free_slot(self, slot: int) -> None:
        blocks = self._slot_blocks.pop(slot)
        if blocks:
            self.allocator.free(blocks)
        self.reserved_total -= self._slot_reserved.pop(slot)
        self.block_table[slot, :] = self.garbage_block

    def ensure_capacity(self, slot: int, length: int) -> None:
        """Allocate any missing physical blocks so positions
        ``[0, length)`` of ``slot`` are backed.  Never exceeds the
        slot's admission-time reservation (the growth that reservation
        guarantees can always be satisfied)."""
        need = self.blocks_needed(length)
        held = self._slot_blocks[slot]
        assert need <= self._slot_reserved[slot], (
            f"slot {slot}: length {length} needs {need} blocks, "
            f"reserved only {self._slot_reserved[slot]}")
        if need > len(held):
            new = self.allocator.alloc(need - len(held))
            self.block_table[slot, len(held):need] = new
            held.extend(new)

    def truncate_slot(self, slot: int, new_len: int) -> None:
        """Speculative rollback: rewind ``slot`` so only positions
        ``[0, new_len)`` are considered written.  Blocks past the new
        length (over-allocated for rejected draft positions) return to
        the free list; the reservation is untouched (the request is
        still running and may grow back).  No data moves — the next
        write at a rewound position simply overwrites stale K/V, which
        per-row lengths already mask until then."""
        keep = self.blocks_needed(new_len) if new_len > 0 else 0
        held = self._slot_blocks[slot]
        if keep < len(held):
            self.allocator.free(held[keep:])
            self.block_table[slot, keep:] = self.garbage_block
            del held[keep:]

    def write_coords(self, slot: int, position: int) -> Tuple[int, int]:
        """Pool (block, offset) for logical ``position`` of ``slot``."""
        b, o = divmod(position, self.block_size)
        return int(self.block_table[slot, b]), o

    # -- preemption swap hooks (repro.serving.slo) ---------------------------

    def warm_prefix_tokens(self, prompt) -> int:
        """Prompt tokens already backed by cached KV (the ``cache_aware``
        admission signal).  Always 0 without prefix caching."""
        return 0

    def swap_footprint(self, slot: int) -> int:
        """Host blocks a swap-out of ``slot`` would consume (owned
        blocks only; the prefix subclass excludes bound shared blocks)."""
        return len(self._slot_blocks[slot])

    def swap_out(self, slot: int, swap, *, uid: int, total_len: int,
                 context_len: int):
        """Copy ``slot``'s blocks into the host pool and release the
        slot (blocks, reservation, table row).  Returns the
        :class:`~repro.serving.slo.swap.SwapRecord` restore needs."""
        rec = swap.store(self, uid=uid, total_len=total_len,
                         context_len=context_len,
                         blocks=list(self._slot_blocks[slot]),
                         skip=0, hashes=[])
        self.free_slot(slot)
        return rec

    def can_restore(self, rec) -> bool:
        """Admission gate for a preempted request: same reservation test
        as a fresh request of the recorded worst-case footprint."""
        return self.can_allocate_slot(rec.total_len)

    def restore_slot(self, slot: int, rec, swap) -> int:
        """Rebuild ``slot`` from a swap record: re-reserve the worst-case
        footprint, allocate device blocks for the recorded context, and
        upload the host copies.  Returns the resume position (always the
        full recorded context here; the prefix subclass may return less
        when an evicted shared block forces recompute-by-prefill).  The
        caller releases ``rec``'s host blocks afterwards."""
        self.allocate_slot(slot, rec.total_len)
        self.ensure_capacity(slot, rec.context_len)
        held = self._slot_blocks[slot]
        swap.load(self, [(rec.host_of[k], held[k])
                         for k in range(rec.num_blocks)])
        return rec.context_len

    def held_blocks(self, slot: int) -> int:
        return len(self._slot_blocks.get(slot, ()))

    def check_conservation(self) -> None:
        """Allocator conservation plus reservation/table invariants:
        held <= reserved per slot, total reservation within the pool,
        and no table row dangles (entries beyond a slot's held blocks
        point at the garbage block; entries within match its blocks)."""
        self.allocator.check_conservation()
        held_total = 0
        for slot, blocks in self._slot_blocks.items():
            held_total += len(blocks)
            assert len(blocks) <= self._slot_reserved[slot], (slot, blocks)
            assert list(self.block_table[slot, :len(blocks)]) == blocks
            assert (self.block_table[slot, len(blocks):]
                    == self.garbage_block).all()
        assert held_total == self.allocator.allocated_count
        assert self.reserved_total == sum(self._slot_reserved.values())
        assert self.reserved_total <= self.num_blocks
        # every slot with no state has an all-garbage table row
        for slot in range(self.block_table.shape[0]):
            if slot not in self._slot_blocks:
                assert (self.block_table[slot] == self.garbage_block).all()

    def update_pools(self, k_pool: jax.Array, v_pool: jax.Array,
                     k_scales=None, v_scales=None) -> None:
        """Adopt the step function's donated-output pools (and scale
        pools, when quantized)."""
        self.k_pool = k_pool
        self.v_pool = v_pool
        if k_scales is not None:
            self.k_scales = k_scales
            self.v_scales = v_scales

    def occupancy(self) -> list:
        """Per-shard block occupancy for the metrics registry: one dict
        per shard with ``free``/``live``/``cached``/``reserved`` block
        counts plus ``block_bytes``, the per-block device cost (bytes
        across all layers, K + V + scales) — counts x ``block_bytes``
        is the pool's byte footprint.  ``cached`` is the refcounted
        prefix allocator's cached-LRU population (0 for the plain
        allocator)."""
        a = self.allocator
        return [{
            "free": a.free_count,
            "live": (getattr(a, "allocated_count", 0)
                     + getattr(a, "live_count", 0)),
            "cached": getattr(a, "cached_count", 0),
            "reserved": self.reserved_total,
            "block_bytes": self.block_bytes,
        }]


class ShardedPagedKVCache:
    """D per-shard caches behind the single-cache interface.

    The mesh's data axis partitions slots *contiguously* — slot ``s``
    lives on shard ``s // slots_per_shard`` — and each shard runs its own
    allocator (:class:`BlockAllocator`, or the refcounted prefix-caching
    one when ``serve.prefix_cache``) over a private pool slice with its
    own garbage block.  Block ids in tables, write coords and the step's
    row buffers are therefore **shard-local**: exactly what each
    shard_map body needs to index its ``(shard_blocks + 1, ...)`` pool
    slice, and structurally what keeps any unsharded ``(num_blocks, ...)``
    pool out of the mapped computation.

    Admission invariants hold at both levels.  Per shard, each sub-cache
    enforces its own reservation bound (``reserved <= shard_blocks``),
    so a shard's running slots can never starve on their own free list
    no matter what other shards do.  In aggregate, this class's
    :meth:`check_conservation` re-asserts the summed invariants.  The
    :class:`~repro.serving.scheduler.Scheduler` keeps the *global*
    admission view: it probes :meth:`can_allocate_slot_on` per free slot,
    so a request is admitted iff some shard with a free slot has room.

    The device pools live *here*, stacked over shards:
    ``(num_layers, D * (shard_blocks + 1), Hkv, bs, hd)``, shard ``d``
    owning rows ``[d * (shard_blocks+1), (d+1) * (shard_blocks+1))`` with
    its garbage block last in its slice.  Sub-caches run detached
    (host accounting only).

    Not supported with data sharding: KV swap-to-host preemption (the
    swap pool is single-device) — the engine rejects ``serve.slo`` with
    preemption before construction, and the hooks here raise.
    """

    def __init__(self, cfg: ModelConfig, serve: ServeConfig):
        import dataclasses

        d = serve.data_shards
        self.cfg = cfg
        self.serve = serve
        self.num_shards = d
        self.block_size = serve.kv_block_size
        self.num_blocks = serve.resolved_num_blocks
        self.slots_per_shard = serve.max_slots // d
        self.shard_blocks = self.num_blocks // d
        # shard-local garbage index: last row of each shard's pool slice
        self.garbage_block = self.shard_blocks
        sub_serve = dataclasses.replace(
            serve, mesh=None, max_slots=self.slots_per_shard,
            num_blocks=self.shard_blocks)
        quantized = getattr(serve, "kv_quant", "none") != "none"
        if serve.prefix_cache:
            if quantized:
                from repro.quant.kv_cache import QuantizedPrefixCachingKVCache
                sub_cls = QuantizedPrefixCachingKVCache
            else:
                from repro.serving.prefix_cache import PrefixCachingKVCache
                sub_cls = PrefixCachingKVCache
        elif quantized:
            from repro.quant.kv_cache import QuantizedPagedKVCache
            sub_cls = QuantizedPagedKVCache
        else:
            sub_cls = PagedKVCache
        self.shards = [sub_cls(cfg, sub_serve) for _ in range(d)]
        for s in self.shards:
            s.detach_pools()
        hd = cfg.resolved_head_dim
        rows = d * (self.shard_blocks + 1)
        pool_shape = (cfg.num_layers, rows, cfg.num_kv_heads,
                      self.block_size, hd)
        if quantized:
            self.k_pool = jnp.zeros(pool_shape, jnp.int8)
            self.v_pool = jnp.zeros(pool_shape, jnp.int8)
            self.k_scales = jnp.zeros(
                (cfg.num_layers, rows, cfg.num_kv_heads), jnp.float32)
            self.v_scales = jnp.zeros_like(self.k_scales)
        else:
            dtype = cfg.activation_dtype
            self.k_pool = jnp.zeros(pool_shape, dtype)
            self.v_pool = jnp.zeros(pool_shape, dtype)
            self.k_scales = self.v_scales = None

    def _loc(self, slot: int) -> Tuple[int, int]:
        """(shard, shard-local slot) for a global slot id."""
        return divmod(slot, self.slots_per_shard)

    def shard_of(self, slot: int) -> int:
        return slot // self.slots_per_shard

    # -- admission / accounting (scheduler-facing) ---------------------------

    @property
    def max_request_blocks(self) -> int:
        return self.shard_blocks

    @property
    def reserved_total(self) -> int:
        return sum(s.reserved_total for s in self.shards)

    def blocks_needed(self, total_len: int) -> int:
        return -(-total_len // self.block_size)

    def can_allocate_slot(self, total_len: int, prompt=None) -> bool:
        """True when *some* shard has room (slot-blind compatibility
        view; the scheduler uses :meth:`can_allocate_slot_on`)."""
        return any(s.can_allocate_slot(total_len, prompt=prompt)
                   for s in self.shards)

    def can_allocate_slot_on(self, slot: int, total_len: int, prompt=None) -> bool:
        d, _ = self._loc(slot)
        return self.shards[d].can_allocate_slot(total_len, prompt=prompt)

    def allocate_slot(self, slot: int, total_len: int, prompt=None) -> int:
        d, ls = self._loc(slot)
        return self.shards[d].allocate_slot(ls, total_len, prompt=prompt)

    def commit(self, slot: int, tokens) -> None:
        d, ls = self._loc(slot)
        self.shards[d].commit(ls, tokens)

    def committed_blocks(self, slot: int) -> int:
        d, ls = self._loc(slot)
        return self.shards[d].committed_blocks(ls)

    def free_slot(self, slot: int) -> None:
        d, ls = self._loc(slot)
        self.shards[d].free_slot(ls)

    def ensure_capacity(self, slot: int, length: int) -> None:
        d, ls = self._loc(slot)
        self.shards[d].ensure_capacity(ls, length)

    def truncate_slot(self, slot: int, new_len: int) -> None:
        d, ls = self._loc(slot)
        self.shards[d].truncate_slot(ls, new_len)

    def write_coords(self, slot: int, position: int) -> Tuple[int, int]:
        """Shard-local (block, offset): the step's scatter and attention
        run under shard_map, where each body sees only its pool slice."""
        d, ls = self._loc(slot)
        return self.shards[d].write_coords(ls, position)

    def row_table(self, slot: int) -> np.ndarray:
        d, ls = self._loc(slot)
        return self.shards[d].row_table(ls)

    def held_blocks(self, slot: int) -> int:
        d, ls = self._loc(slot)
        return self.shards[d].held_blocks(ls)

    def warm_prefix_tokens(self, prompt) -> int:
        return max(s.warm_prefix_tokens(prompt) for s in self.shards)

    @property
    def stats(self):
        """Summed prefix-cache counters across shards."""
        totals: Dict[str, int] = {}
        for s in self.shards:
            for k, v in s.stats.items():
                totals[k] = totals.get(k, 0) + v
        return totals

    def occupancy(self) -> list:
        """Per-shard occupancy — one entry per private allocator."""
        return [d for s in self.shards for d in s.occupancy()]

    # -- preemption swap hooks: unsupported under data sharding --------------

    def swap_footprint(self, slot: int) -> int:
        raise NotImplementedError(
            "KV swap-to-host preemption is not supported on a sharded cache")

    def swap_out(self, slot, swap, *, uid, total_len, context_len):
        raise NotImplementedError(
            "KV swap-to-host preemption is not supported on a sharded cache")

    def can_restore(self, rec) -> bool:
        raise NotImplementedError(
            "KV swap-to-host preemption is not supported on a sharded cache")

    def restore_slot(self, slot, rec, swap) -> int:
        raise NotImplementedError(
            "KV swap-to-host preemption is not supported on a sharded cache")

    def check_conservation(self) -> None:
        """Every shard's full invariant suite, then the aggregate view:
        summed reservations within the global pool and summed
        free/allocated conservation across per-shard allocators."""
        for s in self.shards:
            s.check_conservation()
        assert self.reserved_total <= self.num_blocks
        free = live = cached = 0
        for s in self.shards:
            a = s.allocator
            free += a.free_count
            # plain allocator: allocated; refcounted: live + cached-LRU
            live += getattr(a, "allocated_count", 0) + getattr(a, "live_count", 0)
            cached += getattr(a, "cached_count", 0)
        assert free + live + cached == self.num_blocks, (
            free, live, cached, self.num_blocks)
        if self.k_scales is not None:
            # scale-pool / code-pool bijection over the stacked rows:
            # shard_map splits both along the same row axis, so every
            # shard-local block id indexes its codes and its scale
            assert self.k_scales.shape == self.k_pool.shape[:2] + (
                self.k_pool.shape[2],), (self.k_scales.shape,
                                         self.k_pool.shape)
            assert self.v_scales.shape == self.k_scales.shape

    def update_pools(self, k_pool: jax.Array, v_pool: jax.Array,
                     k_scales=None, v_scales=None) -> None:
        self.k_pool = k_pool
        self.v_pool = v_pool
        if k_scales is not None:
            self.k_scales = k_scales
            self.v_scales = v_scales


def make_kv_cache(cfg: ModelConfig, serve: ServeConfig):
    """Select and build the cache variant ``serve`` asks for: the
    sharded composition when ``serve.mesh`` is set, prefix caching when
    ``serve.prefix_cache``, and the quantized pools when
    ``serve.kv_quant != "none"`` — all eight combinations compose.
    Lazy imports keep the plain paged cache importable on its own."""
    if serve.mesh is not None:
        return ShardedPagedKVCache(cfg, serve)
    quantized = getattr(serve, "kv_quant", "none") != "none"
    if serve.prefix_cache:
        if quantized:
            from repro.quant.kv_cache import QuantizedPrefixCachingKVCache
            return QuantizedPrefixCachingKVCache(cfg, serve)
        from repro.serving.prefix_cache import PrefixCachingKVCache
        return PrefixCachingKVCache(cfg, serve)
    if quantized:
        from repro.quant.kv_cache import QuantizedPagedKVCache
        return QuantizedPagedKVCache(cfg, serve)
    return PagedKVCache(cfg, serve)
