"""Request traces: JSONL loading, synthetic Poisson generation, and a
static-batching trace runner for comparison against the continuous engine.

Trace format (one JSON object per line):

    {"prompt_len": 24, "gen_len": 48, "arrival_ms": 130.5}

plus, for SLO workloads, optional ``"priority"`` ("high" | "normal" |
"low", or the int class value) and ``"deadline_ms"`` fields.

Prompt *contents* are synthesized deterministically from the request uid
(serving cost does not depend on token values), so a trace file carries
only shapes and timing — easy to share, easy to generate.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.request import Priority, Request


def _prompt_tokens(uid: int, prompt_len: int, vocab_size: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed * 100003 + uid)
    return rng.integers(0, vocab_size, size=prompt_len, dtype=np.int64).astype(np.int32)


def load_trace(path: str, vocab_size: int, seed: int = 0) -> List[Request]:
    reqs = []
    with open(path) as f:
        for uid, line in enumerate(l for l in f if l.strip()):
            d = json.loads(line)
            dl = d.get("deadline_ms")
            reqs.append(Request(
                uid=uid,
                prompt=_prompt_tokens(uid, int(d["prompt_len"]), vocab_size, seed),
                max_new_tokens=int(d["gen_len"]),
                arrival_ms=float(d.get("arrival_ms", 0.0)),
                priority=d.get("priority", Priority.NORMAL),
                deadline_ms=float(dl) if dl is not None else None))
    # the scheduler queue is FCFS in list order: an out-of-order trace
    # file must not let a late arrival block (or fast-forward past) an
    # earlier one
    reqs.sort(key=lambda r: (r.arrival_ms, r.uid))
    return reqs


def synthetic_trace(num_requests: int, vocab_size: int, *, seed: int = 0,
                    qps: float = 50.0, prompt_lens: Tuple[int, int] = (8, 48),
                    gen_lens: Tuple[int, ...] = (4, 8, 16, 64),
                    ) -> List[Request]:
    """Poisson arrivals at ``qps``, uniform prompt lengths in
    ``prompt_lens``, generation lengths drawn from the (deliberately
    long-tailed) ``gen_lens`` choices — the mixed-length workload where
    static lockstep batching pays the whole batch for its longest member.
    """
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1000.0 / qps, size=num_requests))
    reqs = []
    for uid in range(num_requests):
        p = int(rng.integers(prompt_lens[0], prompt_lens[1] + 1))
        g = int(rng.choice(gen_lens))
        reqs.append(Request(
            uid=uid, prompt=_prompt_tokens(uid, p, vocab_size, seed),
            max_new_tokens=g, arrival_ms=float(arrivals[uid])))
    return reqs


def synthetic_multitenant(num_requests: int, vocab_size: int, *, seed: int = 0,
                          qps: float = 50.0, num_tenants: int = 4,
                          system_prompt_len: int = 48,
                          suffix_lens: Tuple[int, int] = (2, 12),
                          gen_lens: Tuple[int, ...] = (4, 8, 16),
                          ) -> List[Request]:
    """Poisson arrivals where every request belongs to one of
    ``num_tenants`` tenants and opens with that tenant's fixed
    ``system_prompt_len``-token system prompt, followed by a short
    per-request suffix (uniform length in ``suffix_lens``).  This is the
    workload prefix caching targets: the long shared head is identical
    across a tenant's requests, so after one cold prefill every later
    request can bind the cached system-prompt blocks and prefill only
    its suffix.  Tenant assignment round-robins over arrival order so
    every tenant's prompt stays warm under LRU eviction.

    System prompts are deterministic in ``(seed, tenant)`` and suffixes
    in ``(seed, uid)`` (via :func:`_prompt_tokens` with negated/offset
    uids), so two traces built with the same arguments carry identical
    token contents — the property warm-vs-cold identity tests rely on.
    """
    if num_tenants < 1:
        raise ValueError("synthetic_multitenant: num_tenants must be >= 1")
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1000.0 / qps, size=num_requests))
    # tenant system prompts: uid-space disjoint from per-request suffixes
    systems = [_prompt_tokens(10**9 + t, system_prompt_len, vocab_size, seed)
               for t in range(num_tenants)]
    reqs = []
    for uid in range(num_requests):
        s = int(rng.integers(suffix_lens[0], suffix_lens[1] + 1))
        g = int(rng.choice(gen_lens))
        suffix = _prompt_tokens(uid, s, vocab_size, seed)
        reqs.append(Request(
            uid=uid,
            prompt=np.concatenate([systems[uid % num_tenants], suffix]),
            max_new_tokens=g, arrival_ms=float(arrivals[uid])))
    return reqs


def synthetic_priority(num_requests: int, vocab_size: int, *, seed: int = 0,
                       qps: float = 20.0, burst_qps: Optional[float] = None,
                       burst_len: int = 8,
                       prompt_lens: Tuple[int, int] = (8, 32),
                       gen_lens: Tuple[int, ...] = (4, 8, 16, 32),
                       class_weights: Tuple[float, float, float] = (0.25, 0.5, 0.25),
                       gen_lens_by_class: Optional[Dict[Priority, Tuple[int, ...]]] = None,
                       deadline_budgets: Optional[Dict[Priority, Tuple[float, float]]] = None,
                       system_prompt_len: int = 0, num_tenants: int = 2,
                       ) -> List[Request]:
    """Bursty mixed-priority overload: the SLO-scheduling workload.

    Arrivals are Poisson with a rate that alternates every ``burst_len``
    requests between ``burst_qps`` (default ``4 * qps``) and ``qps`` —
    sustained bursts are what collapse tail latency under fcfs, and what
    preemption degrades gracefully.  Each request draws a
    :class:`Priority` from ``class_weights`` (HIGH, NORMAL, LOW order).
    ``gen_lens_by_class`` overrides ``gen_lens`` per class — the
    classic shape is short interactive HIGH requests against long batch
    LOW ones, which is exactly where priority scheduling pays.
    ``deadline_budgets`` maps a class to ``(base_ms, per_token_ms)``; a
    request of that class gets ``deadline_ms = arrival + base +
    per_token * gen_len``.  The default gives HIGH a tight budget,
    NORMAL a loose one, LOW none (best-effort).  With
    ``system_prompt_len > 0`` every prompt opens with one of
    ``num_tenants`` shared tenant prefixes (same uid-space convention as
    :func:`synthetic_multitenant`), which is what gives ``cache_aware``
    admission something to prefer.  Deterministic in ``seed``.
    """
    if deadline_budgets is None:
        deadline_budgets = {Priority.HIGH: (400.0, 40.0),
                            Priority.NORMAL: (2000.0, 120.0)}
    rng = np.random.default_rng(seed)
    burst_qps = burst_qps if burst_qps is not None else 4.0 * qps
    classes = [Priority.HIGH, Priority.NORMAL, Priority.LOW]
    weights = np.asarray(class_weights, np.float64)
    weights = weights / weights.sum()
    systems = [_prompt_tokens(10**9 + t, system_prompt_len, vocab_size, seed)
               for t in range(num_tenants)] if system_prompt_len > 0 else None
    reqs = []
    t = 0.0
    for uid in range(num_requests):
        rate = burst_qps if (uid // burst_len) % 2 == 0 else qps
        t += float(rng.exponential(1000.0 / rate))
        pri = classes[int(rng.choice(3, p=weights))]
        p = int(rng.integers(prompt_lens[0], prompt_lens[1] + 1))
        g = int(rng.choice((gen_lens_by_class or {}).get(pri, gen_lens)))
        prompt = _prompt_tokens(uid, p, vocab_size, seed)
        if systems is not None:
            prompt = np.concatenate([systems[uid % num_tenants], prompt])
        budget = deadline_budgets.get(pri)
        deadline = (t + budget[0] + budget[1] * g) if budget else None
        reqs.append(Request(uid=uid, prompt=prompt, max_new_tokens=g,
                            arrival_ms=t, priority=pri, deadline_ms=deadline))
    return reqs


def save_trace(path: str, requests: List[Request]) -> None:
    with open(path, "w") as f:
        for r in requests:
            d = {"prompt_len": r.prompt_len,
                 "gen_len": r.max_new_tokens,
                 "arrival_ms": r.arrival_ms}
            if r.priority is not Priority.NORMAL:
                d["priority"] = r.priority.name.lower()
            if r.deadline_ms is not None:
                d["deadline_ms"] = r.deadline_ms
            f.write(json.dumps(d) + "\n")


def static_max_len(requests: List[Request]) -> int:
    """Cache bound for serving ``requests`` with the lockstep engine: a
    group can pair the longest *prompt* with another request's longest
    *gen* (dynamic_update_slice would silently clamp past a smaller
    cache)."""
    return (max(r.prompt_len for r in requests)
            + max(r.max_new_tokens for r in requests) + 1)


def latency_stats(lats: List[float], total_ms: float, generated: int
                  ) -> Dict[str, float]:
    """Shared serving metrics: one definition so the static and
    continuous engines' reported numbers stay comparable."""
    lats = sorted(lats)
    return {
        "total_ms": total_ms,
        "generated_tokens": float(generated),
        "generated_tokens_per_s": generated / max(total_ms / 1e3, 1e-9),
        "p50_ms": lats[len(lats) // 2] if lats else 0.0,
        "p95_ms": lats[min(int(len(lats) * 0.95), len(lats) - 1)] if lats else 0.0,
    }


def slo_class_stats(states: Sequence) -> Dict[str, float]:
    """Per-priority-class latency percentiles and goodput (deadline-met
    fraction) over finished :class:`RequestState`s, as flat float keys
    (``high_p95_ms``, ``low_n``, ``goodput``, ...).  Empty when the
    workload has a single class and no deadlines — plain traffic keeps
    the plain stats dict."""
    states = list(states)
    by_class: Dict[Priority, list] = {}
    for st in states:
        by_class.setdefault(st.request.priority, []).append(st)
    any_deadline = any(st.request.effective_deadline_ms is not None
                       for st in states)
    if len(by_class) <= 1 and not any_deadline:
        return {}
    out: Dict[str, float] = {}
    for pri, sts in by_class.items():
        tag = pri.name.lower()
        lats = sorted(st.latency_ms() for st in sts
                      if st.latency_ms() is not None)
        out[f"{tag}_n"] = float(len(sts))
        out[f"{tag}_p50_ms"] = lats[len(lats) // 2] if lats else 0.0
        out[f"{tag}_p95_ms"] = (lats[min(int(len(lats) * 0.95), len(lats) - 1)]
                                if lats else 0.0)
        met = [st.met_deadline() for st in sts]
        met = [m for m in met if m is not None]
        if met:
            out[f"{tag}_goodput"] = sum(met) / len(met)
    met_all = [st.met_deadline() for st in states]
    met_all = [m for m in met_all if m is not None]
    if met_all:
        out["goodput"] = sum(met_all) / len(met_all)
    return out


def slo_class_line(stats: Dict[str, float]) -> str:
    """Human-readable per-class summary from :func:`slo_class_stats`
    keys (plus the scheduler's preemption counters when present)."""
    parts = []
    for tag in ("high", "normal", "low"):
        if f"{tag}_n" not in stats:
            continue
        seg = (f"{tag} n={stats[f'{tag}_n']:.0f} "
               f"p50 {stats[f'{tag}_p50_ms']:.0f}ms "
               f"p95 {stats[f'{tag}_p95_ms']:.0f}ms")
        if f"{tag}_goodput" in stats:
            seg += f" goodput {stats[f'{tag}_goodput']:.0%}"
        parts.append(seg)
    if "goodput" in stats:
        parts.append(f"overall goodput {stats['goodput']:.0%}")
    if "preemptions" in stats:
        parts.append(f"preemptions {stats['preemptions']:.0f} "
                     f"(swapped {stats.get('swapped_blocks', 0):.0f} blocks, "
                     f"restored {stats.get('restore_tokens', 0):.0f} tokens)")
    return "slo: " + " | ".join(parts) if parts else ""


def run_trace_static(engine, requests: List[Request], batch: int, *,
                     temperature: float = 0.0, seed: int = 0
                     ) -> Tuple[Dict[int, List[int]], Dict[str, float]]:
    """Serve a trace with the lockstep :class:`ServingEngine`: FCFS
    groups of ``batch``, prompts right-padded to the group's longest,
    every request generating the group's *longest* ``gen_len`` (lockstep
    batching cannot stop per-request — that waste is the baseline the
    continuous engine removes).  Only each request's first ``gen_len``
    tokens count as useful output.  Latency clock: wall time since call,
    fast-forwarded to a group's last arrival when the server is idle.
    """
    import time

    need = static_max_len(requests)
    assert engine.max_len >= need, (
        f"static engine max_len {engine.max_len} < worst-case group "
        f"prompt+gen {need}")
    t0 = time.perf_counter()
    clock = 0.0
    out: Dict[int, List[int]] = {}
    lats: List[float] = []
    order = sorted(requests, key=lambda r: (r.arrival_ms, r.uid))
    useful = 0
    for i in range(0, len(order), batch):
        group = order[i:i + batch]
        clock = max(clock, (time.perf_counter() - t0) * 1e3,
                    max(r.arrival_ms for r in group))
        S = max(r.prompt_len for r in group)
        gen = max(r.max_new_tokens for r in group)
        prompts = np.zeros((len(group), S), np.int32)
        for j, r in enumerate(group):
            prompts[j, :r.prompt_len] = r.prompt   # right-padded
        toks, _ = engine.generate(prompts, gen, temperature=temperature,
                                  seed=seed)
        toks = np.asarray(toks)
        clock = max(clock, (time.perf_counter() - t0) * 1e3)
        for j, r in enumerate(group):
            out[r.uid] = toks[j, :r.max_new_tokens].tolist()
            useful += r.max_new_tokens
            lats.append(clock - r.arrival_ms)
    return out, latency_stats(lats, clock, useful)


def latency_line(stats: Dict[str, float]) -> str:
    return (f"{stats['generated_tokens']:.0f} tokens in "
            f"{stats['total_ms'] / 1e3:.2f}s "
            f"({stats['generated_tokens_per_s']:.1f} tok/s), "
            f"latency p50 {stats['p50_ms']:.0f}ms p95 {stats['p95_ms']:.0f}ms")
