"""Batched serving engine: prefill + decode with KV caches / recurrent
states, greedy or temperature sampling.

Works for every family in the registry.  Transformer families use the
single-pass prefill; recurrent families (xlstm / zamba) consume the
prompt with a scanned decode (O(1) state).
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.context import MoEContext
from repro.distributed.sharding import Rules, use_rules
from repro.models.registry import get_family


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, max_len: int,
                 rules: Optional[Rules] = None):
        self.cfg = cfg
        self.fam = get_family(cfg)
        self.params = params
        self.max_len = max_len
        self.rules = rules
        cfg_ = cfg
        fam = self.fam
        # Serving-side MoE context (is_training=False).  The family's
        # decode fills in the *absolute* decode positions (from the KV
        # cache length) and the current token ids, so content/identity
        # routing is consistent between prefill and decode instead of
        # decode-time MoE seeing neither.
        serve_ctx = MoEContext(is_training=False)

        def _decode(params, tokens, state):
            with use_rules(rules):
                return fam.decode(params, tokens, state, cfg_, ctx=serve_ctx)

        self._decode = jax.jit(_decode, donate_argnums=(2,))

        if fam.prefill is not None:
            def _prefill(params, batch):
                with use_rules(rules):
                    return fam.prefill(params, batch, cfg_, max_len=max_len,
                                       ctx=serve_ctx)

            self._prefill = jax.jit(_prefill, static_argnums=())
        else:
            self._prefill = None

    def _sample(self, logits, key, temperature: float):
        logits = logits[:, -1, :].astype(jnp.float32)
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)

    def generate(self, prompts: jax.Array, num_tokens: int,
                 temperature: float = 0.0, seed: int = 0):
        """prompts: (B, S) int32. Returns (B, num_tokens) int32 + stats."""
        B, S = prompts.shape
        key = jax.random.PRNGKey(seed)
        t0 = time.time()
        if self._prefill is not None:
            logits, state = self._prefill(self.params, {"tokens": prompts})
        else:
            # recurrent prompt consumption, token by token
            state = self.fam.init_state(self.cfg, B, self.max_len)
            logits = None
            for i in range(S):
                logits, state = self._decode(self.params, prompts[:, i:i + 1], state)
        t_prefill = time.time() - t0

        key, sub = jax.random.split(key)
        tok = self._sample(logits, sub, temperature)
        out = [tok]
        n_decode = max(num_tokens - 1, 0)
        t0 = time.time()
        for _ in range(n_decode):
            logits, state = self._decode(self.params, tok[:, None], state)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub, temperature)
            out.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0
        stats = {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            # num_tokens == 1 never enters the decode loop: reporting
            # (num_tokens - 1) * B over a near-zero timer would be 0/eps
            # noise — return an explicit 0.0 instead.
            "decode_tokens_per_s": (n_decode * B / max(t_decode, 1e-9)
                                    if n_decode else 0.0),
        }
        return jnp.stack(out, axis=1), stats
