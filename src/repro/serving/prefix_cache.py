"""Block-level prefix caching: content-addressed, refcounted,
copy-on-write KV sharing for multi-tenant serving.

Multi-tenant traffic is dominated by shared prompt *prefixes* — system
prompts, few-shot templates, conversation history.  Serving in the
paper's regime (outrageously many parameters, constant per-token
compute) makes KV memory, not FLOPs, the binding constraint, so making
identical prefixes share physical KV blocks multiplies effective pool
capacity and turns prefill of a cached prefix into a block-table write.

Three pieces, layered between :class:`~repro.serving.kv_cache.PagedKVCache`
and the engine:

* :class:`RefcountedBlockAllocator` — generalizes ``BlockAllocator``
  with a per-block refcount (number of slot-table bindings), an owner
  (the slot whose reservation the block is charged to, or ``None`` for
  purely shared blocks), and a **cached-free list**: blocks whose
  refcount hit 0 but whose contents are still bound in the
  :class:`PrefixIndex` stay reusable, ordered LRU; allocation takes the
  truly-free list first and evicts cached blocks (oldest first, via the
  ``on_evict`` unbind callback) only under pressure.

* :class:`PrefixIndex` — content addressing.  A block's identity is the
  **chain hash** ``H(parent_hash, block_token_ids)`` over the *full*
  block of tokens it holds K/V for, so a hash pins the entire prefix
  from position 0 (absolute positions and therefore RoPE phases are
  part of the identity by construction — block boundaries are
  position-aligned).  The index is a bijection ``hash <-> physical
  block``; matching a prompt walks it hash by hash from the root.

* :class:`PrefixCachingKVCache` — the ``PagedKVCache`` subclass the
  engine actually uses (``ServeConfig.prefix_cache=True``).  Admission
  matches the request's prompt against the index and **binds** the
  matched blocks straight into the slot's table (refcount + 1 each):
  those positions are already-written context, prefill resumes at the
  first uncached token, and admission charges only the *unshared*
  footprint.  :meth:`commit` publishes a slot's newly *full* blocks of
  confirmed tokens back into the index — during prefill/decode, not
  just at eviction, so concurrent requests of the same tenant share
  live blocks.  Copy-on-write is expressed entirely in the host-side
  table/allocator layer: shared blocks are never write targets
  (:meth:`write_coords` enforces it), and :meth:`truncate_slot` into a
  shared or published block detaches the slot onto a fresh copy while
  binders keep the original.

Capacity accounting under sharing: each slot's reservation covers only
the blocks it may need *exclusively* (``blocks_needed(total_len) -
bound_blocks``); admission gates on ``reserved_total + live_shared +
new`` against the pool, where ``live_shared`` counts distinct bound
blocks charged to no reservation.  Under the engine's discipline
(truncate never rewinds below the committed boundary, so a slot never
detaches from a block another slot binds) this preserves the original
no-mid-flight-starvation witness ``free + cached >=
reserved_total - owned_total``, which :meth:`check_conservation`
asserts.  A COW *detach* (possible through the raw cache API, exercised
by the property tests, never by the engine) pins the original block
outside every reservation; the strict witness is only asserted while no
detach has occurred, and regrowth past a released shared region beyond
the slot's exclusive reservation raises rather than silently starving
another slot.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.serving.kv_cache import PagedKVCache

ROOT_HASH = b""          # chain parent of the block at positions [0, bs)


def chain_hash(parent: bytes, block_tokens: np.ndarray) -> bytes:
    """Content identity of one full KV block: the tokens it covers plus
    the identity of everything before it (a 128-bit blake2b keeps
    accidental collisions — which would silently serve the wrong
    prefix — out of reach)."""
    h = hashlib.blake2b(digest_size=16)
    h.update(parent)
    h.update(np.ascontiguousarray(block_tokens, dtype=np.int32).tobytes())
    return h.digest()


class PrefixIndex:
    """Bijection between chain hashes and physical block ids.

    ``put`` is first-writer-wins: if the hash is already bound (another
    slot published identical content earlier) the new block simply stays
    unpublished — deduplicating by remapping would mean rewriting live
    tables.  ``drop_block`` unbinds on eviction or content divergence.
    """

    def __init__(self):
        self._block_of: Dict[bytes, int] = {}
        self._hash_of: Dict[int, bytes] = {}

    def __len__(self) -> int:
        return len(self._block_of)

    def get(self, h: bytes) -> Optional[int]:
        return self._block_of.get(h)

    def published(self, block: int) -> bool:
        return block in self._hash_of

    def put(self, h: bytes, block: int) -> bool:
        """Bind ``hash -> block``; returns False when the hash is
        already taken (the caller's block stays unpublished)."""
        if h in self._block_of:
            return False
        assert block not in self._hash_of, (
            f"block {block} already published under another hash")
        self._block_of[h] = block
        self._hash_of[block] = h
        return True

    def drop_block(self, block: int) -> None:
        h = self._hash_of.pop(block)
        del self._block_of[h]

    def check_bijection(self) -> None:
        assert len(self._block_of) == len(self._hash_of)
        for h, b in self._block_of.items():
            assert self._hash_of[b] == h


class RefcountedBlockAllocator:
    """Free-list allocator with per-block refcounts and an LRU
    cached-free list.

    Block states (every id in exactly one):

    * **free** — unreferenced, contents meaningless.
    * **cached** — refcount 0 but still published in the index; contents
      valid and reusable by a future prefix match.  LRU-ordered;
      evicted (via ``on_evict``, which must unpublish) only when the
      free list runs dry.
    * **live** — refcount > 0 (bound in that many slot tables).  A live
      block optionally has an **owner**: the slot whose exclusive
      reservation it is charged to.  Ownerless live blocks are *shared*
      capacity pinned outside every reservation.
    """

    def __init__(self, num_blocks: int,
                 on_evict: Optional[Callable[[int], None]] = None):
        self.num_blocks = num_blocks
        self.on_evict = on_evict
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._cached: "OrderedDict[int, None]" = OrderedDict()  # oldest first
        self._ref: Dict[int, int] = {}
        self._owner: Dict[int, Optional[int]] = {}
        self.evicted_blocks = 0

    # -- queries ------------------------------------------------------------

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def cached_count(self) -> int:
        return len(self._cached)

    @property
    def live_count(self) -> int:
        return len(self._ref)

    @property
    def owned_count(self) -> int:
        return sum(1 for o in self._owner.values() if o is not None)

    @property
    def live_shared(self) -> int:
        """Live blocks charged to no reservation (purely shared)."""
        return sum(1 for o in self._owner.values() if o is None)

    def refcount(self, block: int) -> int:
        return self._ref.get(block, 0)

    def owner(self, block: int) -> Optional[int]:
        return self._owner.get(block)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free) + len(self._cached)

    def is_cached(self, block: int) -> bool:
        return block in self._cached

    # -- transitions --------------------------------------------------------

    def alloc(self, n: int, owner: int) -> List[int]:
        """Hand out ``n`` fresh exclusively-owned blocks (refcount 1,
        charged to ``owner``), evicting LRU cached blocks if the free
        list cannot cover the request."""
        if not self.can_alloc(n):
            raise RuntimeError(
                f"KV pool exhausted: requested {n} blocks, "
                f"{len(self._free)} free + {len(self._cached)} cached")
        out: List[int] = []
        for _ in range(n):
            if self._free:
                b = self._free.pop()
            else:
                b, _ = self._cached.popitem(last=False)   # LRU: oldest
                if self.on_evict is not None:
                    self.on_evict(b)                      # unpublish
                self.evicted_blocks += 1
            self._ref[b] = 1
            self._owner[b] = owner
            out.append(b)
        return out

    def bind(self, block: int) -> None:
        """One more table binding for ``block`` (a prefix match).  A
        cached block comes back to life; a live one just gains a
        reference (its owner, if any, keeps the charge)."""
        if block in self._cached:
            del self._cached[block]
            self._ref[block] = 1
            self._owner[block] = None
        else:
            self._ref[block] += 1

    def touch(self, block: int) -> None:
        """Refresh a cached block's LRU position (a lookup hit)."""
        if block in self._cached:
            self._cached.move_to_end(block)

    def release(self, block: int, *, owner_release: bool,
                published: bool) -> None:
        """Drop one binding.  ``owner_release`` also drops the
        reservation charge (the block becomes purely shared if other
        binders remain).  At refcount 0 the block goes to the cached
        list when ``published`` (contents stay matchable) and to the
        free list otherwise."""
        if block not in self._ref:
            raise RuntimeError(f"release of unreferenced KV block {block}")
        if owner_release:
            assert self._owner[block] is not None, (
                f"owner release of ownerless block {block}")
            self._owner[block] = None
        self._ref[block] -= 1
        if self._ref[block] == 0:
            del self._ref[block]
            del self._owner[block]
            if published:
                self._cached[block] = None
                self._cached.move_to_end(block)
            else:
                self._free.append(block)

    def check_conservation(self) -> None:
        free, cached, live = set(self._free), set(self._cached), set(self._ref)
        assert len(self._free) == len(free)
        assert not (free & cached) and not (free & live) and not (cached & live)
        assert len(free) + len(cached) + len(live) == self.num_blocks
        assert all(r > 0 for r in self._ref.values())
        assert set(self._owner) == live


class PrefixCachingKVCache(PagedKVCache):
    """``PagedKVCache`` with content-addressed block sharing.

    Slot table layout: entries ``[0, bound)`` are **bound** blocks —
    matched from the index at admission, read-only, possibly shared
    with other slots and with the index; entries ``[bound, held)`` are
    **owned** blocks the slot allocated for its own writes (charged to
    its exclusive reservation).  ``reserved`` here is the *exclusive*
    reservation: ``blocks_needed(total_len) - bound-at-admission``.
    """

    def __init__(self, cfg: ModelConfig, serve: ServeConfig):
        super().__init__(cfg, serve)
        self.index = PrefixIndex()
        self.allocator = RefcountedBlockAllocator(
            self.num_blocks, on_evict=self._on_evict)
        self._slot_bound: Dict[int, int] = {}     # leading bound (read-only) blocks
        self._slot_chain: Dict[int, List[bytes]] = {}  # chain hash per full block
        self.stats = {"lookups": 0, "hit_tokens": 0, "bound_blocks": 0,
                      "published_blocks": 0, "evicted_blocks": 0,
                      "cow_copies": 0, "cow_detaches": 0}

    # -- index plumbing -----------------------------------------------------

    def _on_evict(self, block: int) -> None:
        """LRU eviction of a cached block: its contents are about to be
        reused, so the index binding must go first."""
        self.index.drop_block(block)
        self.stats["evicted_blocks"] += 1

    def _match_prefix(self, prompt: np.ndarray) -> Tuple[List[bytes], List[int]]:
        """Walk the index over the prompt's full blocks.  At most
        ``prompt_len - 1`` tokens may come from the cache: the engine
        needs at least one prompt row to run to sample the first
        generated token, so a fully-cached prompt recomputes its last
        block."""
        bs = self.block_size
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        hashes: List[bytes] = []
        blocks: List[int] = []
        parent = ROOT_HASH
        for k in range((prompt.size - 1) // bs):
            h = chain_hash(parent, prompt[k * bs:(k + 1) * bs])
            b = self.index.get(h)
            if b is None:
                break
            hashes.append(h)
            blocks.append(b)
            parent = h
        return hashes, blocks

    # -- admission ----------------------------------------------------------

    def _admission_room(self, total_len: int, matched: Sequence[int]) -> bool:
        """Gate: exclusive reservations + shared-pinned blocks (current,
        plus the matched blocks that would leave the cached list) must
        fit the pool — every admitted slot can then always grow to its
        exclusive bound."""
        a = self.allocator
        need_excl = self.blocks_needed(total_len) - len(matched)
        newly_live = sum(1 for b in matched if a.refcount(b) == 0)
        return (self.reserved_total + a.live_shared + newly_live + need_excl
                <= self.num_blocks)

    def can_allocate_slot(self, total_len: int,
                          prompt: Optional[np.ndarray] = None) -> bool:
        matched = self._match_prefix(prompt)[1] if prompt is not None else []
        return self._admission_room(total_len, matched)

    def allocate_slot(self, slot: int, total_len: int,
                      prompt: Optional[np.ndarray] = None) -> int:
        """Reserve the unshared footprint and bind the cached prefix
        into the slot's table.  Returns the number of prompt tokens the
        bound blocks already hold K/V for (``cached_tokens``); prefill
        resumes there."""
        assert slot not in self._slot_reserved, f"slot {slot} already allocated"
        hashes, blocks = (self._match_prefix(prompt) if prompt is not None
                          else ([], []))
        self.stats["lookups"] += 1
        if not self._admission_room(total_len, blocks):
            raise RuntimeError(
                f"KV pool over-reserved: slot {slot} needs "
                f"{self.blocks_needed(total_len) - len(blocks)} exclusive "
                f"blocks beyond the shared prefix")
        for b in blocks:
            self.allocator.touch(b)
            self.allocator.bind(b)
        self._slot_reserved[slot] = self.blocks_needed(total_len) - len(blocks)
        self.reserved_total += self._slot_reserved[slot]
        self._slot_blocks[slot] = list(blocks)
        self._slot_bound[slot] = len(blocks)
        self._slot_chain[slot] = list(hashes)
        self.block_table[slot, :] = self.garbage_block
        if blocks:
            self.block_table[slot, :len(blocks)] = blocks
        cached_tokens = len(blocks) * self.block_size
        self.stats["hit_tokens"] += cached_tokens
        self.stats["bound_blocks"] += len(blocks)
        return cached_tokens

    # -- publication --------------------------------------------------------

    def commit(self, slot: int, tokens: np.ndarray) -> None:
        """Confirm that positions ``[0, len(tokens))`` of ``slot`` hold
        K/V for exactly ``tokens``, and publish any newly *full* blocks
        into the index.  Called by the engine after every step (so
        concurrent requests share live blocks) and by the scheduler at
        eviction (so the last generated blocks outlive the slot)."""
        bs = self.block_size
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        chain = self._slot_chain[slot]
        held = self._slot_blocks[slot]
        nfull = tokens.size // bs
        assert nfull <= len(held), (
            f"slot {slot}: commit of {tokens.size} tokens but only "
            f"{len(held)} blocks held")
        for k in range(len(chain), nfull):
            parent = chain[k - 1] if k else ROOT_HASH
            h = chain_hash(parent, tokens[k * bs:(k + 1) * bs])
            chain.append(h)
            if self.index.put(h, held[k]):
                self.stats["published_blocks"] += 1

    def committed_blocks(self, slot: int) -> int:
        """Full blocks of ``slot`` whose token contents are confirmed
        (cheap guard so per-step commits cost nothing until a slot's
        written length crosses a block boundary)."""
        return len(self._slot_chain[slot])

    # -- growth / copy-on-write ---------------------------------------------

    def ensure_capacity(self, slot: int, length: int) -> None:
        need = self.blocks_needed(length)
        held = self._slot_blocks[slot]
        if need <= len(held):
            return
        bound = self._slot_bound[slot]
        if need - bound > self._slot_reserved[slot]:
            # only reachable after a truncate released bound blocks
            # (never through the engine): regrowing them would need
            # exclusive blocks beyond the admission-time reservation —
            # refusing keeps every *other* slot's growth guarantee intact
            raise RuntimeError(
                f"slot {slot}: growth to {length} needs {need - bound} "
                f"exclusive blocks, reserved only {self._slot_reserved[slot]} "
                f"(shared prefix blocks were released by truncate)")
        new = self.allocator.alloc(need - len(held), owner=slot)
        self.block_table[slot, len(held):need] = new
        held.extend(new)

    def _cow_replace(self, slot: int, k: int) -> None:
        """Detach table entry ``k`` of ``slot`` from a block other
        parties still need: release our binding, allocate a fresh block
        and copy the pool contents across (device-side, both pools, all
        layers).  The original stays with its remaining binders and/or
        the index; the slot's future writes land in its own copy."""
        held = self._slot_blocks[slot]
        old = held[k]
        owner_release = k >= self._slot_bound[slot]
        self.allocator.release(old, owner_release=owner_release,
                               published=self.index.published(old))
        if self.allocator.refcount(old) > 0:
            self.stats["cow_detaches"] += 1
        if self.k_pool is None:
            # Detached per-shard sub-cache (ShardedPagedKVCache owns the
            # stacked pools).  Only speculative rollback into a partial
            # *shared* block reaches a COW detach, and the engine rejects
            # spec + mesh before construction — so this is a guard, not a
            # path.
            raise NotImplementedError(
                "copy-on-write detach needs device pools; not supported on "
                "a detached per-shard cache")
        new = self.allocator.alloc(1, owner=slot)[0]
        if new != old:      # eviction can hand the same id straight back
            self.k_pool = self.k_pool.at[:, new].set(self.k_pool[:, old])
            self.v_pool = self.v_pool.at[:, new].set(self.v_pool[:, old])
            self.stats["cow_copies"] += 1
        held[k] = new
        self.block_table[slot, k] = new
        if k < self._slot_bound[slot]:
            self._slot_bound[slot] = k

    def truncate_slot(self, slot: int, new_len: int) -> None:
        """Rewind ``slot`` to ``new_len`` written positions.

        Owned blocks past the new length are released (back to the
        cached list when published — their contents are still valid
        prefixes — else to the free list); released *bound* blocks just
        drop one refcount, their sharers unaffected.  The block
        containing ``new_len`` (about to be partially rewritten) is the
        copy-on-write edge: if it is bound or has other binders the slot
        detaches onto a fresh copy, and if it is published the (now
        stale-to-be) index binding is dropped — the shared tail is never
        written."""
        keep = self.blocks_needed(new_len) if new_len > 0 else 0
        held = self._slot_blocks[slot]
        bound = self._slot_bound[slot]
        for k in range(len(held) - 1, keep - 1, -1):
            self.allocator.release(held[k], owner_release=k >= bound,
                                   published=self.index.published(held[k]))
        if keep < len(held):
            self.block_table[slot, keep:] = self.garbage_block
            del held[keep:]
        self._slot_bound[slot] = min(bound, keep)
        chain = self._slot_chain[slot]
        del chain[new_len // self.block_size:]
        if new_len % self.block_size != 0 and keep == len(held) and held:
            k = keep - 1                      # partial boundary block
            blk = held[k]
            if k < self._slot_bound[slot] or self.allocator.refcount(blk) > 1:
                self._cow_replace(slot, k)    # others read it: never write it
            elif self.index.published(blk):
                self.index.drop_block(blk)    # sole user: content will diverge

    # -- writes -------------------------------------------------------------

    def write_coords(self, slot: int, position: int) -> Tuple[int, int]:
        b, o = divmod(position, self.block_size)
        blk = int(self.block_table[slot, b])
        if b < self._slot_bound.get(slot, 0):
            raise RuntimeError(
                f"COW violation: write at position {position} of slot {slot} "
                f"targets bound (shared, read-only) block {blk}")
        if self.allocator.refcount(blk) > 1:
            raise RuntimeError(
                f"COW violation: write at position {position} of slot {slot} "
                f"would land in block {blk} with refcount "
                f"{self.allocator.refcount(blk)}")
        if self.index.published(blk):
            raise RuntimeError(
                f"write at position {position} of slot {slot} would rewrite "
                f"published block {blk} behind the index (truncate_slot "
                f"unpublishes the divergence point first)")
        return blk, o

    # -- preemption swap hooks (repro.serving.slo) ---------------------------

    def warm_prefix_tokens(self, prompt) -> int:
        """Prompt tokens a fresh admission would serve from the index
        right now (no LRU touch — this is a policy probe, not a hit)."""
        return len(self._match_prefix(prompt)[1]) * self.block_size

    def swap_footprint(self, slot: int) -> int:
        # bound blocks are shared and re-bindable: never copied
        return len(self._slot_blocks[slot]) - self._slot_bound[slot]

    def swap_out(self, slot: int, swap, *, uid: int, total_len: int,
                 context_len: int):
        """Refcount-aware swap-out: host-copy only the slot's *owned*
        blocks; the bound shared prefix is recorded by chain hash alone
        (restore re-binds whatever block then holds that content).
        ``free_slot`` then drops the bindings — owned published blocks
        land on the cached list, so an undisturbed pool restores them by
        re-bind too, without touching the host copies."""
        rec = swap.store(self, uid=uid, total_len=total_len,
                         context_len=context_len,
                         blocks=list(self._slot_blocks[slot]),
                         skip=self._slot_bound[slot],
                         hashes=list(self._slot_chain[slot]))
        self.free_slot(slot)
        return rec

    def _match_record(self, rec) -> Tuple[List[bytes], List[int]]:
        """Leading run of the record's chain still published in the
        index (the re-bindable prefix; stops at the first evicted
        hash)."""
        hashes: List[bytes] = []
        blocks: List[int] = []
        for h in rec.hashes:
            b = self.index.get(h)
            if b is None:
                break
            hashes.append(h)
            blocks.append(b)
        return hashes, blocks

    def can_restore(self, rec) -> bool:
        return self._admission_room(rec.total_len, self._match_record(rec)[1])

    def restore_slot(self, slot: int, rec, swap) -> int:
        """Rebuild a preempted slot: re-bind the still-published prefix,
        upload host copies for the rest, republish restored full blocks.
        If a *bound* (never-copied) block's hash was evicted from the
        index, everything past the hole is unusable — KV at position p
        needs all positions before it — so restore stops there and the
        engine recomputes the tail by resume-prefill; host copies past
        the hole are simply dropped with the record."""
        assert slot not in self._slot_reserved, f"slot {slot} already allocated"
        hashes, blocks = self._match_record(rec)
        m = len(blocks)
        # tail uploads exist for every k >= rec.skip; a hole before that
        # (m < skip) leaves nothing usable past position m * block_size
        n_tail = rec.num_blocks - m if m >= rec.skip else 0
        if not self._admission_room(rec.total_len, blocks):
            raise RuntimeError(
                f"KV pool over-reserved: restore of request {rec.uid} into "
                f"slot {slot} needs "
                f"{self.blocks_needed(rec.total_len) - m} exclusive blocks")
        for b in blocks:
            self.allocator.touch(b)
            self.allocator.bind(b)
        self.stats["bound_blocks"] += m
        self._slot_reserved[slot] = self.blocks_needed(rec.total_len) - m
        self.reserved_total += self._slot_reserved[slot]
        self._slot_blocks[slot] = list(blocks)
        self._slot_bound[slot] = m
        self._slot_chain[slot] = list(hashes)
        self.block_table[slot, :] = self.garbage_block
        if blocks:
            self.block_table[slot, :m] = blocks
        if n_tail == 0:
            return min(m * self.block_size, rec.context_len)
        new = self.allocator.alloc(n_tail, owner=slot)
        self.block_table[slot, m:rec.num_blocks] = new
        self._slot_blocks[slot].extend(new)
        swap.load(self, [(rec.host_of[k], new[k - m])
                         for k in range(m, rec.num_blocks)])
        # uploaded *full* blocks hold the recorded chain content again:
        # extend the slot chain and republish (first-writer-wins)
        for k in range(m, len(rec.hashes)):
            self._slot_chain[slot].append(rec.hashes[k])
            if self.index.put(rec.hashes[k], self._slot_blocks[slot][k]):
                self.stats["published_blocks"] += 1
        return rec.context_len

    # -- eviction -----------------------------------------------------------

    def free_slot(self, slot: int) -> None:
        held = self._slot_blocks.pop(slot)
        bound = self._slot_bound.pop(slot)
        for k, b in enumerate(held):
            self.allocator.release(b, owner_release=k >= bound,
                                   published=self.index.published(b))
        del self._slot_chain[slot]
        self.reserved_total -= self._slot_reserved.pop(slot)
        self.block_table[slot, :] = self.garbage_block

    # -- invariants ---------------------------------------------------------

    def check_conservation(self) -> None:
        """Base table/reservation hygiene plus refcount/owner/index
        invariants:

        * free / cached / live partition the pool; refcount(b) equals
          the number of slot-table bindings of b, and nothing a slot
          binds is ever on a free or cached list;
        * owned blocks sit at table indices >= the slot's bound region
          and are charged to exactly that slot; owned <= exclusive
          reservation per slot;
        * the index is a hash<->block bijection, cached blocks are all
          published, free blocks never are, and every slot's chain
          matches its bound prefix;
        * while no COW detach has occurred (always, under the engine's
          discipline), the no-starvation witness holds:
          free + cached >= reserved_total - owned_total.
        """
        a = self.allocator
        a.check_conservation()
        self.index.check_bijection()
        bindings: Dict[int, int] = {}
        for slot, blocks in self._slot_blocks.items():
            bound = self._slot_bound[slot]
            assert 0 <= bound <= len(blocks)
            assert len(blocks) - bound <= self._slot_reserved[slot], slot
            assert list(self.block_table[slot, :len(blocks)]) == blocks
            assert (self.block_table[slot, len(blocks):]
                    == self.garbage_block).all()
            assert len(self._slot_chain[slot]) <= len(blocks)
            for k, b in enumerate(blocks):
                bindings[b] = bindings.get(b, 0) + 1
                if k >= bound:
                    assert a.owner(b) == slot, (slot, k, b)
        for b, n in bindings.items():
            assert a.refcount(b) == n, (b, n, a.refcount(b))
            assert not a.is_cached(b)
        assert sum(1 for b in bindings if a.owner(b) is not None) == a.owned_count
        assert a.live_count == len(bindings)
        for b in range(self.num_blocks):
            if a.is_cached(b):
                assert self.index.published(b), f"cached block {b} unpublished"
        for b in a._free:
            assert not self.index.published(b), f"free block {b} published"
        assert self.reserved_total == sum(self._slot_reserved.values())
        assert self.reserved_total <= self.num_blocks
        if self.stats["cow_detaches"] == 0:
            assert (a.free_count + a.cached_count
                    >= self.reserved_total - a.owned_count), (
                a.free_count, a.cached_count, self.reserved_total,
                a.owned_count)
        for slot in range(self.block_table.shape[0]):
            if slot not in self._slot_blocks:
                assert (self.block_table[slot] == self.garbage_block).all()
