"""FCFS admission scheduler for the continuous-batching engine.

The scheduler owns request lifecycle: a FIFO waiting queue, a fixed pool
of ``max_slots`` decode slots, and (for paged transformer serving)
coordination with the :class:`~repro.serving.kv_cache.PagedKVCache`
allocator.  Admission is strict FCFS — a request at the head that does
not fit (no free slot, or not enough free KV blocks for its worst-case
``prompt + max_new_tokens`` footprint) blocks everything behind it; no
reordering means no starvation.

Eviction happens on EOS or on reaching ``max_new_tokens``; the slot and
its blocks return to the free pools in the same step, so the next
admission can reuse them immediately (slots stay full under load — the
whole point of continuous batching).
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional

from repro.serving.kv_cache import PagedKVCache
from repro.serving.request import Request, RequestState, Status


class Scheduler:
    def __init__(self, max_slots: int, max_len: int,
                 kv_cache: Optional[PagedKVCache] = None):
        self.max_slots = max_slots
        self.max_len = max_len
        self.kv_cache = kv_cache
        self.waiting: Deque[RequestState] = deque()
        self.running: Dict[int, RequestState] = {}     # slot -> state
        self.free_slots: List[int] = list(range(max_slots - 1, -1, -1))
        self._admit_seq = 0                            # FCFS tiebreaker

    # -- intake -------------------------------------------------------------

    def add(self, request: Request) -> RequestState:
        if request.total_len > self.max_len:
            raise ValueError(
                f"request {request.uid}: prompt_len + max_new_tokens = "
                f"{request.total_len} exceeds serve max_len {self.max_len}")
        if self.kv_cache is not None:
            need = self.kv_cache.blocks_needed(request.total_len)
            if need > self.kv_cache.allocator.num_blocks:
                # would never fit even an empty pool: admission (FCFS,
                # head blocks the queue) would spin for ever
                raise ValueError(
                    f"request {request.uid}: needs {need} KV blocks but the "
                    f"pool only has {self.kv_cache.allocator.num_blocks}")
        st = RequestState(request)
        self.waiting.append(st)
        return st

    # -- admission ----------------------------------------------------------

    def admit(self, clock_ms: float) -> List[RequestState]:
        """Admit FCFS from the queue: arrived requests only, while a slot
        (and, when paged, enough KV blocks) is available."""
        admitted = []
        while self.waiting and self.free_slots:
            st = self.waiting[0]
            if st.request.arrival_ms > clock_ms:
                break
            if (self.kv_cache is not None
                    and not self.kv_cache.can_allocate_slot(st.request.total_len)):
                break
            self.waiting.popleft()
            slot = self.free_slots.pop()
            if self.kv_cache is not None:
                self.kv_cache.allocate_slot(slot, st.request.total_len)
            st.slot = slot
            st.status = Status.PREFILL
            st.prefill_pos = 0
            st.admitted_ms = clock_ms
            st.admit_seq = self._admit_seq
            self._admit_seq += 1
            self.running[slot] = st
            admitted.append(st)
        return admitted

    # -- eviction -----------------------------------------------------------

    def finish(self, st: RequestState, clock_ms: float) -> None:
        assert st.slot in self.running and self.running[st.slot] is st
        del self.running[st.slot]
        self.free_slots.append(st.slot)
        if self.kv_cache is not None:
            self.kv_cache.free_slot(st.slot)
        # the scheduler deliberately keeps no reference to finished
        # states (a server runs for ever); callers that need completion
        # records collect the states step()/finish() hand back
        st.status = Status.FINISHED
        st.finished_ms = clock_ms

    # -- queries ------------------------------------------------------------

    @property
    def prefilling(self) -> Optional[RequestState]:
        """The request currently being chunk-prefilled (FCFS: at most the
        single earliest-admitted PREFILL request makes progress per step)."""
        cands = [st for st in self.running.values() if st.status is Status.PREFILL]
        return min(cands, key=lambda s: s.admit_seq) if cands else None

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def next_arrival_ms(self) -> Optional[float]:
        return self.waiting[0].request.arrival_ms if self.waiting else None

    def check_conservation(self) -> None:
        """Slot/block invariants: every slot is exactly free or running,
        and the block allocator accounts for every block exactly once."""
        assert len(self.free_slots) + len(self.running) == self.max_slots
        assert set(self.free_slots).isdisjoint(self.running.keys())
        if self.kv_cache is not None:
            self.kv_cache.allocator.check_conservation()
