"""Admission scheduling for the continuous-batching engine.

The scheduler owns request lifecycle: a waiting queue, a fixed pool of
``max_slots`` decode slots, and (for paged transformer serving)
coordination with the :class:`~repro.serving.kv_cache.PagedKVCache`
allocator.  A request is admissible when a slot is free *and* the cache
can reserve its worst-case KV-block footprint (``prompt_len +
max_new_tokens``, which also bounds in-flight speculative draft
positions — the engine clamps per-slot drafts to the remaining
generation budget); reserving the full footprint at admission means a
running request can never hit block starvation mid-flight.

*Which* admissible request is admitted next is a pluggable
**admission policy**, a registry keyed by ``ServeConfig.sched_policy``
(mirroring the router/dispatcher/drafter registries):

* ``fcfs`` (default) — strict arrival order; a head that does not fit
  blocks everything behind it.  No reordering means no starvation.
* ``sjf`` — shortest job first: among arrived requests that fit, admit
  the one with the smallest worst-case footprint.  Lower mean latency
  on mixed-length traffic; long requests can starve under sustained
  short-request load (documented tradeoff).
* ``prefill_first`` — first fit in arrival order: skip over a blocked
  head to keep slots (and the prefill pipeline) busy; earliest-arrival
  otherwise, so reordering only ever happens past a request that could
  not have been admitted anyway.

Eviction happens on EOS or on reaching ``max_new_tokens``; the slot and
its blocks return to the free pools in the same step, so the next
admission can reuse them immediately (slots stay full under load — the
whole point of continuous batching).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple, Type

import numpy as np

from repro.serving.kv_cache import PagedKVCache
from repro.serving.request import Request, RequestState, Status

# ---------------------------------------------------------------------------
# Admission-policy registry
# ---------------------------------------------------------------------------

_POLICIES: Dict[str, "AdmissionPolicy"] = {}


def register_policy(cls: Type) -> Type:
    """Class decorator: instantiate and register a policy under cls.name."""
    name = getattr(cls, "name", None)
    if not name or not isinstance(name, str):
        raise ValueError(f"policy class {cls!r} needs a string `name` attribute")
    _POLICIES[name] = cls()
    return cls


def get_policy(name: str) -> "AdmissionPolicy":
    try:
        return _POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown admission policy {name!r}; registered policies: "
            f"{', '.join(available_policies())}"
        ) from None


def available_policies() -> Tuple[str, ...]:
    return tuple(sorted(_POLICIES))


class AdmissionPolicy:
    """Picks the next request to admit.  ``pick`` sees the waiting list
    (arrival order), the clock, and a fit predicate; it returns an index
    into ``waiting`` or None when nothing should be admitted now.  The
    scheduler calls it repeatedly until it declines or slots run out."""

    name = "abstract"

    def pick(self, waiting: Sequence[RequestState], clock_ms: float,
             fits: Callable[[RequestState], bool]) -> Optional[int]:
        raise NotImplementedError


@register_policy
class FCFSPolicy(AdmissionPolicy):
    name = "fcfs"

    def pick(self, waiting, clock_ms, fits):
        if not waiting:
            return None
        head = waiting[0]
        if head.request.arrival_ms > clock_ms or not fits(head):
            return None
        return 0


@register_policy
class SJFPolicy(AdmissionPolicy):
    name = "sjf"

    def pick(self, waiting, clock_ms, fits):
        best: Optional[int] = None
        for i, st in enumerate(waiting):
            r = st.request
            if r.arrival_ms > clock_ms or not fits(st):
                continue
            if best is None or ((r.total_len, r.arrival_ms, r.uid)
                                < (waiting[best].request.total_len,
                                   waiting[best].request.arrival_ms,
                                   waiting[best].request.uid)):
                best = i
        return best


@register_policy
class PrefillFirstPolicy(AdmissionPolicy):
    name = "prefill_first"

    def pick(self, waiting, clock_ms, fits):
        for i, st in enumerate(waiting):
            if st.request.arrival_ms > clock_ms:
                continue
            if fits(st):
                return i
        return None


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------

class Scheduler:
    def __init__(self, max_slots: int, max_len: int,
                 kv_cache: Optional[PagedKVCache] = None,
                 policy: str = "fcfs"):
        self.max_slots = max_slots
        self.max_len = max_len
        self.kv_cache = kv_cache
        self.policy = get_policy(policy)
        self.waiting: List[RequestState] = []
        self.running: Dict[int, RequestState] = {}     # slot -> state
        self.free_slots: List[int] = list(range(max_slots - 1, -1, -1))
        self._admit_seq = 0                            # admission-order tiebreaker

    # -- intake -------------------------------------------------------------

    def add(self, request: Request) -> RequestState:
        if request.total_len > self.max_len:
            raise ValueError(
                f"request {request.uid}: prompt_len + max_new_tokens = "
                f"{request.total_len} exceeds serve max_len {self.max_len}")
        if self.kv_cache is not None:
            need = self.kv_cache.blocks_needed(request.total_len)
            if need > self.kv_cache.allocator.num_blocks:
                # would never fit even an empty pool: admission would
                # spin on it (fcfs) or skip it for ever (sjf/first-fit)
                raise ValueError(
                    f"request {request.uid}: needs {need} KV blocks but the "
                    f"pool only has {self.kv_cache.allocator.num_blocks}")
        st = RequestState(request)
        self.waiting.append(st)
        return st

    # -- admission ----------------------------------------------------------

    def _fits(self, st: RequestState) -> bool:
        return (self.kv_cache is None
                or self.kv_cache.can_allocate_slot(st.request.total_len,
                                                   prompt=st.request.prompt))

    def admit(self, clock_ms: float) -> List[RequestState]:
        """Admit from the queue under the configured policy: arrived
        requests only, while a slot (and, when paged, an unreserved
        worst-case KV footprint) is available."""
        admitted = []
        while self.free_slots:
            idx = self.policy.pick(self.waiting, clock_ms, self._fits)
            if idx is None:
                break
            st = self.waiting.pop(idx)
            slot = self.free_slots.pop()
            st.cached_tokens = 0
            if self.kv_cache is not None:
                # prefix caching: matched prompt-prefix blocks are bound
                # into the slot's table (already-written context), so
                # prefill resumes at the first uncached token
                st.cached_tokens = self.kv_cache.allocate_slot(
                    slot, st.request.total_len, prompt=st.request.prompt)
            st.slot = slot
            st.status = Status.PREFILL
            st.prefill_pos = st.cached_tokens
            st.admitted_ms = clock_ms
            st.admit_seq = self._admit_seq
            self._admit_seq += 1
            self.running[slot] = st
            admitted.append(st)
        return admitted

    # -- eviction -----------------------------------------------------------

    def finish(self, st: RequestState, clock_ms: float) -> None:
        assert st.slot in self.running and self.running[st.slot] is st
        del self.running[st.slot]
        self.free_slots.append(st.slot)
        if self.kv_cache is not None:
            # eviction publishes: confirm the written context (prompt +
            # every fed-back sample) so the slot's full blocks go into
            # the prefix index before the blocks are released — they
            # land on the cached-free list, matchable until evicted
            self.kv_cache.commit(st.slot, np.concatenate(
                [st.request.prompt,
                 np.asarray(st.generated[:-1], np.int32)]))
            self.kv_cache.free_slot(st.slot)
        # the scheduler deliberately keeps no reference to finished
        # states (a server runs for ever); callers that need completion
        # records collect the states step()/finish() hand back
        st.status = Status.FINISHED
        st.finished_ms = clock_ms

    # -- queries ------------------------------------------------------------

    @property
    def prefilling(self) -> Optional[RequestState]:
        """The request currently being chunk-prefilled (at most the
        single earliest-admitted PREFILL request makes progress per
        step, whatever the admission policy)."""
        cands = [st for st in self.running.values() if st.status is Status.PREFILL]
        return min(cands, key=lambda s: s.admit_seq) if cands else None

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def next_arrival_ms(self) -> Optional[float]:
        if not self.waiting:
            return None
        return min(st.request.arrival_ms for st in self.waiting)

    def check_conservation(self) -> None:
        """Slot/block invariants: every slot is exactly free or running,
        and the cache accounts for every block and reservation exactly
        once (table rows never dangle)."""
        assert len(self.free_slots) + len(self.running) == self.max_slots
        assert set(self.free_slots).isdisjoint(self.running.keys())
        if self.kv_cache is not None:
            self.kv_cache.check_conservation()
