"""Admission scheduling for the continuous-batching engine.

The scheduler owns request lifecycle: a waiting queue, a fixed pool of
``max_slots`` decode slots, and (for paged transformer serving)
coordination with the :class:`~repro.serving.kv_cache.PagedKVCache`
allocator.  A request is admissible when a slot is free *and* the cache
can reserve its worst-case KV-block footprint (``prompt_len +
max_new_tokens``, which also bounds in-flight speculative draft
positions — the engine clamps per-slot drafts to the remaining
generation budget); reserving the full footprint at admission means a
running request can never hit block starvation mid-flight.

*Which* admissible request is admitted next is a pluggable
**admission policy**, a registry keyed by ``ServeConfig.sched_policy``
(mirroring the router/dispatcher/drafter registries):

* ``fcfs`` (default) — strict arrival order; a head that does not fit
  blocks everything behind it.  No reordering means no starvation.
* ``sjf`` — shortest job first: among arrived requests that fit, admit
  the one with the smallest worst-case footprint.  Lower mean latency
  on mixed-length traffic; long requests can starve under sustained
  short-request load (documented tradeoff).
* ``prefill_first`` — first fit in arrival order: skip over a blocked
  head to keep slots (and the prefill pipeline) busy; earliest-arrival
  otherwise, so reordering only ever happens past a request that could
  not have been admitted anyway.
* ``priority_strict`` / ``edf`` / ``cache_aware`` — the SLO-aware
  policies (:mod:`repro.serving.slo.policies`, registered by the import
  at the bottom of this module): strict priority classes, earliest
  effective deadline, and warm-prefix preference.

**Preemption** (``ServeConfig.slo``): when a higher-priority arrival
cannot be admitted, :meth:`Scheduler.maybe_preempt` evicts a running
victim — the *lowest-priority* one, most remaining work as tiebreak —
by committing its confirmed context, swapping its owned KV blocks to
the host-side :class:`~repro.serving.slo.swap.SwapManager` pool, and
re-queueing it in arrival order as ``PREEMPTED``.  Re-admission goes
through the same policy pick; ``_fits`` gates it on
``kv_cache.can_restore`` and admission restores the blocks (host→device
upload, or re-binding still-published prefix blocks) so generation
resumes at the exact token.

Eviction happens on EOS or on reaching ``max_new_tokens``; the slot and
its blocks return to the free pools in the same step, so the next
admission can reuse them immediately (slots stay full under load — the
whole point of continuous batching).
"""
from __future__ import annotations

import bisect
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Type

import numpy as np

from repro.configs.base import SLOConfig
from repro.serving.kv_cache import PagedKVCache
from repro.serving.request import Request, RequestState, Status

# ---------------------------------------------------------------------------
# Admission-policy registry
# ---------------------------------------------------------------------------

_POLICIES: Dict[str, "AdmissionPolicy"] = {}


def register_policy(cls: Type) -> Type:
    """Class decorator: instantiate and register a policy under cls.name."""
    name = getattr(cls, "name", None)
    if not name or not isinstance(name, str):
        raise ValueError(f"policy class {cls!r} needs a string `name` attribute")
    _POLICIES[name] = cls()
    return cls


def get_policy(name: str) -> "AdmissionPolicy":
    try:
        return _POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown admission policy {name!r}; registered policies: "
            f"{', '.join(available_policies())}"
        ) from None


def available_policies() -> Tuple[str, ...]:
    return tuple(sorted(_POLICIES))


class AdmissionPolicy:
    """Picks the next request to admit.  ``pick`` sees the waiting list
    (arrival order), the clock, and a fit predicate; it returns an index
    into ``waiting`` or None when nothing should be admitted now.  The
    scheduler calls it repeatedly until it declines or slots run out.
    ``sched`` is the calling :class:`Scheduler` (for policies that read
    engine state, e.g. ``cache_aware``'s warm-prefix probe); policies
    must accept ``sched=None`` so they remain directly testable."""

    name = "abstract"

    def pick(self, waiting: Sequence[RequestState], clock_ms: float,
             fits: Callable[[RequestState], bool],
             sched: Optional["Scheduler"] = None) -> Optional[int]:
        raise NotImplementedError


@register_policy
class FCFSPolicy(AdmissionPolicy):
    name = "fcfs"

    def pick(self, waiting, clock_ms, fits, sched=None):
        if not waiting:
            return None
        head = waiting[0]
        if head.request.arrival_ms > clock_ms or not fits(head):
            return None
        return 0


@register_policy
class SJFPolicy(AdmissionPolicy):
    name = "sjf"

    def pick(self, waiting, clock_ms, fits, sched=None):
        best: Optional[int] = None
        for i, st in enumerate(waiting):
            r = st.request
            if r.arrival_ms > clock_ms or not fits(st):
                continue
            if best is None or ((r.total_len, r.arrival_ms, r.uid)
                                < (waiting[best].request.total_len,
                                   waiting[best].request.arrival_ms,
                                   waiting[best].request.uid)):
                best = i
        return best


@register_policy
class PrefillFirstPolicy(AdmissionPolicy):
    name = "prefill_first"

    def pick(self, waiting, clock_ms, fits, sched=None):
        for i, st in enumerate(waiting):
            if st.request.arrival_ms > clock_ms:
                continue
            if fits(st):
                return i
        return None


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------

class Scheduler:
    def __init__(self, max_slots: int, max_len: int,
                 kv_cache: Optional[PagedKVCache] = None,
                 policy: str = "fcfs",
                 slo: Optional[SLOConfig] = None,
                 obs=None):
        self.max_slots = max_slots
        self.max_len = max_len
        self.kv_cache = kv_cache
        self.policy = get_policy(policy)
        self.slo = slo
        if obs is None:
            from repro.obs import Observability

            obs = Observability()          # standalone use (tests)
        self.obs = obs
        self.swap = None
        if slo is not None and slo.preemption and kv_cache is not None:
            from repro.serving.slo.swap import SwapManager

            self.swap = SwapManager(kv_cache, host_blocks=slo.host_blocks,
                                    metrics=obs.metrics)
        self.waiting: List[RequestState] = []
        self.running: Dict[int, RequestState] = {}     # slot -> state
        self.free_slots: List[int] = list(range(max_slots - 1, -1, -1))
        self._admit_seq = 0                            # admission-order tiebreaker
        # Measured decode ms/token (EMA over finished requests) — the
        # service-rate estimate deadline-aware shedding reasons with.
        # None until the first finish measures it: shedding never fires
        # on guesses.
        self._decode_ms_ema: Optional[float] = None

    # Legacy int attributes, now views over the registry (the engine's
    # run() reads the same counters through mark()/delta()).

    @property
    def preemptions(self) -> int:
        """Swap-out count."""
        return int(self.obs.metrics.get("sched_preemptions_total"))

    @property
    def restore_tokens(self) -> int:
        """Context resumed from swapped/re-bound KV."""
        return int(self.obs.metrics.get("sched_restore_tokens_total"))

    @property
    def recompute_tokens(self) -> int:
        """Context re-prefilled after a restore hole."""
        return int(self.obs.metrics.get("sched_recompute_tokens_total"))

    # -- intake -------------------------------------------------------------

    def add(self, request: Request) -> RequestState:
        if request.total_len > self.max_len:
            raise ValueError(
                f"request {request.uid}: prompt_len + max_new_tokens = "
                f"{request.total_len} exceeds serve max_len {self.max_len}")
        if self.kv_cache is not None:
            need = self.kv_cache.blocks_needed(request.total_len)
            if need > self.kv_cache.max_request_blocks:
                # would never fit even an empty pool (one *shard's* pool
                # under a sharded cache): admission would spin on it
                # (fcfs) or skip it for ever (sjf/first-fit)
                raise ValueError(
                    f"request {request.uid}: needs {need} KV blocks but a "
                    f"request can hold at most "
                    f"{self.kv_cache.max_request_blocks}")
        st = RequestState(request)
        self.waiting.append(st)
        self.obs.metrics.counter("sched_requests_total").inc()
        self.obs.request_arrived(request.uid, prompt_len=request.prompt_len,
                                 max_new_tokens=request.max_new_tokens)
        return st

    # -- admission ----------------------------------------------------------

    def _fits(self, st: RequestState) -> bool:
        """Global admission view: does some *free slot's* shard have room
        for this request's worst-case footprint?  With a single pool
        every free slot is equivalent, so one probe suffices; a sharded
        cache is probed per free slot (slots are bound to shards)."""
        if self.kv_cache is None:
            return True
        if st.status is Status.PREEMPTED:
            return self.kv_cache.can_restore(st.swap_record)
        slots = self.free_slots
        if self.kv_cache.num_shards == 1:
            slots = slots[-1:] or [0]   # one pool: any slot is the same probe
        return any(
            self.kv_cache.can_allocate_slot_on(slot, st.request.total_len,
                                               prompt=st.request.prompt)
            for slot in reversed(slots))

    def _pick_slot(self, st: RequestState) -> int:
        """The free slot this admission lands on: LIFO for a single pool
        (exactly the pre-mesh behaviour), else the LIFO-first free slot
        whose shard can take the reservation.  Only called after
        ``_fits`` said yes, so a fitting slot exists."""
        if (self.kv_cache is None or self.kv_cache.num_shards == 1
                or st.status is Status.PREEMPTED):
            return self.free_slots.pop()
        for i in range(len(self.free_slots) - 1, -1, -1):
            slot = self.free_slots[i]
            if self.kv_cache.can_allocate_slot_on(slot, st.request.total_len,
                                                  prompt=st.request.prompt):
                return self.free_slots.pop(i)
        raise AssertionError("admit without a fitting shard")  # _fits lied

    def admit(self, clock_ms: float) -> List[RequestState]:
        """Admit from the queue under the configured policy: arrived
        requests only, while a slot (and, when paged, an unreserved
        worst-case KV footprint) is available.  A ``PREEMPTED`` pick is
        *restored* instead of freshly allocated: its recorded KV blocks
        come back (host→device upload and/or prefix re-bind) and prefill
        resumes at the restored position — all the way at the confirmed
        frontier when the whole context came back, in which case it goes
        straight to DECODE."""
        admitted = []
        while self.free_slots:
            idx = self.policy.pick(self.waiting, clock_ms, self._fits,
                                   sched=self)
            if idx is None:
                break
            st = self.waiting.pop(idx)
            slot = self._pick_slot(st)
            st.cached_tokens = 0
            if st.status is Status.PREEMPTED:
                rec, st.swap_record = st.swap_record, None
                resume = rec.context_len
                if self.kv_cache is not None:
                    resume = self.kv_cache.restore_slot(slot, rec, self.swap)
                    self.swap.release(rec)
                    m = self.obs.metrics
                    m.counter("sched_restore_tokens_total").inc(resume)
                    m.counter("sched_recompute_tokens_total").inc(
                        rec.context_len - resume)
                    self.obs.tracer.instant(
                        "restore", uid=st.request.uid, restored=resume,
                        recomputed=rec.context_len - resume)
                st.prefill_pos = resume
                st.status = (Status.DECODE if resume >= st.prefill_target
                             else Status.PREFILL)
            else:
                if self.kv_cache is not None:
                    # prefix caching: matched prompt-prefix blocks are
                    # bound into the slot's table (already-written
                    # context), so prefill resumes at the first uncached
                    # token
                    st.cached_tokens = self.kv_cache.allocate_slot(
                        slot, st.request.total_len, prompt=st.request.prompt)
                st.status = Status.PREFILL
                st.prefill_pos = st.cached_tokens
            st.slot = slot
            st.admitted_ms = clock_ms
            st.admit_seq = self._admit_seq
            self._admit_seq += 1
            self.running[slot] = st
            self.obs.metrics.histogram("request_queue_ms").observe(
                max(clock_ms - st.request.arrival_ms, 0.0))
            self.obs.request_phase(
                st.request.uid,
                "decode" if st.status is Status.DECODE else "prefill",
                slot=slot)
            admitted.append(st)
        return admitted

    # -- eviction -----------------------------------------------------------

    def finish(self, st: RequestState, clock_ms: float) -> None:
        assert st.slot in self.running and self.running[st.slot] is st
        del self.running[st.slot]
        self.free_slots.append(st.slot)
        if self.kv_cache is not None:
            # eviction publishes: confirm the written context (prompt +
            # every fed-back sample) so the slot's full blocks go into
            # the prefix index before the blocks are released — they
            # land on the cached-free list, matchable until evicted
            self.kv_cache.commit(st.slot, np.concatenate(
                [st.request.prompt,
                 np.asarray(st.generated[:-1], np.int32)]))
            self.kv_cache.free_slot(st.slot)
        # the scheduler deliberately keeps no reference to finished
        # states (a server runs for ever); callers that need completion
        # records collect the states step()/finish() hand back
        st.status = Status.FINISHED
        st.finished_ms = clock_ms
        if st.first_token_ms is not None and len(st.generated) > 1:
            per_tok = ((clock_ms - st.first_token_ms)
                       / (len(st.generated) - 1))
            if per_tok > 0:
                ema = self._decode_ms_ema
                self._decode_ms_ema = (per_tok if ema is None
                                       else 0.8 * ema + 0.2 * per_tok)
        m = self.obs.metrics
        m.counter("sched_finished_total").inc()
        m.counter("generated_tokens_total").inc(len(st.generated))
        # final (post-restore) per-request values, so the registry sums
        # match the old sum-over-done-states prefix accounting exactly
        m.counter("prefix_cached_tokens_total").inc(st.cached_tokens)
        m.counter("prefix_prompt_tokens_total").inc(st.request.prompt_len)
        m.histogram("request_latency_ms").observe(st.latency_ms())
        self.obs.request_finished(st.request.uid)

    # -- preemption (repro.serving.slo) --------------------------------------

    def preempt(self, st: RequestState, clock_ms: float) -> None:
        """Evict a running request to make room for a more urgent one:
        commit its confirmed context (published full blocks stay
        matchable), swap its owned KV blocks to the host pool, release
        the slot, and put it back in the waiting queue — at its
        *arrival-order* position, not the back of the line — as
        ``PREEMPTED``."""
        assert self.swap is not None, "preemption requires ServeConfig.slo"
        slot = st.slot
        assert self.running.get(slot) is st, f"slot {slot} not running"
        del self.running[slot]
        self.free_slots.append(slot)
        ctx = st.context_len
        if self.kv_cache is not None:
            self.kv_cache.commit(slot, st.confirmed_tokens[:ctx])
            st.swap_record = self.kv_cache.swap_out(
                slot, self.swap, uid=st.request.uid,
                total_len=st.request.total_len, context_len=ctx)
        st.slot = -1
        st.status = Status.PREEMPTED
        st.preemptions += 1
        self.obs.metrics.counter("sched_preemptions_total").inc()
        self.obs.tracer.instant("preempt", uid=st.request.uid, slot=slot,
                                context_len=ctx)
        self.obs.request_phase(st.request.uid, "preempted")
        keys = [(w.request.arrival_ms, w.request.uid) for w in self.waiting]
        self.waiting.insert(
            bisect.bisect_left(keys, (st.request.arrival_ms, st.request.uid)),
            st)

    def maybe_preempt(self, clock_ms: float) -> int:
        """Preemption decision point, called once per engine step before
        admission.  The candidate is the *admission policy's* next
        choice (its pick under a permissive fit) — preemption enforces
        the policy's ordering against running work, it does not impose
        a second one.  Deciding the candidate any other way thrashes:
        evicting a victim for an urgent arrival the policy would not
        actually admit next just burns a swap round trip (e.g.
        ``cache_aware`` hands a freed slot back to the warm victim it
        came from).  While that candidate is in the preempting class
        band (``slo.preempt_threshold``) and cannot be admitted, evict
        the strictly-lower-priority victim with the lowest class, then
        the most remaining work (its progress is the cheapest to set
        aside — re-admission restores, it does not recompute), then the
        latest admission.  Declines gracefully: no victim, victim at
        its preemption cap, or host pool full ⇒ stop (the candidate
        waits, which is exactly pre-SLO behaviour)."""
        if self.swap is None:
            return 0
        evicted = 0
        while self.waiting:
            idx = self.policy.pick(self.waiting, clock_ms,
                                   lambda st: True, sched=self)
            if idx is None:
                break
            cand = self.waiting[idx]
            if int(cand.request.priority) > self.slo.preempt_threshold:
                break            # urgent enough to queue-jump, not to evict
            if self.free_slots and self._fits(cand):
                break                                   # admit() will take it
            victims = [st for st in self.running.values()
                       if int(st.request.priority) > int(cand.request.priority)
                       and st.preemptions < self.slo.max_preemptions]
            if not victims:
                break
            victim = max(
                victims,
                key=lambda s: (int(s.request.priority),
                               s.request.total_len - s.context_len,
                               s.admit_seq))
            if (self.kv_cache is not None and not self.swap.can_store(
                    self.kv_cache.swap_footprint(victim.slot))):
                break
            self.preempt(victim, clock_ms)
            evicted += 1
        return evicted

    # -- deadline-aware admission shedding (repro.serving.slo) ---------------

    def shed_unmeetable(self, clock_ms: float) -> List[RequestState]:
        """Reject waiting requests whose effective deadline is provably
        unmeetable, instead of queueing work that can only miss.  Gated
        on ``slo.shed`` (off by default — a shed request gets *no*
        tokens) and on a *measured* decode rate: until the first finish
        establishes ms/token, nothing is shed.

        The proof is the most optimistic schedule the engine could give
        the request: admitted right now, prefill at one chunk per step,
        then its full ``max_new_tokens`` budget at the measured ms/token
        (an early EOS is not knowable at the door — the SLO target is
        stated for the full budget, as ``slo_tokens_per_s`` deadlines
        are).  If even that finishes after the deadline, the request is
        finished with :attr:`Status.SHED` and counted in
        ``requests_shed_total``.  Deadline-free and ``PREEMPTED``
        requests are never shed (a preempted request holds swapped KV —
        its sunk work is worth more than the queue slot)."""
        if (self.slo is None or not self.slo.shed
                or self._decode_ms_ema is None):
            return []
        ms_tok = self._decode_ms_ema
        chunk = 1
        if self.kv_cache is not None:
            chunk = self.kv_cache.serve.prefill_chunk
        shed: List[RequestState] = []
        keep: List[RequestState] = []
        for st in self.waiting:
            r = st.request
            d = r.effective_deadline_ms
            if (d is None or st.status is Status.PREEMPTED
                    or r.arrival_ms > clock_ms):
                keep.append(st)
                continue
            steps = -(-r.prompt_len // chunk) + r.max_new_tokens
            if clock_ms + ms_tok * steps > d:
                st.status = Status.SHED
                st.finished_ms = clock_ms
                m = self.obs.metrics
                m.counter("requests_shed_total").inc()
                self.obs.tracer.instant("shed", uid=r.uid, deadline_ms=d,
                                        needed_ms=ms_tok * steps)
                self.obs.request_finished(r.uid)
                shed.append(st)
            else:
                keep.append(st)
        self.waiting = keep
        return shed

    # -- queries ------------------------------------------------------------

    @property
    def prefilling(self) -> Optional[RequestState]:
        """The request currently being chunk-prefilled (at most the
        single earliest-admitted PREFILL request makes progress per
        step, whatever the admission policy)."""
        cands = [st for st in self.running.values() if st.status is Status.PREFILL]
        return min(cands, key=lambda s: s.admit_seq) if cands else None

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def next_arrival_ms(self) -> Optional[float]:
        if not self.waiting:
            return None
        return min(st.request.arrival_ms for st in self.waiting)

    def check_conservation(self) -> None:
        """Slot/block invariants: every slot is exactly free or running,
        and the cache accounts for every block and reservation exactly
        once (table rows never dangle).  With preemption enabled, the
        host pool conserves too: every allocated host block belongs to
        exactly one live swap record, every record to exactly one
        PREEMPTED waiting request — so a swapped block is counted on the
        host side only, against neither the device free list nor any
        reservation."""
        assert len(self.free_slots) + len(self.running) == self.max_slots
        assert set(self.free_slots).isdisjoint(self.running.keys())
        if self.kv_cache is not None:
            self.kv_cache.check_conservation()
        for st in self.waiting:
            if st.status is Status.PREEMPTED:
                assert self.swap is not None
                assert st.swap_record is not None, st.request.uid
                assert self.swap.records.get(
                    st.request.uid) is st.swap_record, st.request.uid
            else:
                assert st.status is Status.QUEUED, st.request.uid
                assert st.swap_record is None, st.request.uid
        if self.swap is not None:
            self.swap.check_conservation()
            preempted = {st.request.uid for st in self.waiting
                         if st.status is Status.PREEMPTED}
            assert preempted == set(self.swap.records), (
                preempted, set(self.swap.records))
        for st in self.running.values():
            assert st.swap_record is None, st.request.uid


# Registered last so the registry (and `ServeConfig.sched_policy`
# validation) always includes the SLO-aware policies; the module imports
# `register_policy` back from here, which is safe because everything it
# needs is defined above.
from repro.serving.slo import policies as _slo_policies  # noqa: E402,F401
