"""Continuous-batching serving engine: mixed prefill/decode steps over a
paged KV cache.

The static :class:`~repro.serving.engine.ServingEngine` runs one batch
in lockstep: one prompt length, one generation length, the whole batch
finishes together.  This engine instead keeps a fixed pool of
``max_slots`` decode slots full: requests are admitted FCFS as slots and
KV blocks free up, prompts are ingested in ``prefill_chunk``-token
chunks *interleaved with* one decode step for every active slot, and
finished requests are evicted immediately so their slot is refilled.

Every engine step is one call of a jit'd function of **static shape**:

    rows = [max_slots decode rows] + [prefill_chunk chunk rows]

Row ``i < max_slots`` is slot ``i``'s decode token (masked when the slot
is idle or mid-prefill); the tail rows carry the current chunk of the
oldest prefilling request (masked when nothing is prefilling — a
decode-only variant with ``rows = max_slots`` also exists, so steady
state does not pay for empty chunk rows).  Each row carries its token
id, slot, absolute position and context length; K/V are projected,
written into the slot's pool blocks, and attention reads back through
the block table (:func:`repro.kernels.decode_attention.paged_decode_attention`)
— writing the chunk's K/V *before* the attention read makes per-row
"attend to my own prefix" exactly causal attention, which is what lets
prefill and decode share one kernel and one compiled step.  Requests
entering/leaving only change *values* (tables, lengths, tokens), never
shapes: no recompilation as traffic churns.

Per-row absolute positions and token ids ride to the MoE layers through
:class:`~repro.core.context.MoEContext`, so hash/content routing stays
correct under slot reuse (a reused slot's rows carry the new request's
identity, not the previous occupant's).

Recurrent families (xlstm) keep O(1) state keyed by slot: every step is
a decode step of shape ``(max_slots, 1)``; "prefill" feeds prompt tokens
one per step into the slot's state, which is zero-reset at admission.
Hybrid zamba (shared-attention cache with a single batch-wide length
scalar) and encdec (per-request encoder memory) are not supported yet.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.core.context import MoEContext
from repro.core.moe import moe_ffn_apply
from repro.distributed.sharding import Rules, shard, use_rules
from repro.kernels.decode_attention import paged_decode_attention
from repro.models import layers as L
from repro.models.attention import _project_qkv
from repro.models.registry import get_family
from repro.models.transformer import _is_moe_layer
from repro.serving.kv_cache import PagedKVCache
from repro.serving.request import Request, RequestState, Status
from repro.serving.scheduler import Scheduler

_PAGED_FAMILIES = ("decoder_lm", "vlm", "m6")
_RECURRENT_FAMILIES = ("xlstm",)


# ---------------------------------------------------------------------------
# Paged transformer forward (one mixed prefill/decode step)
# ---------------------------------------------------------------------------

def _paged_block(bp, x, cfg: ModelConfig, *, moe_layer: bool, positions,
                 lengths, row_tables, wb, wo, kp, vp, ctx):
    """One pre-norm block over the flat row batch ``x: (1, N, d)``.

    K/V for every row are written into the pool at (wb, wo) *before* the
    paged-attention read, so chunk rows see their same-step predecessors
    — exact causal semantics for prefill and decode alike.  Masked rows
    write into the garbage block and read length 0.
    """
    N = x.shape[1]
    h = L.norm_apply(bp["ln_attn"], x, cfg)
    q, k, v = _project_qkv(bp["attn"], h, cfg, positions)       # (1, N, H*, D)
    kp = kp.at[wb, :, wo].set(k[0].astype(kp.dtype))            # (N, Hkv, D) scatter
    vp = vp.at[wb, :, wo].set(v[0].astype(vp.dtype))
    out = paged_decode_attention(q[0], kp, vp, row_tables, lengths)  # (N, Hq, D)
    attn_out = L.dense_apply(bp["attn"]["wo"], out.reshape(1, N, -1), cfg)
    x = x + attn_out
    x = shard(x, "batch", "seq", "embed")

    h = L.norm_apply(bp["ln_ffn"], x, cfg)
    if moe_layer:
        ffn_out, _ = moe_ffn_apply(bp["ffn"], h, cfg, ctx=ctx)
    else:
        ffn_out = L.ffn_apply(bp["ffn"], h, cfg)
    x = x + ffn_out
    x = shard(x, "batch", "seq", "embed")
    return x, kp, vp


def _paged_forward(params, cfg: ModelConfig, tokens, ctx_ids, positions,
                   lengths, row_tables, wb, wo, k_pools, v_pools, *,
                   temperature: float, key):
    """Flat-row step: embed -> blocks (scan or unrolled) -> sample.

    Returns (next_token per row (N,), new k_pools, new v_pools)."""
    x = L.embedding_apply(params["embed"], tokens[None], cfg)   # (1, N, d)
    pos2 = positions[None]
    if cfg.pos_embed == "learned":
        x = x + params["pos_embed"].astype(x.dtype)[positions][None]
    ctx = MoEContext(is_training=False).replace(token_ids=ctx_ids[None],
                                                positions=pos2)
    x = shard(x, "batch", "seq", "embed")

    blocks = params["blocks"]
    if isinstance(blocks, (list, tuple)):       # unrolled (mixed layer kinds)
        ks, vs = [], []
        for i, bp in enumerate(blocks):
            x, kp, vp = _paged_block(
                bp, x, cfg, moe_layer=_is_moe_layer(cfg, i), positions=pos2,
                lengths=lengths, row_tables=row_tables, wb=wb, wo=wo,
                kp=k_pools[i], vp=v_pools[i], ctx=ctx)
            ks.append(kp)
            vs.append(vp)
        k_pools, v_pools = jnp.stack(ks), jnp.stack(vs)
    else:
        moe_layer = _is_moe_layer(cfg, 0)

        def body(h, scanned):
            bp, kp, vp = scanned
            h, kp, vp = _paged_block(
                bp, h, cfg, moe_layer=moe_layer, positions=pos2,
                lengths=lengths, row_tables=row_tables, wb=wb, wo=wo,
                kp=kp, vp=vp, ctx=ctx)
            return h, (kp, vp)

        x, (k_pools, v_pools) = jax.lax.scan(body, x, (blocks, k_pools, v_pools))

    x = L.norm_apply(params["final_norm"], x, cfg)
    unembed = params.get("unembed", params["embed"])
    logits = L.unembed_apply(unembed, x, cfg)[0].astype(jnp.float32)  # (N, V)
    if temperature <= 0.0:
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    else:
        next_tok = jax.random.categorical(key, logits / temperature,
                                          axis=-1).astype(jnp.int32)
    return next_tok, k_pools, v_pools


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class ContinuousEngine:
    """Continuous-batching engine over a fixed slot pool.

    ``temperature`` is engine-level (0 = greedy, matching the static
    engine's sampling math token for token).  Drive it either with
    :meth:`run` (trace of :class:`Request`, virtual clock, per-request
    latencies) or the batch-parity convenience :meth:`generate`.
    """

    def __init__(self, cfg: ModelConfig, params, serve: ServeConfig = ServeConfig(),
                 *, temperature: float = 0.0, seed: int = 0,
                 rules: Optional[Rules] = None):
        if cfg.family in _PAGED_FAMILIES:
            self.mode = "paged"
            if cfg.attn_logit_softcap > 0:
                raise NotImplementedError(
                    "paged decode attention does not implement logit softcap")
            if cfg.moe.moe_attention:
                raise NotImplementedError(
                    "moe_attention has no cached decode path")
        elif cfg.family in _RECURRENT_FAMILIES:
            self.mode = "recurrent"
        else:
            raise NotImplementedError(
                f"continuous batching not implemented for family "
                f"{cfg.family!r} (zamba's shared-attention cache keeps one "
                f"batch-wide length; encdec needs per-request encoder memory)")
        self.cfg = cfg
        self.fam = get_family(cfg)
        self.params = params
        self.serve = serve
        self.temperature = float(temperature)
        self.rules = rules
        self._key = jax.random.PRNGKey(seed)
        self.steps = 0

        if self.mode == "paged":
            self.cache: Optional[PagedKVCache] = PagedKVCache(cfg, serve)
            self.scheduler = Scheduler(serve.max_slots, serve.max_len, self.cache)
            temp = self.temperature

            def step_fn(p, k_pools, v_pools, tokens, ctx_ids, positions,
                        lengths, row_tables, wb, wo, key):
                with use_rules(rules):
                    return _paged_forward(p, cfg, tokens, ctx_ids, positions,
                                          lengths, row_tables, wb, wo,
                                          k_pools, v_pools,
                                          temperature=temp, key=key)

            # Two static shapes only: N = max_slots (decode-only) and
            # N = max_slots + prefill_chunk (mixed) — jit caches both.
            self._step_fn = jax.jit(step_fn, donate_argnums=(1, 2))
        else:
            self.cache = None
            self.scheduler = Scheduler(serve.max_slots, serve.max_len, None)
            self._state = self.fam.init_state(cfg, serve.max_slots, serve.max_len)
            temp = self.temperature
            serve_ctx = MoEContext(is_training=False)
            fam = self.fam

            def rec_step(p, state, tokens, key):
                with use_rules(rules):
                    logits, new_state = fam.decode(p, tokens, state, cfg,
                                                   ctx=serve_ctx)
                lg = logits[:, -1, :].astype(jnp.float32)
                if temp <= 0.0:
                    tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                else:
                    tok = jax.random.categorical(key, lg / temp,
                                                 axis=-1).astype(jnp.int32)
                return tok, new_state

            def reset_slot(state, slot):
                return jax.tree_util.tree_map(
                    lambda a: a.at[slot].set(jnp.zeros_like(a[slot])), state)

            self._step_fn = jax.jit(rec_step, donate_argnums=(1,))
            self._reset_fn = jax.jit(reset_slot, donate_argnums=(0,))

    # -- one engine step ----------------------------------------------------

    def step(self, clock_ms: float = 0.0) -> List[RequestState]:
        """Admit, run one mixed prefill/decode step, process samples.
        Returns the requests that finished during this step."""
        admitted = self.scheduler.admit(clock_ms)
        if self.mode == "recurrent":
            for st in admitted:
                self._state = self._reset_fn(self._state, jnp.int32(st.slot))
        if not self.scheduler.running:
            return []
        self._key, sub = jax.random.split(self._key)
        if self.mode == "paged":
            finished = self._paged_host_step(sub, clock_ms)
        else:
            finished = self._recurrent_host_step(sub, clock_ms)
        self.steps += 1
        return finished

    def _paged_host_step(self, key, clock_ms: float) -> List[RequestState]:
        serve, cache, sched = self.serve, self.cache, self.scheduler
        S = serve.max_slots
        pre = sched.prefilling
        chunk = 0
        if pre is not None:
            chunk = min(serve.prefill_chunk,
                        pre.request.prompt_len - pre.prefill_pos)
        N = S + (serve.prefill_chunk if pre is not None else 0)

        tokens = np.zeros(N, np.int32)
        ctx_ids = np.full(N, -1, np.int32)
        positions = np.zeros(N, np.int32)
        lengths = np.zeros(N, np.int32)
        wb = np.full(N, cache.garbage_block, np.int32)
        wo = np.zeros(N, np.int32)
        row_tables = np.full((N, serve.blocks_per_slot), cache.garbage_block,
                             np.int32)
        sample_rows: List[Tuple[int, RequestState]] = []

        for slot, st in sched.running.items():
            if st.status is not Status.DECODE:
                continue
            pos = st.context_len
            tokens[slot] = ctx_ids[slot] = st.last_token
            positions[slot] = pos
            lengths[slot] = pos + 1
            wb[slot], wo[slot] = cache.write_coords(slot, pos)
            row_tables[slot] = cache.block_table[st.slot]
            sample_rows.append((slot, st))

        if pre is not None:
            prompt = pre.request.prompt
            for j in range(chunk):
                row, p = S + j, pre.prefill_pos + j
                tokens[row] = ctx_ids[row] = prompt[p]
                positions[row] = p
                lengths[row] = p + 1
                wb[row], wo[row] = cache.write_coords(pre.slot, p)
                row_tables[row] = cache.block_table[pre.slot]
                if p == pre.request.prompt_len - 1:
                    sample_rows.append((row, pre))

        next_tok, k_pools, v_pools = self._step_fn(
            self.params, cache.k_pool, cache.v_pool, tokens, ctx_ids,
            positions, lengths, row_tables, wb, wo, key)
        cache.update_pools(k_pools, v_pools)

        if pre is not None:
            pre.prefill_pos += chunk
            if pre.prefill_pos == pre.request.prompt_len:
                pre.status = Status.DECODE
        return self._collect_samples(np.asarray(next_tok), sample_rows, clock_ms)

    def _recurrent_host_step(self, key, clock_ms: float) -> List[RequestState]:
        S = self.serve.max_slots
        tokens = np.zeros((S, 1), np.int32)
        sample_rows: List[Tuple[int, RequestState]] = []
        prefill_advanced: List[RequestState] = []
        for slot, st in self.scheduler.running.items():
            if st.status is Status.PREFILL:
                tokens[slot, 0] = st.request.prompt[st.prefill_pos]
                prefill_advanced.append(st)
                if st.prefill_pos + 1 == st.request.prompt_len:
                    sample_rows.append((slot, st))
            else:
                tokens[slot, 0] = st.last_token
                sample_rows.append((slot, st))

        next_tok, self._state = self._step_fn(self.params, self._state,
                                              tokens, key)
        for st in prefill_advanced:
            st.prefill_pos += 1
            if st.prefill_pos == st.request.prompt_len:
                st.status = Status.DECODE
        return self._collect_samples(np.asarray(next_tok), sample_rows, clock_ms)

    def _collect_samples(self, next_tok: np.ndarray, sample_rows, clock_ms: float
                         ) -> List[RequestState]:
        finished = []
        for row, st in sample_rows:
            st.generated.append(int(next_tok[row]))
            if st.first_token_ms is None:
                st.first_token_ms = clock_ms
            if st.done():
                self.scheduler.finish(st, clock_ms)
                finished.append(st)
        return finished

    # -- drivers ------------------------------------------------------------

    def run(self, requests: List[Request], *,
            on_finish: Optional[Callable[[RequestState], None]] = None
            ) -> Tuple[Dict[int, List[int]], Dict[str, float]]:
        """Serve a trace to completion.  The clock is wall time since the
        call, fast-forwarded over idle gaps to the next arrival (so a
        sparse trace doesn't busy-wait); request latency = finish - arrival
        on that clock.  Returns ({uid: generated tokens}, stats)."""
        for r in requests:
            self.scheduler.add(r)
        t0 = time.perf_counter()
        steps0 = self.steps
        clock = 0.0
        done: List[RequestState] = []
        while self.scheduler.has_work():
            clock = max(clock, (time.perf_counter() - t0) * 1e3)
            if not self.scheduler.running:
                nxt = self.scheduler.next_arrival_ms()
                if nxt is not None and nxt > clock:
                    clock = nxt                      # idle: jump to next arrival
            for st in self.step(clock):
                done.append(st)
                if on_finish is not None:
                    on_finish(st)
        total_ms = max(clock, (time.perf_counter() - t0) * 1e3)
        self.scheduler.check_conservation()

        from repro.serving.trace import latency_stats

        stats = latency_stats([st.latency_ms() for st in done], total_ms,
                              sum(len(st.generated) for st in done))
        stats["steps"] = float(self.steps - steps0)
        return {st.request.uid: list(st.generated) for st in done}, stats

    def generate(self, prompts: jax.Array, num_tokens: int, seed: int = 0):
        """Static-engine-compatible entry: (B, S) prompts, all admitted at
        t=0, each generating ``num_tokens``.  Returns ((B, num_tokens)
        int32, stats) — token-identical to ``ServingEngine.generate``
        under greedy decoding."""
        del seed  # sampling key is engine-level; greedy needs none
        prompts = np.asarray(prompts)
        reqs = [Request(uid=i, prompt=prompts[i], max_new_tokens=num_tokens)
                for i in range(prompts.shape[0])]
        out, stats = self.run(reqs)
        toks = jnp.asarray(np.stack([out[i] for i in range(prompts.shape[0])]),
                           jnp.int32)
        return toks, stats
