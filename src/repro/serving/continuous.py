"""Continuous-batching serving engine: mixed prefill/decode steps over a
paged KV cache, with optional speculative decoding.

The static :class:`~repro.serving.engine.ServingEngine` runs one batch
in lockstep: one prompt length, one generation length, the whole batch
finishes together.  This engine instead keeps a fixed pool of
``max_slots`` decode slots full: requests are admitted as slots and KV
blocks free up (admission policy pluggable — see
``repro.serving.scheduler``), prompts are ingested in
``prefill_chunk``-token chunks *interleaved with* one decode step for
every active slot, and finished requests are evicted immediately so
their slot is refilled.

Every engine step is one call of a jit'd function of **static shape**:

    rows = [max_slots decode rows] + [prefill_chunk chunk rows]

Row ``i < max_slots`` is slot ``i``'s decode token (masked when the slot
is idle or mid-prefill); the tail rows carry the current chunk of the
oldest prefilling request (masked when nothing is prefilling — a
decode-only variant with ``rows = max_slots`` also exists, so steady
state does not pay for empty chunk rows).  Each row carries its token
id, slot, absolute position and context length; K/V are projected,
written into the slot's pool blocks, and attention reads back through
the block table (:func:`repro.kernels.decode_attention.paged_decode_attention`)
— writing the chunk's K/V *before* the attention read makes per-row
"attend to my own prefix" exactly causal attention, which is what lets
prefill and decode share one kernel and one compiled step.  Requests
entering/leaving only change *values* (tables, lengths, tokens), never
shapes: no recompilation as traffic churns.

**Speculative decoding** (``ServeConfig.spec``) multiplies decode
throughput by making tokens-per-slot-per-step variable while the step
stays static-shape.  When no request is mid-prefill, the engine runs a
*verify* step instead of a decode step: a drafter
(``repro.serving.speculative``) proposes up to ``gamma`` continuation
tokens per slot, and the step scores ``gamma + 1`` rows per slot —
row ``j`` is exactly a prefill-chunk-style row (token ``j`` of the
draft at absolute position ``c + j``), so the verify variant reuses the
mixed-step machinery unchanged, per-row positions/token ids threading
through :class:`~repro.core.context.MoEContext` exactly as chunk rows
do.  The acceptance rule (``speculative.accept``) emits the accepted
draft prefix plus one bonus token: temperature 0 is token-identical to
non-speculative decoding, temperature > 0 preserves the target
distribution.  (Token-identity assumes batch-composition-invariant
routing — dense FFN or dropless dispatch; a finite ``capacity_factor``
derives per-expert capacity from the row count, which differs between
the decode and verify step shapes, so capacity-limited MoE dispatch can
drop differently across them — the same caveat non-speculative
continuous serving already carries vs the static engine.)  Rejected draft positions are undone by
``PagedKVCache.truncate_slot`` — a pure length rewind through the block
table, over-allocated blocks back on the free list, no copying.  The
compiled-variant census stays tiny: the two existing shapes plus one
verify shape (``rows = max_slots * (gamma + 1)``), still zero
recompiles as traffic churns.

Temperature > 0 sampling uses a **per-row key** folded from the fixed
engine key, the row's slot and its absolute position: samples are
independent across slots and reproducible under slot reuse (a replayed
trace samples identically however admission interleaves).

Recurrent families (xlstm) keep O(1) state keyed by slot: every step is
a decode step of shape ``(max_slots, 1)``; "prefill" feeds prompt tokens
one per step into the slot's state, which is zero-reset at admission.
Speculative mode requires the paged cache (recurrent slot states have
no cheap rollback).  Hybrid zamba (shared-attention cache with a single
batch-wide length scalar) and encdec (per-request encoder memory) are
not supported yet.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.core.context import MoEContext
from repro.core.moe import moe_ffn_apply
from repro.distributed.sharding import Rules, shard, use_rules
from repro.kernels.decode_attention import (
    paged_update_attention,
    quantized_paged_update_attention,
    sharded_paged_update_attention,
    sharded_quantized_paged_update_attention,
)
from repro.models import layers as L
from repro.models.attention import _project_qkv
from repro.models.registry import get_family
from repro.models.transformer import _is_moe_layer
from repro.obs import Observability
from repro.serving.kv_cache import PagedKVCache, ShardedPagedKVCache
from repro.serving.request import Request, RequestState, Status
from repro.serving.scheduler import Scheduler
from repro.serving.speculative.accept import accept_greedy_ids, accept_rejection
from repro.serving.speculative.base import DraftItem

_PAGED_FAMILIES = ("decoder_lm", "vlm", "m6")
_RECURRENT_FAMILIES = ("xlstm",)


# ---------------------------------------------------------------------------
# Paged transformer forward (one mixed prefill/decode/verify step)
# ---------------------------------------------------------------------------

def _paged_block(bp, x, cfg: ModelConfig, *, moe_layer: bool, positions,
                 lengths, row_tables, wb, wo, kp, vp, ctx, mesh=None,
                 ksc=None, vsc=None, policy=None):
    """One pre-norm block over the flat row batch ``x: (1, N, d)``.

    K/V for every row are written into the pool at (wb, wo) *before* the
    paged-attention read, so chunk rows see their same-step predecessors
    — exact causal semantics for prefill and decode alike.  Masked rows
    write into the garbage block and read length 0.

    With ``mesh``, the write + attention pair runs under shard_map over
    the data axis: rows are laid out shard-major (each shard's rows
    cover its own slots) and (wb, wo)/row_tables carry shard-local block
    ids into the shard's private pool slice.  This is sequential with —
    never nested inside — the MoE dispatcher's own shard_map.
    """
    N = x.shape[1]
    h = L.norm_apply(bp["ln_attn"], x, cfg)
    q, k, v = _project_qkv(bp["attn"], h, cfg, positions)       # (1, N, H*, D)
    if policy is not None:
        if mesh is None:
            out, kp, vp, ksc, vsc = quantized_paged_update_attention(
                q[0], k[0], v[0], kp, vp, ksc, vsc, wb, wo, row_tables,
                lengths, policy=policy)
        else:
            out, kp, vp, ksc, vsc = sharded_quantized_paged_update_attention(
                q[0], k[0], v[0], kp, vp, ksc, vsc, wb, wo, row_tables,
                lengths, policy=policy, mesh=mesh, axis="data")
    elif mesh is None:
        out, kp, vp = paged_update_attention(
            q[0], k[0], v[0], kp, vp, wb, wo, row_tables, lengths)
    else:
        out, kp, vp = sharded_paged_update_attention(
            q[0], k[0], v[0], kp, vp, wb, wo, row_tables, lengths,
            mesh=mesh, axis="data")
    attn_out = L.dense_apply(bp["attn"]["wo"], out.reshape(1, N, -1), cfg)
    x = x + attn_out
    x = shard(x, "batch", "seq", "embed")

    h = L.norm_apply(bp["ln_ffn"], x, cfg)
    if moe_layer:
        with jax.named_scope("moe_ffn"):
            ffn_out, aux = moe_ffn_apply(bp["ffn"], h, cfg, ctx=ctx)
        telem = _layer_telemetry(aux, cfg.moe.num_experts)
    else:
        ffn_out = L.ffn_apply(bp["ffn"], h, cfg)
        telem = _layer_telemetry(None, cfg.moe.num_experts)
    x = x + ffn_out
    x = shard(x, "batch", "seq", "embed")
    return x, kp, vp, ksc, vsc, telem


def _layer_telemetry(aux, num_experts: int) -> dict:
    """Per-layer routing telemetry with a shape uniform across MoE and
    dense layers, so the per-layer stack (scan ys or manual) is a clean
    ``(L, ...)`` pytree.  Dense layers contribute exact zeros."""
    if aux is None:
        return {"expert_tokens": jnp.zeros((num_experts,), jnp.float32),
                "gate_entropy": jnp.zeros((), jnp.float32),
                "dropped": jnp.zeros((), jnp.float32),
                "routed_choices": jnp.zeros((), jnp.float32)}
    choices = aux["moe_routed_choices"]
    return {"expert_tokens": aux["moe_expert_tokens"],
            "gate_entropy": aux["moe_gate_entropy"],
            # drop *count* (fraction × denominator): summable across
            # steps, and exactly 0.0 when the fraction is exactly 0.0
            "dropped": aux["moe_dropped_fraction"] * choices,
            "routed_choices": choices}


def _paged_logits(params, cfg: ModelConfig, tokens, ctx_ids, positions,
                  lengths, row_tables, wb, wo, k_pools, v_pools, mesh=None,
                  k_scales=None, v_scales=None, policy=None):
    """Flat-row forward: embed -> blocks (scan or unrolled) -> logits.

    Returns (float32 logits (N, V), new k_pools, new v_pools, new
    k_scales, new v_scales, telem) — ``telem`` is the per-layer routing
    telemetry stack ({} for dense models; see ``_layer_telemetry``).
    Shared by the decode/mixed step (which samples on top) and the
    speculative verify step (which ships the logits to the host
    acceptance rule).  ``policy`` (a quantized
    :class:`repro.quant.KVQuantPolicy`) switches the K/V write +
    attention to the quantized ops, with the (L, P, Hkv) scale pools
    threading alongside the code pools; None keeps the full-precision
    path byte-identical (the scale leaves stay None)."""
    x = L.embedding_apply(params["embed"], tokens[None], cfg)   # (1, N, d)
    pos2 = positions[None]
    if cfg.pos_embed == "learned":
        x = x + params["pos_embed"].astype(x.dtype)[positions][None]
    ctx = MoEContext(is_training=False).replace(token_ids=ctx_ids[None],
                                                positions=pos2)
    x = shard(x, "batch", "seq", "embed")

    blocks = params["blocks"]
    quantized = policy is not None
    if isinstance(blocks, (list, tuple)):       # unrolled (mixed layer kinds)
        ks, vs, kss, vss, telems = [], [], [], [], []
        for i, bp in enumerate(blocks):
            x, kp, vp, ksc, vsc, tl = _paged_block(
                bp, x, cfg, moe_layer=_is_moe_layer(cfg, i), positions=pos2,
                lengths=lengths, row_tables=row_tables, wb=wb, wo=wo,
                kp=k_pools[i], vp=v_pools[i], ctx=ctx, mesh=mesh,
                ksc=k_scales[i] if quantized else None,
                vsc=v_scales[i] if quantized else None, policy=policy)
            ks.append(kp)
            vs.append(vp)
            kss.append(ksc)
            vss.append(vsc)
            telems.append(tl)
        k_pools, v_pools = jnp.stack(ks), jnp.stack(vs)
        if quantized:
            k_scales, v_scales = jnp.stack(kss), jnp.stack(vss)
        telem = {k: jnp.stack([t[k] for t in telems]) for k in telems[0]}
    elif quantized:
        moe_layer = _is_moe_layer(cfg, 0)

        def qbody(h, scanned):
            bp, kp, vp, ksc, vsc = scanned
            h, kp, vp, ksc, vsc, tl = _paged_block(
                bp, h, cfg, moe_layer=moe_layer, positions=pos2,
                lengths=lengths, row_tables=row_tables, wb=wb, wo=wo,
                kp=kp, vp=vp, ctx=ctx, mesh=mesh, ksc=ksc, vsc=vsc,
                policy=policy)
            return h, (kp, vp, ksc, vsc, tl)

        x, (k_pools, v_pools, k_scales, v_scales, telem) = jax.lax.scan(
            qbody, x, (blocks, k_pools, v_pools, k_scales, v_scales))
    else:
        moe_layer = _is_moe_layer(cfg, 0)

        def body(h, scanned):
            bp, kp, vp = scanned
            h, kp, vp, _, _, tl = _paged_block(
                bp, h, cfg, moe_layer=moe_layer, positions=pos2,
                lengths=lengths, row_tables=row_tables, wb=wb, wo=wo,
                kp=kp, vp=vp, ctx=ctx, mesh=mesh)
            return h, (kp, vp, tl)

        x, (k_pools, v_pools, telem) = jax.lax.scan(
            body, x, (blocks, k_pools, v_pools))
    if cfg.moe.num_experts == 0:
        telem = {}      # dense model: nothing to report, nothing to ship

    x = L.norm_apply(params["final_norm"], x, cfg)
    unembed = params.get("unembed", params["embed"])
    logits = L.unembed_apply(unembed, x, cfg)[0].astype(jnp.float32)  # (N, V)
    return logits, k_pools, v_pools, k_scales, v_scales, telem


def _row_buffers(N: int, blocks_per_slot: int, garbage_block: int):
    """Host-side flat-row operands for one step, every row masked: token 0,
    no identity, length 0, writes into the garbage block."""
    return dict(
        tokens=np.zeros(N, np.int32),
        ctx_ids=np.full(N, -1, np.int32),
        positions=np.zeros(N, np.int32),
        lengths=np.zeros(N, np.int32),
        slots=np.zeros(N, np.int32),
        wb=np.full(N, garbage_block, np.int32),
        wo=np.zeros(N, np.int32),
        row_tables=np.full((N, blocks_per_slot), garbage_block, np.int32),
    )


def _fill_row(b, cache, r: int, slot: int, token: int, pos: int) -> None:
    """One live row: ``token`` of ``slot`` at absolute position ``pos``
    (decode, prefill-chunk and verify rows all have this shape)."""
    b["tokens"][r] = b["ctx_ids"][r] = token
    b["positions"][r] = pos
    b["lengths"][r] = pos + 1
    b["slots"][r] = slot
    b["wb"][r], b["wo"][r] = cache.write_coords(slot, pos)
    b["row_tables"][r] = cache.row_table(slot)


def _sample_rows(logits, slots, positions, *, temperature: float, key):
    """Greedy argmax, or per-row categorical with a key folded from
    (engine key, slot, absolute position): independent across slots,
    reproducible under slot reuse."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def one(lg, s, p):
        k = jax.random.fold_in(jax.random.fold_in(key, s), p)
        return jax.random.categorical(k, lg / temperature)

    return jax.vmap(one)(logits, slots, positions).astype(jnp.int32)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class ContinuousEngine:
    """Continuous-batching engine over a fixed slot pool.

    ``temperature`` is engine-level (0 = greedy, matching the static
    engine's sampling math token for token).  ``serve.spec`` switches on
    speculative decoding; ``draft_model=(cfg, params)`` optionally hands
    the ``model`` drafter an explicit draft model.  Drive it either with
    :meth:`run` (trace of :class:`Request`, virtual clock, per-request
    latencies) or the batch-parity convenience :meth:`generate`.
    ``check_invariants=True`` re-asserts slot/block/reservation
    conservation after every step (tests, benchmarks, paranoid prod).
    """

    def __init__(self, cfg: ModelConfig, params, serve: ServeConfig = ServeConfig(),
                 *, temperature: float = 0.0, seed: int = 0,
                 rules: Optional[Rules] = None,
                 draft_model: Optional[Tuple] = None,
                 check_invariants: bool = False,
                 obs: Optional[Observability] = None,
                 logit_tap: Optional[Callable] = None):
        if cfg.family in _PAGED_FAMILIES:
            self.mode = "paged"
            if cfg.attn_logit_softcap > 0:
                raise NotImplementedError(
                    "paged decode attention does not implement logit softcap")
            if cfg.moe.moe_attention:
                raise NotImplementedError(
                    "moe_attention has no cached decode path")
        elif cfg.family in _RECURRENT_FAMILIES:
            self.mode = "recurrent"
        else:
            raise NotImplementedError(
                f"continuous batching not implemented for family "
                f"{cfg.family!r} (zamba's shared-attention cache keeps one "
                f"batch-wide length; encdec needs per-request encoder memory)")
        self.cfg = cfg
        self.fam = get_family(cfg)
        self.params = params
        self.serve = serve
        self.temperature = float(temperature)
        self.rules = rules
        self.seed = seed
        self._key = jax.random.PRNGKey(seed)   # fixed base key; per-row folds
        self.steps = 0
        self.check_invariants = check_invariants
        # debug spy on the per-step logits matrix (paged mode): called
        # via jax.debug.callback with (logits, slots, positions, lengths)
        # host arrays each engine step (length 0 marks a padding row) —
        # reads, never steers (the benchmark quant sweep uses it to
        # measure logit divergence across kv_quant policies)
        self._logit_tap = logit_tap
        self.obs = obs if obs is not None else Observability()
        self._moe_acc = None        # device-side telemetry accumulator
        self._moe_rows = 0          # host row count backing the entropy mean
        self._seen_variants = 0     # compiled-variant census (recompile det.)

        self.mesh = None
        self.data_shards = serve.data_shards
        if serve.mesh is not None:
            if self.mode != "paged":
                raise NotImplementedError(
                    "mesh serving needs the paged KV cache (recurrent slot "
                    "states have no block partition)")
            if serve.spec is not None:
                raise NotImplementedError(
                    "speculative decoding is not supported with "
                    "ServeConfig.mesh yet (the verify row layout is not "
                    "shard-major)")
            if serve.slo is not None:
                raise NotImplementedError(
                    "SLO scheduling is not supported with ServeConfig.mesh "
                    "yet (KV swap-to-host assumes a single device pool)")
            from repro.launch.mesh import make_serve_mesh

            self.mesh = make_serve_mesh(serve.mesh)
            if rules is None:
                from repro.distributed.sharding import make_rules

                # data axis carries slots/groups, expert axis the FFN
                # experts — exactly what the ragged EP dispatch wants
                rules = make_rules(cfg, self.mesh, expert_axis="expert")
                self.rules = rules

        self.spec = serve.spec
        self.drafter = None
        if self.spec is not None:
            if self.mode != "paged":
                raise NotImplementedError(
                    "speculative decoding needs the paged KV cache "
                    "(recurrent slot states have no cheap rollback)")
            from repro.serving.speculative import make_drafter

            self.drafter = make_drafter(self.spec, cfg, serve, seed=seed,
                                        draft_model=draft_model)

        if serve.prefix_cache and self.mode != "paged":
            raise NotImplementedError(
                "prefix caching needs the paged KV cache (recurrent slot "
                "states are not content-addressable blocks)")
        if serve.kv_quant != "none" and self.mode != "paged":
            raise NotImplementedError(
                "KV quantization needs the paged KV cache (recurrent slot "
                "states are not block pools)")
        if (serve.slo is not None and serve.slo.preemption
                and self.mode != "paged"):
            raise NotImplementedError(
                "preemption needs the paged KV cache (recurrent slot states "
                "have no block-level swap); use SLOConfig(preemption=False) "
                "for priority/deadline ordering alone")

        if self.mode == "paged":
            from repro.serving.kv_cache import make_kv_cache

            self.cache: Optional[PagedKVCache] = make_kv_cache(cfg, serve)
            self.scheduler = Scheduler(serve.max_slots, serve.max_len,
                                       self.cache, policy=serve.sched_policy,
                                       slo=serve.slo, obs=self.obs)
            temp = self.temperature
            mesh = self.mesh
            # The quantized policy rides in the step closures (jit
            # static); None keeps the full-precision path bit-identical
            # — the scale args are then None pytree leaves, which add
            # nothing to the traced computation.
            if serve.kv_quant != "none":
                from repro.quant import get_kv_quant

                kv_policy = get_kv_quant(serve.kv_quant)
            else:
                kv_policy = None
            self._kv_policy = kv_policy
            tap = logit_tap

            def step_fn(p, k_pools, v_pools, tokens, ctx_ids, positions,
                        lengths, row_tables, wb, wo, slots, key,
                        k_scales=None, v_scales=None):
                with use_rules(rules):
                    (logits, k_pools, v_pools, k_scales, v_scales,
                     telem) = _paged_logits(
                        p, cfg, tokens, ctx_ids, positions, lengths,
                        row_tables, wb, wo, k_pools, v_pools, mesh=mesh,
                        k_scales=k_scales, v_scales=v_scales,
                        policy=kv_policy)
                    if tap is not None:
                        jax.debug.callback(tap, logits, slots, positions,
                                           lengths)
                    tok = _sample_rows(logits, slots, positions,
                                       temperature=temp, key=key)
                return tok, k_pools, v_pools, k_scales, v_scales, telem

            # Static shapes only: N = max_slots (decode-only),
            # N = max_slots + data_shards * prefill_chunk (mixed), and —
            # speculative — N = max_slots * (gamma + 1) (verify); jit
            # caches each once.  The scale pools are donated alongside
            # the code pools when quantized (args 12, 13).
            donate = (1, 2, 12, 13) if kv_policy is not None else (1, 2)
            self._step_fn_raw = step_fn    # structural tests trace this
            self._step_fn = jax.jit(step_fn, donate_argnums=donate)

            def verify_fn(p, k_pools, v_pools, tokens, ctx_ids, positions,
                          lengths, row_tables, wb, wo,
                          k_scales=None, v_scales=None):
                with use_rules(rules):
                    (logits, k_pools, v_pools, k_scales, v_scales,
                     telem) = _paged_logits(
                        p, cfg, tokens, ctx_ids, positions, lengths,
                        row_tables, wb, wo, k_pools, v_pools,
                        k_scales=k_scales, v_scales=v_scales,
                        policy=kv_policy)
                # greedy acceptance only compares token ids: ship N int32
                # argmaxes, not the (N, V) logits matrix, to the host
                if temp <= 0.0:
                    return (jnp.argmax(logits, axis=-1).astype(jnp.int32),
                            k_pools, v_pools, k_scales, v_scales, telem)
                return logits, k_pools, v_pools, k_scales, v_scales, telem

            vdonate = (1, 2, 10, 11) if kv_policy is not None else (1, 2)
            self._verify_fn = jax.jit(verify_fn, donate_argnums=vdonate)
            # the documented compiled census: {mixed, decode-only} for
            # the step fn, plus the verify shape when speculating —
            # anything beyond this is a recompile worth flagging
            self._expected_variants = 3 if self.spec is not None else 2
        else:
            self.cache = None
            self.scheduler = Scheduler(serve.max_slots, serve.max_len, None,
                                       policy=serve.sched_policy,
                                       slo=serve.slo, obs=self.obs)
            self._expected_variants = 1         # one (max_slots, 1) shape
            self._state = self.fam.init_state(cfg, serve.max_slots, serve.max_len)
            temp = self.temperature
            serve_ctx = MoEContext(is_training=False)
            fam = self.fam
            S = serve.max_slots

            def rec_step(p, state, tokens, positions, key):
                with use_rules(rules):
                    logits, new_state = fam.decode(p, tokens, state, cfg,
                                                   ctx=serve_ctx)
                lg = logits[:, -1, :].astype(jnp.float32)
                tok = _sample_rows(lg, jnp.arange(S), positions,
                                   temperature=temp, key=key)
                return tok, new_state

            def reset_slot(state, slot):
                return jax.tree_util.tree_map(
                    lambda a: a.at[slot].set(jnp.zeros_like(a[slot])), state)

            self._step_fn = jax.jit(rec_step, donate_argnums=(1,))
            self._reset_fn = jax.jit(reset_slot, donate_argnums=(0,))

    # -- observability ------------------------------------------------------

    @property
    def spec_stats(self) -> Dict[str, int]:
        """Legacy dict view over the speculative-decoding counters."""
        m = self.obs.metrics
        return {"verify_steps": int(m.get("spec_verify_steps_total")),
                "proposed": int(m.get("spec_proposed_total")),
                "accepted": int(m.get("spec_accepted_total")),
                "emitted": int(m.get("spec_emitted_total"))}

    def compiled_variants(self) -> int:
        """Jit-cache entry count for the engine's step functions — the
        compiled-shape census the recompile detector watches."""
        n = 0
        for fn in (getattr(self, "_step_fn", None),
                   getattr(self, "_verify_fn", None)
                   if self.spec is not None else None):
            size = getattr(fn, "_cache_size", None)
            if size is not None:
                n += int(size())
        return n

    def _obs_step(self, kind: str, live_rows: int, total_rows: int) -> None:
        """Per-step registry publication: step/row counters, queue and
        pool gauges, prefix-cache counter mirror, recompile check."""
        m = self.obs.metrics
        sched = self.scheduler
        m.counter("engine_steps_total", kind=kind).inc()
        m.counter("engine_rows_total", state="live").inc(live_rows)
        m.counter("engine_rows_total",
                  state="padded").inc(total_rows - live_rows)
        m.gauge("queue_depth").set(len(sched.waiting))
        m.gauge("running_slots").set(len(sched.running))
        m.gauge("serve_peak_running").set_max(len(sched.running))
        if self.cache is not None:
            for d, occ in enumerate(self.cache.occupancy()):
                for state in ("free", "live", "cached"):
                    m.gauge("kv_blocks", state=state, shard=d).set(occ[state])
                m.gauge("kv_reserved_blocks", shard=d).set(occ["reserved"])
                # device footprint of the shard's whole pool (every
                # block row incl. the garbage block, at the per-block
                # byte cost — int8 + scales when quantized)
                rows = occ["free"] + occ["live"] + occ["cached"] + 1
                m.gauge("kv_pool_bytes", shard=d).set(
                    rows * occ["block_bytes"])
            if self.serve.prefix_cache:
                for k, v in self.cache.stats.items():
                    m.counter(f"prefix_{k}_total").set_to(v)
        n = self.compiled_variants()
        if n != self._seen_variants:
            m.gauge("engine_compiled_variants").set(n)
            if n > self._expected_variants:
                m.counter("engine_recompiles_total").inc(
                    n - max(self._seen_variants, self._expected_variants))
                self.obs.tracer.instant("recompile", variants=n,
                                        expected=self._expected_variants)
            self._seen_variants = n
        self.obs.maybe_metrics_row(self.steps)

    # -- MoE routing telemetry ----------------------------------------------
    # Device-side accumulation (four tiny adds per step, no sync); the
    # host pull happens once per run() — or at a metrics-JSONL flush —
    # via _moe_pull().

    def _moe_reset(self) -> None:
        self._moe_acc = None
        self._moe_rows = 0

    def _moe_accum(self, telem, rows: int) -> None:
        if not telem:
            return
        add = {"expert_tokens": telem["expert_tokens"],          # (L, E)
               "gate_entropy": telem["gate_entropy"] * float(rows),  # (L,)
               "dropped": telem["dropped"],                      # (L,)
               "routed_choices": telem["routed_choices"]}        # (L,)
        if self._moe_acc is None:
            self._moe_acc = add
        else:
            self._moe_acc = jax.tree_util.tree_map(
                jnp.add, self._moe_acc, add)
        self._moe_rows += rows

    def _moe_pull(self) -> Dict[str, float]:
        """Host pull of the accumulated routing telemetry: publish the
        per-layer gauges and return the run-level scalar stats."""
        if self._moe_acc is None:
            return {}
        from repro.core.metrics import load_entropy

        acc = jax.device_get(self._moe_acc)
        tok = np.asarray(acc["expert_tokens"], np.float64)      # (L, E)
        ent = np.asarray(acc["gate_entropy"], np.float64)       # (L,)
        drop = np.asarray(acc["dropped"], np.float64)           # (L,)
        choices = np.asarray(acc["routed_choices"], np.float64)  # (L,)
        rows = max(self._moe_rows, 1)
        m = self.obs.metrics
        for layer in range(tok.shape[0]):
            if choices[layer] <= 0:
                continue                    # dense layer (or never ran)
            tot = tok[layer].sum()
            for e in range(tok.shape[1]):
                m.gauge("moe_expert_load_share", layer=layer, expert=e).set(
                    tok[layer, e] / max(tot, 1.0))
            m.gauge("moe_load_entropy", layer=layer).set(
                load_entropy(tok[layer]))
            m.gauge("moe_gate_entropy", layer=layer).set(ent[layer] / rows)
            m.gauge("moe_dropped_fraction", layer=layer).set(
                drop[layer] / choices[layer])
        total_choices = choices.sum()
        moe_layers = choices > 0
        loads = tok[moe_layers].sum(axis=0)
        mean = loads.mean() if loads.size else 0.0
        stats = {
            # exact 0.0 on dropless paths: drop is a sum of exact zeros
            "moe_dropped_fraction": float(
                drop.sum() / max(total_choices, 1.0)),
            "moe_gate_entropy": float(
                ent[moe_layers].mean() / rows) if moe_layers.any() else 0.0,
            "moe_load_entropy": float(load_entropy(loads)),
            "moe_load_cv": float(loads.std() / (mean + 1e-9)),
        }
        m.gauge("moe_dropped_fraction_overall").set(
            stats["moe_dropped_fraction"])
        return stats

    # -- one engine step ----------------------------------------------------

    def step(self, clock_ms: float = 0.0) -> List[RequestState]:
        """Admit, run one mixed prefill/decode (or speculative verify)
        step, process samples.  Returns the requests that finished.
        With preemption enabled (``serve.slo``), the step first lets the
        scheduler evict lower-priority victims for urgent arrivals that
        could not otherwise be admitted — eviction and re-admission both
        happen here, at step granularity, never mid-forward."""
        self.scheduler.maybe_preempt(clock_ms)
        # deadline-aware shedding (slo.shed): provably-late requests are
        # finished with Status.SHED at the door, surfaced alongside the
        # step's completions so run()/callers see them resolve
        shed = self.scheduler.shed_unmeetable(clock_ms)
        admitted = self.scheduler.admit(clock_ms)
        if self.mode == "recurrent":
            for st in admitted:
                self._state = self._reset_fn(self._state, jnp.int32(st.slot))
        if not self.scheduler.running:
            return shed
        if self.mode == "paged":
            # speculate only in decode-only steps: mid-prefill, the mixed
            # step makes prompt progress and decode slots emit one token
            if self.spec is not None and self.scheduler.prefilling is None:
                finished = self._verify_host_step(clock_ms)
            else:
                finished = self._paged_host_step(clock_ms)
        else:
            finished = self._recurrent_host_step(clock_ms)
        self.steps += 1
        if self.check_invariants:
            self.scheduler.check_conservation()
        return shed + finished

    def _paged_host_step(self, clock_ms: float) -> List[RequestState]:
        serve, cache, sched = self.serve, self.cache, self.scheduler
        S = serve.max_slots
        pre = sched.prefilling
        chunk = 0
        stream = target = None
        if pre is not None:
            # the prefill stream is the *confirmed* token sequence, not
            # just the prompt: a restored preempted request re-ingests
            # (or re-bound) prompt + fed-back samples up to the exact
            # position it was evicted at — identical K/V, identical
            # routing, by construction
            stream = pre.confirmed_tokens
            target = pre.prefill_target
            chunk = min(serve.prefill_chunk, target - pre.prefill_pos)
        # Shard-major row layout over the mesh's data axis (D = 1 reduces
        # to the original [S decode rows] + [chunk rows]): shard d owns
        # rows [d * per, (d+1) * per) — its own slots' decode rows first,
        # then chunk rows, which live on (and are masked on all but) the
        # shard of the prefilling slot.  shard_map then splits the row
        # batch along the data axis with no data movement.
        D = self.data_shards
        spd = S // D
        per = spd + (serve.prefill_chunk if pre is not None else 0)
        N = D * per

        def row_of(slot: int) -> int:
            return (slot // spd) * per + slot % spd

        b = _row_buffers(N, serve.blocks_per_slot, cache.garbage_block)
        sample_rows: List[Tuple[int, RequestState]] = []

        for slot, st in sched.running.items():
            if st.status is not Status.DECODE:
                continue
            pos = st.context_len
            cache.ensure_capacity(slot, pos + 1)
            _fill_row(b, cache, row_of(slot), slot, st.last_token, pos)
            sample_rows.append((row_of(slot), st))

        if pre is not None:
            cache.ensure_capacity(pre.slot, pre.prefill_pos + chunk)
            base = (pre.slot // spd) * per + spd
            for j in range(chunk):
                row, p = base + j, pre.prefill_pos + j
                _fill_row(b, cache, row, pre.slot, stream[p], p)
                # sample off the last *prompt* row only on first ingest:
                # a resume past it already holds that sample in generated
                if p == pre.request.prompt_len - 1 and not pre.generated:
                    sample_rows.append((row, pre))

        kind = "mixed" if pre is not None else "decode"
        live = len(sample_rows) + (chunk if pre is not None else 0)
        if pre is not None and any(st is pre for _, st in sample_rows):
            live -= 1       # pre's sample row is one of its chunk rows
        tr = self.obs.tracer
        with tr.span("engine_step", kind=kind, step=self.steps,
                     rows=N, live_rows=live):
            if self.mesh is not None and tr.enabled:
                # per-shard child spans: each shard's slice of the row
                # batch (rows [d*per, (d+1)*per)), with its own live-row
                # census — the mesh analogue of the step-level args
                for d in range(D):
                    sl = int(np.count_nonzero(
                        b["lengths"][d * per:(d + 1) * per]))
                    with tr.span("engine_step_shard", kind=kind,
                                 step=self.steps, shard=d, rows=per,
                                 live_rows=sl):
                        pass
            (next_tok, k_pools, v_pools, k_scales, v_scales,
             telem) = self._step_fn(
                self.params, cache.k_pool, cache.v_pool, b["tokens"],
                b["ctx_ids"], b["positions"], b["lengths"], b["row_tables"],
                b["wb"], b["wo"], b["slots"], self._key,
                cache.k_scales, cache.v_scales)
            cache.update_pools(k_pools, v_pools, k_scales, v_scales)
        self._moe_accum(telem, N)

        if pre is not None:
            pre.prefill_pos += chunk
            if pre.prefill_pos == target:
                pre.status = Status.DECODE
                self.obs.request_phase(pre.request.uid, "decode",
                                       slot=pre.slot)
        finished = self._collect_samples(np.asarray(next_tok), sample_rows,
                                         clock_ms)
        self._commit_running()
        self._obs_step(kind, live, N)
        return finished

    def _commit_running(self) -> None:
        """Prefix caching: confirm every still-running slot's written
        token contents so newly full blocks publish into the index —
        live publication is what lets *concurrent* requests of one
        tenant share blocks, not just later arrivals.  (Slots that just
        finished were committed by ``Scheduler.finish`` before their
        blocks were released.)"""
        if not self.serve.prefix_cache:
            return
        bs, cache = self.cache.block_size, self.cache
        for slot, st in self.scheduler.running.items():
            stream = st.confirmed_tokens
            written = (st.prefill_pos if st.status is Status.PREFILL
                       else stream.size)
            if written // bs > cache.committed_blocks(slot):
                cache.commit(slot, stream[:written])

    # -- speculative verify step --------------------------------------------

    def _host_rng(self, slot: int, position: int) -> np.random.Generator:
        """Deterministic per-(slot, position) generator for host-side
        acceptance sampling — the numpy twin of the on-device per-row
        fold keys."""
        return np.random.default_rng(
            np.random.SeedSequence(entropy=[self.seed, slot, position]))

    def _verify_host_step(self, clock_ms: float) -> List[RequestState]:
        serve, cache, sched = self.serve, self.cache, self.scheduler
        S, gamma = serve.max_slots, self.spec.gamma
        W = gamma + 1
        N = S * W

        items: List[DraftItem] = []
        for slot, st in sorted(sched.running.items()):
            # remaining >= 1 in DECODE (a drained budget evicts); a draft
            # never needs to run past it, and clamping keeps every draft
            # KV write below total_len — inside the admission reservation
            remaining = st.request.max_new_tokens - len(st.generated)
            context = np.concatenate(
                [st.request.prompt,
                 np.asarray(st.generated, np.int32)]).astype(np.int32)
            items.append(DraftItem(slot=slot, context=context,
                                   max_tokens=min(gamma, remaining)))
        proposals = self.drafter.propose(items)
        drafts = [np.asarray(d, np.int32).reshape(-1)[:it.max_tokens]
                  for it, d in zip(items, proposals)]
        if all(d.size == 0 for d in drafts):
            # nothing to verify anywhere: an ordinary decode step costs
            # 1/(gamma+1) the rows for the same one token per slot (the
            # decode-only shape is already in the compiled census)
            return self._paged_host_step(clock_ms)

        b = _row_buffers(N, serve.blocks_per_slot, cache.garbage_block)
        per_slot: Dict[int, Tuple[RequestState, np.ndarray, int]] = {}
        for it, d in zip(items, drafts):
            slot = it.slot
            st = sched.running[slot]
            g = int(d.size)
            c = st.context_len
            cache.ensure_capacity(slot, c + g + 1)
            row_toks = [st.last_token, *d.tolist()]
            for j in range(g + 1):
                _fill_row(b, cache, slot * W + j, slot, row_toks[j], c + j)
            per_slot[slot] = (st, d, c)

        live = sum(int(d.size) + 1 for _, d, _ in per_slot.values())
        with self.obs.tracer.span("engine_step", kind="verify",
                                  step=self.steps, rows=N, live_rows=live):
            (scores, k_pools, v_pools, k_scales, v_scales,
             telem) = self._verify_fn(
                self.params, cache.k_pool, cache.v_pool, b["tokens"],
                b["ctx_ids"], b["positions"], b["lengths"], b["row_tables"],
                b["wb"], b["wo"], cache.k_scales, cache.v_scales)
            cache.update_pools(k_pools, v_pools, k_scales, v_scales)
        self._moe_accum(telem, N)
        scores = np.asarray(scores)     # (N,) argmax ids | (N, V) logits

        finished = []
        for slot, (st, d, c) in per_slot.items():
            g = int(d.size)
            rows = scores[slot * W: slot * W + g + 1]
            if self.temperature <= 0.0:
                emitted, n_acc = accept_greedy_ids(d, rows)
            else:
                emitted, n_acc = accept_rejection(
                    d, rows, self.temperature,
                    lambda j, slot=slot, c=c: self._host_rng(slot, c + j))
            remaining = st.request.max_new_tokens - len(st.generated)
            emitted = emitted[:remaining]
            eos = st.request.eos_id
            if eos is not None and eos in emitted:
                emitted = emitted[:emitted.index(eos) + 1]
            assert emitted, "verify step must emit at least the bonus token"
            m = self.obs.metrics
            m.counter("spec_proposed_total").inc(g)
            # accepted = draft tokens actually *used*: the EOS/budget cut
            # can discard accepted drafts, which must not inflate the rate
            m.counter("spec_accepted_total").inc(min(len(emitted), n_acc))
            st.generated.extend(int(t) for t in emitted)
            if st.first_token_ms is None:
                st.first_token_ms = clock_ms
            m.counter("spec_emitted_total").inc(len(emitted))
            if st.done():
                self.scheduler.finish(st, clock_ms)
                finished.append(st)
            else:
                # rollback: positions [0, c + len(emitted)) stay written
                # (row j wrote draft token j at position c + j, which for
                # every kept row IS the fed-back token); rejected rows
                # beyond rewind, their spill blocks return to the pool
                cache.truncate_slot(slot, c + len(emitted))
        self.obs.metrics.counter("spec_verify_steps_total").inc()
        self._commit_running()
        self._obs_step("verify", live, N)
        return finished

    def _recurrent_host_step(self, clock_ms: float) -> List[RequestState]:
        S = self.serve.max_slots
        tokens = np.zeros((S, 1), np.int32)
        positions = np.zeros(S, np.int32)
        sample_rows: List[Tuple[int, RequestState]] = []
        prefill_advanced: List[RequestState] = []
        for slot, st in self.scheduler.running.items():
            positions[slot] = st.context_len
            if st.status is Status.PREFILL:
                tokens[slot, 0] = st.request.prompt[st.prefill_pos]
                positions[slot] = st.prefill_pos
                prefill_advanced.append(st)
                if st.prefill_pos + 1 == st.request.prompt_len:
                    sample_rows.append((slot, st))
            else:
                tokens[slot, 0] = st.last_token
                sample_rows.append((slot, st))

        live = len(self.scheduler.running)
        with self.obs.tracer.span("engine_step", kind="decode",
                                  step=self.steps, rows=S, live_rows=live):
            next_tok, self._state = self._step_fn(self.params, self._state,
                                                  tokens, positions, self._key)
        for st in prefill_advanced:
            st.prefill_pos += 1
            if st.prefill_pos == st.request.prompt_len:
                st.status = Status.DECODE
                self.obs.request_phase(st.request.uid, "decode", slot=st.slot)
        self._obs_step("decode", live, S)
        return self._collect_samples(np.asarray(next_tok), sample_rows, clock_ms)

    def _collect_samples(self, next_tok: np.ndarray, sample_rows, clock_ms: float
                         ) -> List[RequestState]:
        finished = []
        for row, st in sample_rows:
            st.generated.append(int(next_tok[row]))
            if st.first_token_ms is None:
                st.first_token_ms = clock_ms
            if st.done():
                self.scheduler.finish(st, clock_ms)
                finished.append(st)
        return finished

    # -- drivers ------------------------------------------------------------

    def run(self, requests: List[Request], *,
            on_finish: Optional[Callable[[RequestState], None]] = None
            ) -> Tuple[Dict[int, List[int]], Dict[str, float]]:
        """Serve a trace to completion.  The clock is wall time since the
        call, fast-forwarded over idle gaps to the next arrival (so a
        sparse trace doesn't busy-wait); request latency = finish - arrival
        on that clock.  Returns ({uid: generated tokens}, stats) —
        every counter-derived stat is a registry delta over this run
        (``repro.obs``), not a hand-kept snapshot."""
        m = self.obs.metrics
        for r in requests:
            self.scheduler.add(r)
        t0 = time.perf_counter()
        mark = m.mark()
        m.gauge("serve_peak_running").set(0.0)
        self._moe_reset()
        sched = self.scheduler
        clock = 0.0
        done: List[RequestState] = []
        while self.scheduler.has_work():
            clock = max(clock, (time.perf_counter() - t0) * 1e3)
            if not self.scheduler.running:
                nxt = self.scheduler.next_arrival_ms()
                if nxt is not None and nxt > clock:
                    clock = nxt                      # idle: jump to next arrival
            finished = self.step(clock)
            # finished requests were still running when the step began;
            # shed requests never ran, so they don't count toward peak
            ran = [st for st in finished if st.status is not Status.SHED]
            m.gauge("serve_peak_running").set_max(
                len(self.scheduler.running) + len(ran))
            for st in finished:
                done.append(st)
                if on_finish is not None:
                    on_finish(st)
        total_ms = max(clock, (time.perf_counter() - t0) * 1e3)
        self.scheduler.check_conservation()

        from repro.serving.trace import latency_stats, slo_class_stats

        # shed requests resolved without serving a token: excluding them
        # from latency/goodput stats keeps "met deadline" meaning "was
        # served by its deadline" (a shed finish beats its deadline on
        # the clock but delivered nothing)
        served = [st for st in done if st.status is not Status.SHED]
        stats = latency_stats([st.latency_ms() for st in served], total_ms,
                              sum(len(st.generated) for st in served))
        stats["steps"] = m.delta(mark, "engine_steps_total")
        stats["peak_running"] = m.get("serve_peak_running")
        # per-class percentiles + goodput: global p50/p95 hide exactly
        # the targeted degradation SLO scheduling is for
        stats.update(slo_class_stats(served))
        if self.serve.slo is not None and self.serve.slo.shed:
            stats["requests_shed"] = m.delta(mark, "requests_shed_total")
        if sched.swap is not None:
            stats["preemptions"] = m.delta(mark, "sched_preemptions_total")
            stats["restore_tokens"] = m.delta(mark,
                                              "sched_restore_tokens_total")
            stats["recompute_tokens"] = m.delta(
                mark, "sched_recompute_tokens_total")
            stats["swapped_blocks"] = m.delta(mark,
                                              "swap_swapped_blocks_total")
            stats["restored_blocks"] = m.delta(mark,
                                               "swap_restored_blocks_total")
        if self.serve.prefix_cache:
            cached = m.delta(mark, "prefix_cached_tokens_total")
            prompt = m.delta(mark, "prefix_prompt_tokens_total")
            stats["cached_tokens"] = cached
            stats["prompt_tokens"] = prompt
            stats["cached_token_ratio"] = cached / max(prompt, 1)
        if self.spec is not None:
            proposed = m.delta(mark, "spec_proposed_total")
            vsteps = m.delta(mark, "spec_verify_steps_total")
            stats["acceptance_rate"] = (
                m.delta(mark, "spec_accepted_total") / max(proposed, 1))
            stats["spec_tokens_per_step"] = (
                m.delta(mark, "spec_emitted_total") / max(vsteps, 1))
        stats.update(self._moe_pull())
        return {st.request.uid: list(st.generated) for st in done}, stats

    def generate(self, prompts: jax.Array, num_tokens: int, seed: int = 0):
        """Static-engine-compatible entry: (B, S) prompts, all admitted at
        t=0, each generating ``num_tokens``.  Returns ((B, num_tokens)
        int32, stats) — token-identical to ``ServingEngine.generate``
        under greedy decoding."""
        del seed  # sampling keys are engine-level (slot/position folds)
        prompts = np.asarray(prompts)
        reqs = [Request(uid=i, prompt=prompts[i], max_new_tokens=num_tokens)
                for i in range(prompts.shape[0])]
        out, stats = self.run(reqs)
        toks = jnp.asarray(np.stack([out[i] for i in range(prompts.shape[0])]),
                           jnp.int32)
        return toks, stats
