"""Deterministic, seekable synthetic data pipelines.

Every pipeline is a pure function of (seed, step): ``batch_at(step)``
always returns the same batch — so checkpoint/restart resumes the data
stream *exactly* (fault tolerance requires no data-state checkpointing),
and elastic re-sharding just re-slices the same global batch.

The LM task is a *clustered-bigram* language: tokens belong to one of
``n_clusters`` latent clusters; within a cluster the next token follows a
cluster-specific affine map (plus noise).  A mixture model with experts
that specialise per cluster fits it better than a single dense FFN of the
same active size — which is exactly the structure the paper's k>1 routing
claims to exploit (Fig. 3), so quality gaps between top-1 / top-k /
k top-1 are observable at toy scale.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    n_clusters: int = 8
    noise: float = 0.05

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        v = self.vocab_size
        # per-cluster affine next-token maps (co-prime multipliers)
        self.mult = rng.choice([m for m in range(2, v) if np.gcd(m, v) == 1],
                               size=self.n_clusters)
        self.bias = rng.randint(0, v, size=self.n_clusters)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState((self.seed * 1_000_003 + step) % (2**31 - 1))
        B, S, v = self.batch, self.seq_len, self.vocab_size
        cluster = rng.randint(0, self.n_clusters, size=(B,))
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.randint(0, v, size=(B,))
        for t in range(S):
            nxt = (toks[:, t] * self.mult[cluster] + self.bias[cluster]) % v
            noise = rng.rand(B) < self.noise
            nxt = np.where(noise, rng.randint(0, v, size=(B,)), nxt)
            toks[:, t + 1] = nxt
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


@dataclasses.dataclass
class SyntheticSeq2Seq:
    """For the enc-dec family: frames are random frontend embeddings whose
    mean encodes an affine map the decoder must apply (learnable task)."""

    vocab_size: int
    d_model: int
    batch: int
    src_len: int
    tgt_len: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState((self.seed * 999_983 + step) % (2**31 - 1))
        B = self.batch
        frames = rng.randn(B, self.src_len, self.d_model).astype(np.float32) * 0.1
        toks = rng.randint(0, self.vocab_size, size=(B, self.tgt_len + 1)).astype(np.int32)
        return {"frames": frames, "tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


@dataclasses.dataclass
class SyntheticMultimodal:
    """For vlm / m6: clustered-bigram text + patch embeddings that encode
    the cluster id (so attending to the image prefix helps)."""

    vocab_size: int
    d_model: int
    num_image_tokens: int
    batch: int
    seq_len: int
    seed: int = 0
    n_clusters: int = 8

    def __post_init__(self):
        self._lm = SyntheticLM(self.vocab_size, self.batch, self.seq_len,
                               self.seed, self.n_clusters)
        rng = np.random.RandomState(self.seed + 17)
        self.cluster_embeds = rng.randn(self.n_clusters, self.d_model).astype(np.float32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState((self.seed * 7_368_787 + step) % (2**31 - 1))
        lm = self._lm.batch_at(step)
        B = self.batch
        cluster = rng.randint(0, self.n_clusters, size=(B,))
        patches = (self.cluster_embeds[cluster][:, None, :]
                   + 0.05 * rng.randn(B, self.num_image_tokens, self.d_model)).astype(np.float32)
        return {**lm, "patch_embeds": patches}


def make_pipeline(cfg, batch: int, seq_len: int, seed: int = 0):
    """Pick a pipeline matching the model family."""
    if cfg.family == "encdec":
        return SyntheticSeq2Seq(cfg.vocab_size, cfg.d_model, batch,
                                src_len=seq_len, tgt_len=seq_len, seed=seed)
    if cfg.num_image_tokens:
        return SyntheticMultimodal(cfg.vocab_size, cfg.d_model,
                                   cfg.num_image_tokens, batch,
                                   seq_len - cfg.num_image_tokens, seed=seed)
    return SyntheticLM(cfg.vocab_size, batch, seq_len, seed=seed)


class Prefetcher:
    """Background-thread prefetch of the next N batches (straggler hiding
    on the input side).  Seekable: reset(step) jumps anywhere."""

    def __init__(self, pipeline, start_step: int = 0, depth: int = 2):
        import queue
        import threading

        self._pipeline = pipeline
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        while not self._stop.is_set():
            batch = self._pipeline.batch_at(self._step)
            self._step += 1
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except Exception:
                    continue

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()
