"""Per-layer MoE load-balance metrics (paper 3.1, Fig. 1) and their
aggregation across layers."""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp


def dropped_fraction(expert_loads: jax.Array, total_slots: int) -> jax.Array:
    """Fraction of routed choices that capacity dropped.

    ``expert_loads`` counts the choices that *survived* capacity (summed
    over experts); ``total_slots`` is the number of choices the router
    made.  Computed as dropped/total rather than ``1 - kept/total`` so a
    zero-drop plan reports *exactly* 0.0 (XLA lowers division by a
    constant to a reciprocal multiply, which would turn ``1 - 1.0`` into
    ~1e-8 noise — the dropless backend asserts on exact zero).
    """
    kept = jnp.sum(expert_loads)
    return jnp.maximum(float(total_slots) - kept, 0.0) / float(total_slots)


def gate_entropy(gate: jax.Array, valid: jax.Array) -> jax.Array:
    """Mean per-token entropy (nats) of the *kept* gate distribution.

    ``gate``/``valid`` are the plan's ``(G, T, K)`` index-view arrays;
    each token's surviving gates are renormalised over its kept choices
    before the entropy, so a token routed to one expert contributes
    exactly 0 and a token split evenly over k experts contributes
    ``log(k)``.  Tokens with every choice dropped contribute 0.
    """
    g = jnp.where(valid, gate, 0.0)
    tot = jnp.sum(g, axis=-1, keepdims=True)
    p = g / jnp.maximum(tot, 1e-9)
    ent = -jnp.sum(jnp.where(p > 0, p * jnp.log(jnp.maximum(p, 1e-9)), 0.0),
                   axis=-1)
    return jnp.mean(ent)


def load_entropy(expert_loads) -> float:
    """Entropy (nats) of the normalised expert-load distribution — the
    host-side summary the serving telemetry publishes per layer.  A
    perfectly balanced layer reports ``log(E)``; a collapsed router 0."""
    import numpy as np

    loads = np.asarray(expert_loads, np.float64)
    tot = loads.sum()
    if tot <= 0:
        return 0.0
    p = loads / tot
    p = p[p > 0]
    return float(-(p * np.log(p)).sum())


def merge_aux(aux_list: List[Dict]) -> Dict:
    """Combine per-layer aux dicts: losses summed, metrics stacked."""
    if not aux_list:
        return {"moe_aux_loss": jnp.zeros((), jnp.float32),
                "moe_z_loss": jnp.zeros((), jnp.float32)}
    out: Dict = {}
    keys = aux_list[0].keys()
    for k in keys:
        vals = [a[k] for a in aux_list]
        if k.endswith("_loss"):
            out[k] = sum(vals)
        else:
            out[k] = jnp.stack(vals)  # per-layer trace (e.g. cv per layer)
    return out


def empty_aux(num_experts: int = 0) -> Dict:
    """The aux dict a dense layer contributes.  ``num_experts`` sizes the
    telemetry keys so per-layer stacking stays shape-uniform when dense
    layers interleave with MoE layers (``moe_layer_period > 1``)."""
    return {
        "moe_aux_loss": jnp.zeros((), jnp.float32),
        "moe_z_loss": jnp.zeros((), jnp.float32),
        "moe_cv": jnp.zeros((), jnp.float32),
        "moe_dropped_fraction": jnp.zeros((), jnp.float32),
        "moe_expert_tokens": jnp.zeros((num_experts,), jnp.float32),
        "moe_gate_entropy": jnp.zeros((), jnp.float32),
        "moe_routed_choices": jnp.zeros((), jnp.float32),
    }
