"""Per-layer MoE load-balance metrics (paper 3.1, Fig. 1) and their
aggregation across layers."""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp


def dropped_fraction(expert_loads: jax.Array, total_slots: int) -> jax.Array:
    """Fraction of routed choices that capacity dropped.

    ``expert_loads`` counts the choices that *survived* capacity (summed
    over experts); ``total_slots`` is the number of choices the router
    made.  Computed as dropped/total rather than ``1 - kept/total`` so a
    zero-drop plan reports *exactly* 0.0 (XLA lowers division by a
    constant to a reciprocal multiply, which would turn ``1 - 1.0`` into
    ~1e-8 noise — the dropless backend asserts on exact zero).
    """
    kept = jnp.sum(expert_loads)
    return jnp.maximum(float(total_slots) - kept, 0.0) / float(total_slots)


def merge_aux(aux_list: List[Dict]) -> Dict:
    """Combine per-layer aux dicts: losses summed, metrics stacked."""
    if not aux_list:
        return {"moe_aux_loss": jnp.zeros((), jnp.float32),
                "moe_z_loss": jnp.zeros((), jnp.float32)}
    out: Dict = {}
    keys = aux_list[0].keys()
    for k in keys:
        vals = [a[k] for a in aux_list]
        if k.endswith("_loss"):
            out[k] = sum(vals)
        else:
            out[k] = jnp.stack(vals)  # per-layer trace (e.g. cv per layer)
    return out


def empty_aux() -> Dict:
    return {
        "moe_aux_loss": jnp.zeros((), jnp.float32),
        "moe_z_loss": jnp.zeros((), jnp.float32),
        "moe_cv": jnp.zeros((), jnp.float32),
        "moe_dropped_fraction": jnp.zeros((), jnp.float32),
    }
