"""Aggregation of per-layer MoE load-balance metrics (paper 3.1, Fig. 1)."""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp


def merge_aux(aux_list: List[Dict]) -> Dict:
    """Combine per-layer aux dicts: losses summed, metrics stacked."""
    if not aux_list:
        return {"moe_aux_loss": jnp.zeros((), jnp.float32),
                "moe_z_loss": jnp.zeros((), jnp.float32)}
    out: Dict = {}
    keys = aux_list[0].keys()
    for k in keys:
        vals = [a[k] for a in aux_list]
        if k.endswith("_loss"):
            out[k] = sum(vals)
        else:
            out[k] = jnp.stack(vals)  # per-layer trace (e.g. cv per layer)
    return out


def empty_aux() -> Dict:
    return {
        "moe_aux_loss": jnp.zeros((), jnp.float32),
        "moe_z_loss": jnp.zeros((), jnp.float32),
        "moe_cv": jnp.zeros((), jnp.float32),
        "moe_dropped_fraction": jnp.zeros((), jnp.float32),
    }
