"""Expert-choice routing (Zhou et al., 2022 — MoEC/EC family).

Roles are flipped relative to token-choice: each *expert* selects its
top-C tokens by router score, so every expert buffer is exactly full and
load balance holds by construction (no auxiliary loss needed).  A token
may be picked by 0..E experts, so the index view uses K = E choice
columns: column e describes "did expert e pick this token, and at which
slot".

Scores are the per-token softmax over experts (so gate magnitudes are
comparable with the ``topk`` router); selection is a single
``jax.lax.top_k`` over the token axis per expert — no sequential loop.

Caveat (Zhou et al. 4.1): selecting over the token axis makes token t's
routing depend on *other tokens in its group, including future ones* —
fine for encoders/non-autoregressive training, but for causal LMs the
train-time routing is not reproducible at autoregressive decode time.
CE numbers from causal-LM ablations (e.g. examples/prototyping_ablation)
are therefore not directly comparable with token-choice routers.

Second caveat: capacity is this router's *routing rule*, not an
execution buffer, so ``capacity_factor=None`` (dropless) resolves to
c_eff = T — every expert picks every token, the dense all-experts limit
at ~E/k x the FLOPs.  Legal (it is the consistent capacity-infinity
limit) but rarely what you want; keep a finite capacity_factor for EC.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core.routers import base, register_router
from repro.core.routers.base import RoutingPlan
from repro.nn import ParamSpec


def expert_choice_plan(logits: jax.Array, cfg: MoEConfig, capacity: int,
                       combine_dtype=jnp.float32) -> RoutingPlan:
    """Expert-choice gating from precomputed (G,T,E) logits."""
    G, T, E = logits.shape
    c_eff = min(capacity, T)  # an expert cannot pick more tokens than exist
    scores = jax.nn.softmax(logits, axis=-1)                 # (G,T,E)

    # Each expert picks its top-c_eff tokens: (G,E,c_eff) token indices.
    _, sel_tok = jax.lax.top_k(jnp.swapaxes(scores, 1, 2), c_eff)

    # Invert the selection into a per-(token, expert) slot map.
    g = jnp.arange(G)[:, None, None]
    e = jnp.arange(E)[None, :, None]
    c = jnp.arange(c_eff, dtype=jnp.int32)[None, None, :]
    slot_of = jnp.full((G, T, E), -1, jnp.int32)
    slot_of = slot_of.at[g, sel_tok, e].set(jnp.broadcast_to(c, (G, E, c_eff)))

    valid = slot_of >= 0
    expert_index = jnp.broadcast_to(jnp.arange(E, dtype=jnp.int32), (G, T, E))
    slot_index = jnp.where(valid, slot_of, capacity)
    gate = scores
    if cfg.normalize_gates:
        gate = base.normalize_gates(gate, valid)

    # Slot-major view: the top_k selection IS (token, gate) per (e, c) —
    # O(E*C) dispatch metadata (all slots full by construction), sparing
    # the gather path the mostly-invalid (G, T, E) token-choice columns.
    gate_m = jnp.where(valid, gate, 0.0)
    gate_at_slot = jnp.take_along_axis(jnp.swapaxes(gate_m, 1, 2), sel_tok, axis=2)

    zl = base.z_loss(logits, cfg.router_z_loss_coef)
    # Balance is structural: every expert holds exactly c_eff tokens, so
    # loads and cv are compile-time constants — no scatter needed.
    # "dropped" reports the genuinely interesting failure mode: tokens
    # no expert picked.
    routed = jnp.sum(jnp.any(valid, axis=-1).astype(jnp.float32))
    unrouted = base.dropped_fraction(routed, G * T)
    metrics = {
        "cv": jnp.zeros((), jnp.float32),
        "dropped_fraction": unrouted,
        "expert_loads": jnp.full((E,), float(G * c_eff), jnp.float32),
        "routed_choices": jnp.asarray(float(G * T), jnp.float32),
    }
    return RoutingPlan(expert_index, slot_index, gate, valid, E, capacity,
                       jnp.zeros((), jnp.float32), zl, metrics, combine_dtype,
                       token_at_slot=sel_tok.astype(jnp.int32),
                       gate_at_slot=gate_at_slot)


@register_router
class ExpertChoiceRouter:
    name = "expert_choice"

    def param_spec(self, m: MoEConfig, d_model: int, init):
        return ParamSpec((d_model, m.num_experts), jnp.float32,
                         ("embed", "expert"), init)

    def plan(self, x32, w, m: MoEConfig, capacity: int,
             combine_dtype=jnp.float32, ctx=None) -> RoutingPlan:
        logits = jnp.einsum("gtm,me->gte", x32, w.astype(jnp.float32))
        return expert_choice_plan(logits, m, capacity, combine_dtype)
