"""Pluggable token->expert routers.

``MoEConfig.routing`` is a key into this registry.  Built-in strategies:

* ``topk``          — GShard/Switch sequential top-k (paper 3.2/3.3, the
  looping argmax of Table 2);
* ``prototype``     — M6-T k top-1 expert prototyping (Eq. 3 / Fig. 8);
* ``expert_choice`` — expert-choice routing (Zhou et al., 2022): experts
  pick their top-C tokens, perfect load balance by construction;
* ``hash``          — stateless hash routing (Roller et al., 2021):
  deterministic position-hash assignment, no learned router.

Adding a strategy is ~50 lines::

    from repro.core.routers import register_router
    from repro.core.routers.base import Router, RoutingPlan

    @register_router
    class MyRouter:
        name = "mine"
        def param_spec(self, m, d_model, init): ...
        def plan(self, x32, w, m, capacity, combine_dtype=...): ...

Registration must happen before a ``MoEConfig(routing="mine")`` is
constructed (config validation consults this registry).
"""
from __future__ import annotations

from typing import Dict, Tuple, Type

from repro.core.routers.base import Router, RoutingPlan  # noqa: F401

_REGISTRY: Dict[str, Router] = {}


def register_router(cls: Type) -> Type:
    """Class decorator: instantiate and register a Router under cls.name."""
    name = getattr(cls, "name", None)
    if not name or not isinstance(name, str):
        raise ValueError(f"router class {cls!r} needs a string `name` attribute")
    _REGISTRY[name] = cls()
    return cls


def get_router(name: str) -> Router:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown routing mode {name!r}; registered routers: "
            f"{', '.join(available_routers())}"
        ) from None


def available_routers() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# Built-ins self-register on import.
from repro.core.routers import expert_choice, hashed, prototype, topk  # noqa: E402,F401

__all__ = [
    "Router", "RoutingPlan", "register_router", "get_router",
    "available_routers",
]
