"""M6-T k top-1 expert prototyping (Eq. 3 / Fig. 8).

Experts are split into Z prototypes of F = E/Z experts; each prototype
routes independently with top-1 (generalised to top-k' > 1); outputs are
summed.  No argmax loop across prototypes — everything is parallel over
Z, so with k' = 1 the hot path runs exactly one argmax regardless of Z
(the paper's Table 2 speed claim).

Global expert ids follow the Fig. 8 reshape: expert = z * F + f, so the
index view is directly comparable with the ``topk`` router's.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core.routers import base, register_router
from repro.core.routers.base import RoutingPlan
from repro.nn import ParamSpec


def prototype_logits(x32: jax.Array, w: jax.Array) -> jax.Array:
    """(G,T,M) x (M,Z,F) -> (G,Z,T,F)  (Fig. 8: 'dTZM,MZF->dZTF')."""
    return jnp.einsum("gtm,mzf->gztf", x32, w.astype(jnp.float32))


def prototype_plan(logits: jax.Array, cfg: MoEConfig, capacity: int,
                   combine_dtype=jnp.float32) -> RoutingPlan:
    """k top-1 gating from precomputed per-prototype logits."""
    G, Z, T, F = logits.shape
    kp = cfg.prototype_top_k
    raw_gates = jax.nn.softmax(logits, axis=-1)              # (G,Z,T,F)

    remaining = raw_gates
    count = jnp.zeros((G, Z, F), jnp.float32)
    experts, slots, gates = [], [], []
    first_mask = None
    for _ in range(kp):  # paper: kp == 1, no loop in the hot path
        idx = jnp.argmax(remaining, axis=-1)                 # (G,Z,T)
        mask = base.one_hot_f32(idx, F)                      # (G,Z,T,F)
        if first_mask is None:
            first_mask = mask
        gate = jnp.sum(raw_gates * mask, axis=-1)            # (G,Z,T)
        pos, count = base.slot_positions(mask, count, token_axis=2)
        # Fig. 8 reshape: global expert id = z * F + f.
        experts.append(idx.astype(jnp.int32)
                       + (jnp.arange(Z, dtype=jnp.int32) * F)[None, :, None])
        slots.append(pos.astype(jnp.int32))
        gates.append(gate)
        remaining = remaining * (1.0 - mask)

    # (kp lists of (G,Z,T)) -> (G,T,Z,kp) -> (G,T,Z*kp): choices are
    # ordered prototype-major so prototype z's picks sit at [z*kp:(z+1)*kp].
    def _stack(xs):
        return jnp.stack(xs, axis=-1).transpose(0, 2, 1, 3).reshape(G, T, Z * kp)

    expert_index = _stack(experts)
    slot_index = _stack(slots)
    gate = _stack(gates)
    valid = slot_index < capacity

    if cfg.normalize_gates:
        gate = base.normalize_gates(gate, valid)

    # aux loss per prototype over its F experts (Fig. 8: F^2 scaling).
    density = jnp.mean(first_mask, axis=2)                   # (G,Z,F)
    density_proxy = jnp.mean(raw_gates, axis=2)              # (G,Z,F)
    aux = base.aux_loss(density, density_proxy, F, cfg.aux_loss_coef)
    zl = base.z_loss(logits, cfg.router_z_loss_coef)
    metrics = base.index_load_metrics(expert_index, valid, Z * F, G * T * Z * kp)
    return RoutingPlan(expert_index, slot_index, gate, valid, Z * F, capacity,
                       aux, zl, metrics, combine_dtype)


@register_router
class PrototypeRouter:
    name = "prototype"

    def param_spec(self, m: MoEConfig, d_model: int, init):
        return ParamSpec((d_model, m.num_prototypes, m.experts_per_prototype),
                         jnp.float32, ("embed", None, "expert"), init)

    def plan(self, x32, w, m: MoEConfig, capacity: int,
             combine_dtype=jnp.float32, ctx=None) -> RoutingPlan:
        return prototype_plan(prototype_logits(x32, w), m, capacity, combine_dtype)
