"""Stateless hash routing — a *position-hash* variant of the idea in
Roller et al., 2021 ("Hash Layers").

No learned router at all: each token is assigned to experts by a fixed
integer hash, with uniform combine weight 1/k.  Note the deliberate
departure from the citation: Roller et al. hash the *token id* so that
experts specialise per token type; the MoE layer here only sees hidden
states, so we hash the token's global *position* instead — a fully
content-independent assignment (a fixed pseudo-random permutation over
positions).  That makes this the floor baseline for "how much does
learned/content routing matter", strictly weaker than true Hash Layers;
token-id hashing needs ids threaded to the layer (see ROADMAP).  It also
exercises the parameter-free corner of the Router API (``param_spec``
returns None).

Choice i targets expert ``(hash(pos) + i) % E`` so a token's k choices
are always distinct experts.  Capacity/slot semantics are identical to
token-choice routers (first-come within the group, overflow dropped).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core.routers import base, register_router
from repro.core.routers.base import RoutingPlan


def _mix32(x: jax.Array) -> jax.Array:
    """splitmix-style avalanche on uint32 (deterministic, well spread)."""
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def hash_plan(G: int, T: int, cfg: MoEConfig, capacity: int,
              combine_dtype=jnp.float32) -> RoutingPlan:
    E = cfg.num_experts
    k = max(1, min(cfg.top_k, E))
    pos = (jnp.arange(G, dtype=jnp.uint32)[:, None] * jnp.uint32(T)
           + jnp.arange(T, dtype=jnp.uint32)[None, :])       # (G,T) global position
    h = (_mix32(pos) % jnp.uint32(E)).astype(jnp.int32)      # (G,T)

    count = jnp.zeros((G, E), jnp.float32)
    experts, slots = [], []
    for i in range(k):
        idx = (h + i) % E                                    # distinct experts
        mask = base.one_hot_f32(idx, E)
        p, count = base.slot_positions(mask, count, token_axis=1)
        experts.append(idx)
        slots.append(p.astype(jnp.int32))

    expert_index = jnp.stack(experts, axis=-1)               # (G,T,k)
    slot_index = jnp.stack(slots, axis=-1)
    valid = slot_index < capacity
    gate = jnp.full((G, T, k), 1.0 / k, jnp.float32)         # uniform average

    zero = jnp.zeros((), jnp.float32)
    metrics = base.index_load_metrics(expert_index, valid, E, G * T * k)
    return RoutingPlan(expert_index, slot_index, gate, valid, E, capacity,
                       zero, zero, metrics, combine_dtype)


@register_router
class HashRouter:
    name = "hash"

    def param_spec(self, m: MoEConfig, d_model: int, init):
        return None  # stateless: no router weights

    def plan(self, x32, w, m: MoEConfig, capacity: int,
             combine_dtype=jnp.float32) -> RoutingPlan:
        G, T = x32.shape[0], x32.shape[1]
        return hash_plan(G, T, m, capacity, combine_dtype)
