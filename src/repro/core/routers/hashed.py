"""Stateless hash routing (Roller et al., 2021 — "Hash Layers").

No learned router at all: each token is assigned to experts by a fixed
integer hash, with uniform combine weight 1/k.  Two regimes:

* **Token-identity hashing** (the paper's actual scheme): when the
  :class:`~repro.core.context.MoEContext` provides ``token_ids``, the
  hash is over the token's *vocabulary id*, so every occurrence of a
  token routes to the same experts regardless of position — experts
  specialise per token type.  Rows whose identity is unknown
  (``token_ids < 0``, e.g. image-patch prefix embeddings) fall back
  per-row to the position hash.
* **Position hashing** (fallback): with no token ids — or under layers
  that route non-token activations, e.g. ``moe_attention`` — tokens
  hash by position, fully content-independent.  When the context
  provides *absolute* positions the fallback is layout-invariant
  (prefill and single-step decode hash a given sequence position
  identically); with no context at all it hashes the synthetic
  group-local position (a fixed pseudo-random permutation over the
  group layout).  This is the floor baseline for "how much does
  learned/content routing matter", strictly weaker than true Hash
  Layers.

Choice i targets expert ``(hash + i) % E`` so a token's k choices are
always distinct experts.  Capacity/slot semantics are identical to
token-choice routers (first-come within the group, overflow dropped).
This router also exercises the parameter-free corner of the Router API
(``param_spec`` returns None).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core.routers import base, register_router
from repro.core.routers.base import RoutingPlan


def _mix32(x: jax.Array) -> jax.Array:
    """splitmix-style avalanche on uint32 (deterministic, well spread)."""
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def hash_plan(G: int, T: int, cfg: MoEConfig, capacity: int,
              combine_dtype=jnp.float32,
              token_ids: Optional[jax.Array] = None,
              positions: Optional[jax.Array] = None) -> RoutingPlan:
    """Build the hash plan.

    ``token_ids``: optional (G, T) int32; rows with id -1 fall back to
    the position hash.  ``positions``: optional (G, T) int32 *absolute*
    sequence positions for that fallback — a token at sequence position
    p hashes the same whether it arrives in a prefill group or as a
    single decode step.  Without positions the fallback hashes the
    synthetic group-local position ``g*T + t`` (fixed pseudo-random
    permutation over the group layout)."""
    E = cfg.num_experts
    k = max(1, min(cfg.top_k, E))
    if positions is not None:
        pos = positions.astype(jnp.uint32)                   # (G,T) absolute
    else:
        pos = (jnp.arange(G, dtype=jnp.uint32)[:, None] * jnp.uint32(T)
               + jnp.arange(T, dtype=jnp.uint32)[None, :])   # (G,T) group-local
    h = (_mix32(pos) % jnp.uint32(E)).astype(jnp.int32)      # (G,T)
    if token_ids is not None:
        known = token_ids >= 0
        h_id = (_mix32(token_ids.astype(jnp.uint32)) % jnp.uint32(E)).astype(jnp.int32)
        h = jnp.where(known, h_id, h)

    count = jnp.zeros((G, E), jnp.float32)
    experts, slots = [], []
    for i in range(k):
        idx = (h + i) % E                                    # distinct experts
        mask = base.one_hot_f32(idx, E)
        p, count = base.slot_positions(mask, count, token_axis=1)
        experts.append(idx)
        slots.append(p.astype(jnp.int32))

    expert_index = jnp.stack(experts, axis=-1)               # (G,T,k)
    slot_index = jnp.stack(slots, axis=-1)
    valid = slot_index < capacity
    gate = jnp.full((G, T, k), 1.0 / k, jnp.float32)         # uniform average
    if cfg.normalize_gates:
        # keep the uniform average over *surviving* choices (1/(kept k))
        gate = base.normalize_gates(gate, valid)

    zero = jnp.zeros((), jnp.float32)
    metrics = base.index_load_metrics(expert_index, valid, E, G * T * k)
    return RoutingPlan(expert_index, slot_index, gate, valid, E, capacity,
                       zero, zero, metrics, combine_dtype)


@register_router
class HashRouter:
    name = "hash"

    def param_spec(self, m: MoEConfig, d_model: int, init):
        return None  # stateless: no router weights

    def plan(self, x32, w, m: MoEConfig, capacity: int,
             combine_dtype=jnp.float32, ctx=None) -> RoutingPlan:
        G, T = x32.shape[0], x32.shape[1]
        ids = ctx.token_ids if ctx is not None else None
        pos = ctx.positions if ctx is not None else None
        return hash_plan(G, T, m, capacity, combine_dtype,
                         token_ids=ids, positions=pos)
