"""GShard/Switch sequential top-k routing (paper 3.2/3.3).

The literal "looping argmax" the paper benchmarks in Table 2: k
sequential passes, each taking the argmax over the not-yet-chosen
experts.  The index view — (expert, slot, gate, valid) per pass — falls
out of the loop directly; no dense ``(G, T, E, C)`` tensor is built.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core.routers import base, register_router
from repro.core.routers.base import RoutingPlan
from repro.nn import ParamSpec


def topk_logits(x32: jax.Array, w: jax.Array) -> jax.Array:
    """(G,T,M) x (M,E) -> (G,T,E)."""
    return jnp.einsum("gtm,me->gte", x32, w.astype(jnp.float32))


def topk_plan(logits: jax.Array, cfg: MoEConfig, capacity: int,
              combine_dtype=jnp.float32) -> RoutingPlan:
    """Sequential top-k gating from precomputed logits."""
    G, T, E = logits.shape
    k = cfg.top_k
    raw_gates = jax.nn.softmax(logits, axis=-1)              # (G,T,E)

    remaining = raw_gates
    count = jnp.zeros((G, E), jnp.float32)                   # per-expert occupancy
    experts, slots, gates = [], [], []
    first_mask = None
    # The literal "looping argmax" — k sequential passes (Table 2's cost).
    for _ in range(k):
        idx = jnp.argmax(remaining, axis=-1)                 # (G,T)
        mask = base.one_hot_f32(idx, E)                      # (G,T,E)
        if first_mask is None:
            first_mask = mask
        gate = jnp.sum(raw_gates * mask, axis=-1)            # (G,T)
        pos, count = base.slot_positions(mask, count, token_axis=1)
        experts.append(idx.astype(jnp.int32))
        slots.append(pos.astype(jnp.int32))
        gates.append(gate)
        remaining = remaining * (1.0 - mask)

    expert_index = jnp.stack(experts, axis=-1)               # (G,T,k)
    slot_index = jnp.stack(slots, axis=-1)                   # (G,T,k)
    gate = jnp.stack(gates, axis=-1)                         # (G,T,k)
    valid = slot_index < capacity

    if cfg.normalize_gates:
        gate = base.normalize_gates(gate, valid)

    density = jnp.mean(first_mask, axis=1)                   # (G,E)
    density_proxy = jnp.mean(raw_gates, axis=1)              # (G,E)
    aux = base.aux_loss(density, density_proxy, E, cfg.aux_loss_coef)
    zl = base.z_loss(logits, cfg.router_z_loss_coef)
    metrics = base.index_load_metrics(expert_index, valid, E, G * T * k)
    return RoutingPlan(expert_index, slot_index, gate, valid, E, capacity,
                       aux, zl, metrics, combine_dtype)


@register_router
class TopKRouter:
    name = "topk"

    def param_spec(self, m: MoEConfig, d_model: int, init):
        return ParamSpec((d_model, m.num_experts), jnp.float32,
                         ("embed", "expert"), init)

    def plan(self, x32, w, m: MoEConfig, capacity: int,
             combine_dtype=jnp.float32, ctx=None) -> RoutingPlan:
        return topk_plan(topk_logits(x32, w), m, capacity, combine_dtype)
