"""The ``RoutingPlan`` contract and the ``Router`` protocol.

A router turns token activations into a *plan*: for every token, up to K
expert choices, each described by four ``(G, T, K)`` arrays —

* ``expert_index`` — which expert the choice targets (int32 in [0, E));
* ``slot_index``   — the position inside that expert's capacity buffer
  (int32; values >= capacity mean the choice overflowed);
* ``gate``         — the combine weight (float32, post-normalisation);
* ``valid``        — whether the choice survived capacity (bool).

This *index view* is the canonical, compact representation: it is
``O(T*K)`` and is computed natively by every router — never recovered by
``argmax`` over dense masks.  The paper-faithful GShard one-hot tensors
(``combine``/``dispatch`` of shape ``(G, T, E, C)``) are *lazily
materialised* views, built by scatter only when the einsum execution
path asks for them.

Routers whose per-token fanout is naturally wide (expert-choice uses
K = E columns, mostly invalid) additionally provide the *slot-major*
view — ``token_at_slot``/``gate_at_slot`` of shape ``(G, E, C)`` — which
the gather/pallas dispatch prefers, keeping token movement ``O(E*C*M)``
rather than ``O(T*K*M)``.

A third, *ragged* view (:class:`RaggedView`, built on demand by
:meth:`RoutingPlan.ragged` and shared by every router) orders the valid
choices expert-major with block-aligned segment offsets — the
capacity-free layout the ``dropless`` execution backend consumes.

Invariants every router must uphold (asserted by the test-suite):

1. each valid ``(expert, slot)`` pair is unique within a group — a slot
   holds at most one token;
2. ``slot_index < capacity`` whenever ``valid``;
3. gates are non-negative; for token-choice routers the per-token gate
   sum is <= 1 (raw softmax mass) unless gates are renormalised.

Routers are plain stateless objects implementing :class:`Router` and are
looked up by name through :mod:`repro.core.routers` (the registry); a new
routing strategy is a ~50-line plugin, not a fork of the MoE layer.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core.context import MoEContext
from repro.core.metrics import dropped_fraction
from repro.nn import ParamSpec


@partial(jax.tree_util.register_dataclass,
         data_fields=("sort_order", "token", "gate", "expert_offsets",
                      "block_expert"),
         meta_fields=("num_experts", "block_rows"))
@dataclasses.dataclass(frozen=True)
class RaggedView:
    """Sorted, capacity-free execution layout of a :class:`RoutingPlan`.

    The view lists every *valid* choice exactly once, ordered expert-major
    (all of expert 0's rows, then expert 1's, ...), with each expert's
    segment padded up to a multiple of ``block_rows`` so that a fixed-size
    row block never straddles two experts — the layout a blocked/ragged
    grouped GEMM consumes directly (MegaBlocks-style).  There is no
    capacity dimension and no ``(G, T, E, C)`` intermediate: the row count
    ``R`` is ``O(T*K)`` (token-choice) or ``O(E*C)`` (slot-major), not
    ``O(E * C * gamma)``.

    Empty rows (segment padding, plus capacity-dropped choices when the
    plan was built with a finite capacity) carry ``token == -1`` and
    ``gate == 0`` — they flow through the grouped FFN like any other row
    and their outputs are discarded by the gate-weighted combine.
    """

    # Flat index into the plan's own choice space: t*K + k for
    # index-view plans, e*Cs + c for slot-major plans (-1 = empty row).
    # Consumers that need to invert the sort must branch on which view
    # built it (plan.token_at_slot is None); `token`/`gate` are uniform.
    sort_order: jax.Array      # (G, R) int32
    token: jax.Array           # (G, R) int32 source token per row; -1 = empty
    gate: jax.Array            # (G, R) f32 combine weight; 0 on empty rows
    expert_offsets: jax.Array  # (G, E+1) int32 block-aligned segment starts
    block_expert: jax.Array    # (G, R // block_rows) int32 expert per row block
    num_experts: int
    block_rows: int

    @property
    def row_valid(self) -> jax.Array:
        """(G, R) bool — rows holding a real (non-padding) choice."""
        return self.token >= 0


@partial(jax.tree_util.register_dataclass,
         data_fields=("expert_index", "slot_index", "gate", "valid",
                      "aux_loss", "z_loss", "metrics",
                      "token_at_slot", "gate_at_slot"),
         meta_fields=("num_experts", "capacity", "combine_dtype"))
@dataclasses.dataclass(frozen=True)
class RoutingPlan:
    """Index-view routing decision + lazily materialised dense views.

    Registered as a pytree with ``num_experts``/``capacity``/
    ``combine_dtype`` as static metadata, so a plan can cross jit
    boundaries (shapes stay Python ints inside traced code).
    """

    expert_index: jax.Array   # (G, T, K) int32
    slot_index: jax.Array     # (G, T, K) int32
    gate: jax.Array           # (G, T, K) float32
    valid: jax.Array          # (G, T, K) bool
    num_experts: int
    capacity: int
    aux_loss: jax.Array       # scalar f32 (load-balancing loss, 0 if disabled)
    z_loss: jax.Array         # scalar f32 (router z-loss, 0 if disabled)
    metrics: dict             # load-balance metrics (cv, dropped fraction, ...)
    combine_dtype: jnp.dtype = jnp.float32
    # Optional *slot-major* view for routers whose natural K would be
    # large (expert-choice: K = E).  token_at_slot[g, e, c] is the token
    # occupying slot (e, c), or -1 for an empty slot; gate_at_slot is
    # that choice's combine weight.  When present, the gather/pallas
    # dispatch uses these O(E*C) arrays instead of the (G, T, K) view.
    token_at_slot: Optional[jax.Array] = None   # (G, E, Cs) int32, -1 = empty
    gate_at_slot: Optional[jax.Array] = None    # (G, E, Cs) float32

    @property
    def masked_gate(self) -> jax.Array:
        """Gate with overflowed/invalid choices zeroed — the combine weight."""
        return jnp.where(self.valid, self.gate, 0.0)

    @property
    def combine(self) -> jax.Array:
        """Dense (G, T, E, C) combine view: gate * one_hot(e) * one_hot(c).

        Materialised by scatter from the index view; only the einsum
        (paper-faithful) path should touch this.
        """
        return self._scatter_dense(self.masked_gate.astype(self.combine_dtype))

    @property
    def dispatch(self) -> jax.Array:
        """Dense (G, T, E, C) boolean dispatch view (combine > 0)."""
        return self.combine > 0.0

    def _scatter_dense(self, values: jax.Array) -> jax.Array:
        G, T, K = self.expert_index.shape
        E, C = self.num_experts, self.capacity
        g = jnp.arange(G)[:, None, None]
        t = jnp.arange(T)[None, :, None]
        e = jnp.clip(self.expert_index, 0, E - 1)
        # overflowed slots land on a sentinel column that is sliced away
        c = jnp.where(self.valid, self.slot_index, C)
        dense = jnp.zeros((G, T, E, C + 1), values.dtype)
        return dense.at[g, t, e, c].add(values)[..., :C]

    # -- sorted / ragged view (capacity-free dispatch) ---------------------

    def ragged(self, block_rows: int = 128) -> RaggedView:
        """Lazily build the sorted/ragged view (see :class:`RaggedView`).

        Shared by every router: token-choice plans are sorted by expert id
        off the index view; slot-major plans (expert-choice) are already
        expert-major and only need block padding.  Computed on demand —
        only the ``dropless`` execution path pays for it.
        """
        if self.token_at_slot is not None:
            return self._ragged_slot_major(block_rows)
        return self._ragged_index_view(block_rows)

    def _ragged_index_view(self, bx: int) -> RaggedView:
        G, T, K = self.expert_index.shape
        E = self.num_experts
        n = T * K
        # Static row budget: every expert segment wastes < bx rows of
        # padding, so n + E*(bx-1) always fits, rounded up to a block.
        R = -(-(n + E * (bx - 1)) // bx) * bx

        e_flat = jnp.where(self.valid, self.expert_index, E).reshape(G, n)
        g_flat = self.masked_gate.astype(jnp.float32).reshape(G, n)

        def one(e, g):
            order = jnp.argsort(e)                     # stable: invalid last
            e_sorted = e[order]
            counts = jnp.zeros(E + 1, jnp.int32).at[e].add(1)[:E]
            padded = -(-counts // bx) * bx
            offsets = jnp.concatenate(
                [jnp.zeros(1, jnp.int32), jnp.cumsum(padded)])
            starts = jnp.concatenate(
                [jnp.zeros(1, jnp.int32), jnp.cumsum(counts)])
            seg = jnp.minimum(e_sorted, E - 1)
            dest = offsets[seg] + (jnp.arange(n, dtype=jnp.int32) - starts[seg])
            dest = jnp.where(e_sorted < E, dest, R)    # park invalid rows
            order32 = order.astype(jnp.int32)
            sort_order = jnp.full(R + 1, -1, jnp.int32).at[dest].set(order32)[:R]
            token = jnp.full(R + 1, -1, jnp.int32).at[dest].set(order32 // K)[:R]
            gate = jnp.zeros(R + 1, jnp.float32).at[dest].set(g[order])[:R]
            block_expert = jnp.clip(
                jnp.searchsorted(offsets, jnp.arange(R // bx, dtype=jnp.int32) * bx,
                                 side="right") - 1, 0, E - 1).astype(jnp.int32)
            return sort_order, token, gate, offsets, block_expert

        so, tok, gate, off, be = jax.vmap(one)(e_flat, g_flat)
        return RaggedView(so, tok, gate, off, be, E, bx)

    def _ragged_slot_major(self, bx: int) -> RaggedView:
        """Slot-major plans are already expert-major: segment e is its
        ``Cs`` slots, padded to a block multiple."""
        G, E, Cs = self.token_at_slot.shape
        Cp = -(-Cs // bx) * bx
        pad = Cp - Cs
        filled = self.token_at_slot >= 0
        gate = jnp.where(filled, self.gate_at_slot, 0.0).astype(jnp.float32)
        so = jnp.broadcast_to(
            jnp.arange(E * Cs, dtype=jnp.int32).reshape(E, Cs), (G, E, Cs))
        so = jnp.where(filled, so, -1)

        def padded(x, fill):
            return jnp.pad(x, ((0, 0), (0, 0), (0, pad)),
                           constant_values=fill).reshape(G, E * Cp)

        offsets = jnp.broadcast_to(
            jnp.arange(E + 1, dtype=jnp.int32) * Cp, (G, E + 1))
        block_expert = jnp.broadcast_to(
            (jnp.arange(E * Cp // bx, dtype=jnp.int32) * bx) // Cp,
            (G, E * Cp // bx)).astype(jnp.int32)
        return RaggedView(padded(so, -1), padded(self.token_at_slot, -1),
                          padded(gate, 0.0), offsets, block_expert, E, bx)


@runtime_checkable
class Router(Protocol):
    """A routing strategy: parameter spec + plan construction.

    Implementations are registered with
    :func:`repro.core.routers.register_router` and selected by
    ``MoEConfig.routing``.
    """

    name: str

    def param_spec(self, m: MoEConfig, d_model: int, init) -> Optional[ParamSpec]:
        """Router weight spec, or None for stateless (parameter-free) routers."""
        ...

    def plan(self, x32: jax.Array, w: Optional[jax.Array], m: MoEConfig,
             capacity: int, combine_dtype=jnp.float32,
             ctx: Optional[MoEContext] = None) -> RoutingPlan:
        """x32: (G, T, M) float32 tokens -> RoutingPlan.

        ``ctx`` carries (G, T)-grouped token ids / positions plus PRNG
        key, step and train flag — optional side information a router
        may consume (the ``hash`` router hashes ``ctx.token_ids``);
        every router must also work with ``ctx=None``.
        """
        ...


# ---------------------------------------------------------------------------
# Shared router math
# ---------------------------------------------------------------------------

def one_hot_f32(x: jax.Array, n: int) -> jax.Array:
    return jax.nn.one_hot(x, n, dtype=jnp.float32)


def slot_positions(mask: jax.Array, count: jax.Array, token_axis: int):
    """Position of each selected token inside its expert's buffer.

    ``mask`` is a one-hot expert selection with the expert axis last and
    tokens along ``token_axis``; ``count`` carries per-expert occupancy
    from earlier selection rounds.  Returns (pos, new_count).
    """
    pos_in_expert = jnp.cumsum(mask, axis=token_axis) - mask \
        + jnp.expand_dims(count, token_axis)
    pos = jnp.sum(pos_in_expert * mask, axis=-1)
    return pos, count + jnp.sum(mask, axis=token_axis)


def aux_loss(density: jax.Array, density_proxy: jax.Array, n: int,
             coef: float) -> jax.Array:
    """mesh-tf / Fig. 8 form: mean(density * density_proxy) * n^2 * coef."""
    return jnp.mean(density * density_proxy) * float(n) * float(n) * coef


def z_loss(logits: jax.Array, coef: float) -> jax.Array:
    if coef == 0.0:
        return jnp.zeros((), jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    return coef * jnp.mean(jnp.square(lse))


def normalize_gates(gate: jax.Array, valid: jax.Array) -> jax.Array:
    """Renormalise a token's kept gates to sum to 1 (0 if all dropped)."""
    kept = jnp.where(valid, gate, 0.0)
    denom = jnp.sum(kept, axis=-1, keepdims=True)
    return kept / jnp.maximum(denom, 1e-9)


def index_load_metrics(expert_index: jax.Array, valid: jax.Array,
                       num_experts: int, total_slots: int) -> dict:
    """Compute-load metrics straight from the index view (paper 3.1).

    c_v = sigma(loads) / mu(loads) over experts, where loads counts real
    dispatched tokens (capacity overflow excluded) — the paper's
    definition, computed without any (G, T, E, C) intermediate.
    """
    flat_e = jnp.clip(expert_index, 0, num_experts - 1).reshape(-1)
    flat_v = valid.reshape(-1).astype(jnp.float32)
    loads = jnp.zeros((num_experts,), jnp.float32).at[flat_e].add(flat_v)
    mean = jnp.mean(loads)
    cv = jnp.std(loads) / (mean + 1e-9)
    return {"cv": cv,
            "dropped_fraction": dropped_fraction(loads, total_slots),
            "expert_loads": loads,
            # the dropped_fraction denominator, carried so consumers can
            # aggregate drop *counts* exactly across steps (serving
            # telemetry) instead of re-deriving it per router
            "routed_choices": jnp.asarray(float(total_slots), jnp.float32)}
