"""MoE attention (paper 3.4): Q/K/V/O projections as mixtures of experts.

The paper replaces the four attention linear maps with MoE layers and
finds a *negative* result (worse quality, divergence) that expert
prototyping partially mitigates.  We reproduce the mechanism: one router
decision per token per layer; each expert owns a full {Wq,Wk,Wv,Wo} set.
Tokens are dispatched once, projected by their experts' Q/K/V weights,
combined back, attention proper is computed densely, and the output
projection is again dispatched/combined through the same routing.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.context import MoEContext
from repro.core.moe import group_tokens
from repro.core.routers import get_router
from repro.core.routing import route
from repro.distributed.sharding import shard
from repro.models.attention import _sdpa, causal_mask
from repro.models.layers import apply_rope, rope
from repro.nn import ParamSpec, truncated_normal_init


def moe_attention_specs(cfg: ModelConfig):
    m = cfg.moe
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    wdt = jnp.dtype(cfg.param_dtype)
    init = truncated_normal_init(cfg.initializer_range)
    E = m.num_experts
    specs = {
        "wq": ParamSpec((E, d, cfg.num_heads * hd), wdt, ("expert", "embed", "heads"), init),
        "wk": ParamSpec((E, d, cfg.num_kv_heads * hd), wdt, ("expert", "embed", "kv_heads"), init),
        "wv": ParamSpec((E, d, cfg.num_kv_heads * hd), wdt, ("expert", "embed", "kv_heads"), init),
        "wo": ParamSpec((E, cfg.num_heads * hd, d), wdt, ("expert", "heads", "embed"), init),
    }
    router = get_router(m.routing).param_spec(m, d, init)
    if router is not None:
        specs["router"] = router
    return specs


def _moe_project(w, dispatched, dt):
    """(E,G,C,M) x (E,M,O) -> (E,G,C,O)."""
    return jnp.einsum("egcm,emo->egco", dispatched, w.astype(dt))


def moe_attention_apply(params, x, cfg: ModelConfig, *, positions,
                        causal: bool = True,
                        ctx: Optional[MoEContext] = None) -> Tuple[jax.Array, dict]:
    m = cfg.moe
    dt = cfg.activation_dtype
    B, S, M = x.shape
    hd = cfg.resolved_head_dim

    xg, G = group_tokens(x, m)
    T = xg.shape[1]
    capacity = m.capacity(T)
    router_w = params.get("router")
    if router_w is not None:
        router_w = router_w.astype(jnp.float32)
    # Attention experts route *projections*, not token content: the
    # context passed down is positions-only (token_ids stripped), so
    # e.g. the hash router falls back to its position hash here.
    actx = None
    if ctx is not None:
        actx = ctx.replace(token_ids=None).grouped(G, T)
    routing = route(xg, router_w, m, capacity, ctx=actx)
    E, C = m.num_experts, capacity

    combine = routing.combine                  # materialise the dense view once
    disp = (combine > 0.0).astype(dt)
    combine = combine.astype(dt)
    dispatched = jnp.einsum("gtec,gtm->egcm", disp, xg)
    dispatched = shard(dispatched, "expert", "groups", None, None)

    def back(y_egco, out_dim):
        y = jnp.einsum("gtec,egco->gto", combine, y_egco)
        return y.reshape(B, S, out_dim)

    q = back(_moe_project(params["wq"], dispatched, dt), cfg.num_heads * hd)
    k = back(_moe_project(params["wk"], dispatched, dt), cfg.num_kv_heads * hd)
    v = back(_moe_project(params["wv"], dispatched, dt), cfg.num_kv_heads * hd)

    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    sin, cos = rope(positions, hd, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)

    mask = causal_mask(S, S) if causal else None
    attn = _sdpa(q, k, v, cfg, mask,
                 causal_offset=0 if causal else None).reshape(B, S, cfg.num_heads * hd)

    # Output projection through the same routing decision.
    ag, _ = group_tokens(attn, m)
    disp_a = jnp.einsum("gtec,gtm->egcm", disp, ag)
    y = back(_moe_project(params["wo"], disp_a, dt), M)

    aux = {
        "moe_aux_loss": routing.aux_loss,
        "moe_z_loss": routing.z_loss,
        "moe_cv": routing.metrics["cv"],
        "moe_dropped_fraction": routing.metrics["dropped_fraction"],
    }
    return y.astype(x.dtype), aux
