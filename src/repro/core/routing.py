"""Token -> expert routing: sequential top-k and M6-T expert prototyping.

Faithful to the paper's pseudo-code (Figs. 7-8):

* ``topk_gating``   — GShard-style sequential top-k with the *looping
  argmax* the paper identifies as the efficiency problem (Table 2).
* ``prototype_gating`` — the paper's contribution (Eq. 3 / Fig. 8):
  experts are split into Z prototypes of F = E/Z experts; each prototype
  routes independently with top-1 (generalised to top-k'); outputs are
  summed.  No argmax loop across prototypes — everything is parallel.

Tokens are routed inside *groups* (the ``d``/worker dimension in the
paper's pseudo-code generalised to G groups): capacity and the
position-in-expert cumulative sum are per group, matching GShard
semantics where each worker routes its local tokens.

All routing math runs in float32 regardless of activation dtype.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig


class RoutingResult(NamedTuple):
    combine: jax.Array    # (G, T, E, C) float: gate * one_hot(expert) * one_hot(pos)
    dispatch: jax.Array   # (G, T, E, C) bool
    aux_loss: jax.Array   # scalar f32 (load-balancing loss, 0 if disabled)
    z_loss: jax.Array     # scalar f32 (router z-loss, 0 if disabled)
    metrics: dict         # load-balance metrics (c_v, dropped fraction, ...)


def _one_hot(x, n):
    return jax.nn.one_hot(x, n, dtype=jnp.float32)


def _load_metrics(dispatch_mask_gtec: jax.Array, active_k: int) -> dict:
    """Compute-load metrics over *real* dispatched tokens (paper 3.1).

    c_v = sigma(loads) / mu(loads) over experts, where loads counts real
    tokens (capacity padding excluded) — exactly the paper's definition.
    """
    loads = jnp.sum(dispatch_mask_gtec, axis=(0, 1, 3))  # (E,)
    mean = jnp.mean(loads)
    cv = jnp.std(loads) / (mean + 1e-9)
    total_slots = dispatch_mask_gtec.shape[0] * dispatch_mask_gtec.shape[1] * active_k
    dropped = 1.0 - jnp.sum(loads) / total_slots
    return {"cv": cv, "dropped_fraction": dropped, "expert_loads": loads}


def router_logits_topk(x32: jax.Array, w: jax.Array) -> jax.Array:
    """(G,T,M) x (M,E) -> (G,T,E)."""
    return jnp.einsum("gtm,me->gte", x32, w.astype(jnp.float32))


def router_logits_prototype(x32: jax.Array, w: jax.Array) -> jax.Array:
    """(G,T,M) x (M,Z,F) -> (G,Z,T,F)  (Fig. 8: 'dTZM,MZF->dZTF')."""
    return jnp.einsum("gtm,mzf->gztf", x32, w.astype(jnp.float32))


def _aux_loss(density: jax.Array, density_proxy: jax.Array, n: int, coef: float) -> jax.Array:
    """mesh-tf / Fig. 8 form: mean(density * density_proxy) * n^2 * coef."""
    return jnp.mean(density * density_proxy) * float(n) * float(n) * coef


def _z_loss(logits: jax.Array, coef: float) -> jax.Array:
    if coef == 0.0:
        return jnp.zeros((), jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    return coef * jnp.mean(jnp.square(lse))


def topk_gating(
    logits: jax.Array,   # (G, T, E) f32
    cfg: MoEConfig,
    capacity: int,
    combine_dtype=jnp.float32,
) -> RoutingResult:
    """Sequential top-k routing with the looping argmax (paper 3.2/3.3).

    The combine tensor accumulates in ``combine_dtype`` (bf16 at scale, as
    in mesh-tf): every (t,e,c) slot is written by at most one iteration,
    so reduced precision only rounds the gate value itself."""
    G, T, E = logits.shape
    k = cfg.top_k
    raw_gates = jax.nn.softmax(logits, axis=-1)  # (G,T,E)

    remaining = raw_gates
    count = jnp.zeros((G, E), jnp.float32)        # tokens already assigned per expert
    combine = jnp.zeros((G, T, E, capacity), combine_dtype)
    first_mask = None
    # The literal "looping argmax" — k sequential passes (Table 2's cost).
    for i in range(k):
        idx = jnp.argmax(remaining, axis=-1)                     # (G,T)
        mask = _one_hot(idx, E)                                  # (G,T,E)
        if first_mask is None:
            first_mask = mask
        gate = jnp.sum(raw_gates * mask, axis=-1)                # (G,T)
        # position of each token within its expert's buffer, continuing
        # from previous iterations' assignments
        pos_in_expert = jnp.cumsum(mask, axis=1) - mask + count[:, None, :]
        pos = jnp.sum(pos_in_expert * mask, axis=-1)             # (G,T)
        count = count + jnp.sum(mask, axis=1)
        keep = (pos < capacity).astype(jnp.float32)              # (G,T)
        contrib = (gate * keep)[:, :, None, None] * (
            mask[:, :, :, None] * _one_hot(pos.astype(jnp.int32), capacity)[:, :, None, :]
        )
        combine = combine + contrib.astype(combine_dtype)
        remaining = remaining * (1.0 - mask)

    if cfg.normalize_gates:
        denom = jnp.sum(combine, axis=(2, 3), keepdims=True)
        combine = combine / jnp.maximum(denom, 1e-9)
        combine = combine * jnp.minimum(jnp.sum(combine, axis=(2, 3), keepdims=True), 1.0)

    dispatch = combine > 0.0
    density = jnp.mean(first_mask, axis=1)                       # (G,E)
    density_proxy = jnp.mean(raw_gates, axis=1)                  # (G,E)
    aux = _aux_loss(density, density_proxy, E, cfg.aux_loss_coef)
    zl = _z_loss(logits, cfg.router_z_loss_coef)
    metrics = _load_metrics(dispatch, k)
    return RoutingResult(combine, dispatch, aux, zl, metrics)


def prototype_gating(
    logits: jax.Array,   # (G, Z, T, F) f32
    cfg: MoEConfig,
    capacity: int,
    combine_dtype=jnp.float32,
) -> RoutingResult:
    """k top-1 expert prototyping (Fig. 8), generalised to top-k' > 1."""
    G, Z, T, F = logits.shape
    raw_gates = jax.nn.softmax(logits, axis=-1)                  # (G,Z,T,F)

    kp = cfg.prototype_top_k
    combine_zf = jnp.zeros((G, Z, T, F, capacity), combine_dtype)
    remaining = raw_gates
    count = jnp.zeros((G, Z, F), jnp.float32)
    first_mask = None
    for i in range(kp):  # paper: kp == 1, no loop in the hot path
        idx = jnp.argmax(remaining, axis=-1)                     # (G,Z,T)
        mask = _one_hot(idx, F)                                  # (G,Z,T,F)
        if first_mask is None:
            first_mask = mask
        gate = jnp.sum(raw_gates * mask, axis=-1)                # (G,Z,T)
        pos_in_expert = jnp.cumsum(mask, axis=2) - mask + count[:, :, None, :]
        pos = jnp.sum(pos_in_expert * mask, axis=-1)             # (G,Z,T)
        count = count + jnp.sum(mask, axis=2)
        keep = (pos < capacity).astype(jnp.float32)
        contrib = (gate * keep)[..., None, None] * (
            mask[..., None] * _one_hot(pos.astype(jnp.int32), capacity)[..., None, :]
        )
        combine_zf = combine_zf + contrib.astype(combine_dtype)
        remaining = remaining * (1.0 - mask)

    # (G,Z,T,F,C) -> (G,T,Z,F,C) -> (G,T,E,C)   (Fig. 8 reshape)
    combine = jnp.transpose(combine_zf, (0, 2, 1, 3, 4)).reshape(G, T, Z * F, capacity)
    dispatch = combine > 0.0

    # aux loss per prototype over its F experts (Fig. 8: F^2 scaling).
    density = jnp.mean(first_mask, axis=2)                       # (G,Z,F)
    density_proxy = jnp.mean(raw_gates, axis=2)                  # (G,Z,F)
    aux = _aux_loss(density, density_proxy, F, cfg.aux_loss_coef)
    zl = _z_loss(logits, cfg.router_z_loss_coef)
    metrics = _load_metrics(dispatch, Z * kp)
    return RoutingResult(combine, dispatch, aux, zl, metrics)


def route(
    x: jax.Array,        # (G, T, M) tokens (any float dtype)
    router_w: jax.Array,  # (M,E) for topk / (M,Z,F) for prototype
    cfg: MoEConfig,
    capacity: int,
) -> RoutingResult:
    x32 = x.astype(jnp.float32)
    cd = jnp.float32 if cfg.combine_dtype == "float32" else jnp.dtype(x.dtype)
    if cfg.routing == "prototype":
        logits = router_logits_prototype(x32, router_w)
        return prototype_gating(logits, cfg, capacity, combine_dtype=cd)
    elif cfg.routing == "topk":
        logits = router_logits_topk(x32, router_w)
        return topk_gating(logits, cfg, capacity, combine_dtype=cd)
    raise ValueError(f"unknown routing mode {cfg.routing!r}")
