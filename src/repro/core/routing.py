"""Token -> expert routing, dispatched through the pluggable Router API.

The strategies themselves live in :mod:`repro.core.routers` (one module
per router, registered by name); this module is the stable entry point:

* :func:`route` — look up ``cfg.routing`` in the registry and build a
  :class:`~repro.core.routers.base.RoutingPlan` (the compact index view;
  dense GShard ``combine``/``dispatch`` tensors are lazy properties).
* ``topk_gating`` / ``prototype_gating`` — the paper's gating functions
  (Figs. 7-8) operating on precomputed logits, kept for tests and direct
  experimentation.

Tokens are routed inside *groups* (the ``d``/worker dimension in the
paper's pseudo-code generalised to G groups): capacity and the
position-in-expert cumulative sum are per group, matching GShard
semantics where each worker routes its local tokens.

All routing math runs in float32 regardless of activation dtype.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core.context import MoEContext
from repro.core.routers import available_routers, get_router, register_router
from repro.core.routers.base import RoutingPlan
from repro.core.routers.expert_choice import expert_choice_plan
from repro.core.routers.hashed import hash_plan
from repro.core.routers.prototype import prototype_logits, prototype_plan
from repro.core.routers.topk import topk_logits, topk_plan

# Back-compat aliases (pre-Router-API names).
RoutingResult = RoutingPlan
router_logits_topk = topk_logits
router_logits_prototype = prototype_logits
topk_gating = topk_plan
prototype_gating = prototype_plan


def route(
    x: jax.Array,                    # (G, T, M) tokens (any float dtype)
    router_w: Optional[jax.Array],   # router weights, None for stateless routers
    cfg: MoEConfig,
    capacity: int,
    ctx: Optional[MoEContext] = None,  # (G, T)-grouped side information
) -> RoutingPlan:
    """Build the routing plan for ``cfg.routing`` via the registry.

    ``ctx`` (token ids / positions regrouped to the (G, T) layout, PRNG
    key, step, train flag) is optional side information; routers that
    don't consume it ignore it.
    """
    x32 = x.astype(jnp.float32)
    cd = jnp.float32 if cfg.combine_dtype == "float32" else jnp.dtype(x.dtype)
    router = get_router(cfg.routing)
    return router.plan(x32, router_w, cfg, capacity, combine_dtype=cd, ctx=ctx)


__all__ = [
    "RoutingPlan", "RoutingResult", "route",
    "register_router", "get_router", "available_routers",
    "topk_gating", "prototype_gating",
    "topk_plan", "prototype_plan", "expert_choice_plan", "hash_plan",
    "router_logits_topk", "router_logits_prototype",
]
