"""The MoE FFN layer (paper Fig. 7), as a thin composition of two
registries plus parameter specs and token grouping:

* **Routing** (:mod:`repro.core.routers`, keyed by ``MoEConfig.routing``)
  decides *which* expert gets which token and emits a compact index-view
  :class:`~repro.core.routers.base.RoutingPlan`.
* **Dispatch** (:mod:`repro.core.dispatch`, keyed by ``MoEConfig.impl``)
  decides *how* that plan executes: ``einsum`` (paper-faithful GShard
  one-hot einsums, dense ``(G,T,E,C)`` view, implicit GSPMD parallelism),
  ``gather`` (flat slot-id scatter/gather off the index view, O(k*T*M)
  token movement), ``pallas`` (gather dispatch + the Pallas grouped-GEMM
  expert-FFN kernel), ``alltoall`` (explicit expert parallelism:
  ``shard_map`` over the mesh's expert axis with ``lax.all_to_all``
  collectives — Fig. 7's system design written down as collectives
  rather than recovered by the compiler), and ``dropless``
  (capacity-free: the plan's sorted ragged view feeding a blocked
  grouped GEMM — with ``capacity_factor=None`` no token is ever
  dropped and no ``(E, C)`` buffer exists).

Every (router, dispatcher) pair composes: the plan is computed once, so
all backends execute the same assignment and are numerically
interchangeable — asserted forward and backward by the test-suite.

``moe_ffn_apply`` additionally accepts a
:class:`~repro.core.context.MoEContext` carrying token ids, absolute
positions, PRNG key, step, and train/eval mode.  The layer regroups the
per-sequence fields to the router's ``(G, T)`` layout and hands the
context to both registries, which is what lets the ``hash`` router hash
token *identity* (true Hash Layers) instead of position.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.core.context import MoEContext
from repro.core.dispatch import get_dispatcher
from repro.core.metrics import gate_entropy
from repro.core.routers import get_router
from repro.core.routing import RoutingPlan, route
from repro.distributed.sharding import shard
from repro.nn import ParamSpec, truncated_normal_init


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def moe_ffn_specs(cfg: ModelConfig, d_model: Optional[int] = None):
    m = cfg.moe
    d = d_model or cfg.d_model
    dff = cfg.d_ff
    wdt = jnp.dtype(cfg.param_dtype)
    init = truncated_normal_init(cfg.initializer_range)
    specs = {
        "up": ParamSpec((m.num_experts, d, dff), wdt, ("expert", "embed", "mlp"), init),
        "down": ParamSpec((m.num_experts, dff, d), wdt, ("expert", "mlp", "embed"), init),
    }
    router = get_router(m.routing).param_spec(m, d, init)
    if router is not None:
        specs["router"] = router
    if cfg.ffn_activation in ("swiglu", "geglu"):
        specs["gate"] = ParamSpec((m.num_experts, d, dff), wdt, ("expert", "embed", "mlp"), init)
    return specs


# ---------------------------------------------------------------------------
# Grouping
# ---------------------------------------------------------------------------

def group_tokens(x: jax.Array, m: MoEConfig) -> Tuple[jax.Array, int]:
    """(B,S,M) -> (G,T,M).  Group count is a divisor of B*S close to
    B*S/group_size so capacity semantics stay per-group (GShard)."""
    B, S, M = x.shape
    total = B * S
    target_groups = max(total // m.group_size, 1)
    g = _largest_divisor_leq(total, target_groups)
    return x.reshape(g, total // g, M), g


def _largest_divisor_leq(n: int, k: int) -> int:
    k = min(max(k, 1), n)
    for g in range(k, 0, -1):
        if n % g == 0:
            return g
    return 1


# ---------------------------------------------------------------------------
# The layer
# ---------------------------------------------------------------------------

def moe_ffn_apply(params, x, cfg: ModelConfig,
                  ctx: Optional[MoEContext] = None) -> Tuple[jax.Array, dict]:
    """x: (B, S, M) -> (y, aux) where aux carries losses + load metrics.

    ``ctx`` is optional — ``None`` means "no side information" and every
    router/dispatcher must cope (the pre-context signature).
    """
    m = cfg.moe
    B, S, M = x.shape
    xg, G = group_tokens(x, m)
    T = xg.shape[1]
    capacity = m.capacity(T)
    xg = shard(xg, "groups", None, None)
    gctx = ctx.grouped(G, T) if ctx is not None else None

    router_w = params.get("router")
    if router_w is not None:
        router_w = router_w.astype(jnp.float32)
    with jax.named_scope("moe_route"):
        plan = route(xg, router_w, m, capacity, ctx=gctx)

    with jax.named_scope(f"moe_dispatch_{m.impl}"):
        y = get_dispatcher(m.impl)(params, xg, plan, cfg, ctx=gctx)

    y = y.reshape(B, S, M).astype(x.dtype)
    aux = {
        "moe_aux_loss": plan.aux_loss,
        "moe_z_loss": plan.z_loss,
        "moe_cv": plan.metrics["cv"],
        "moe_dropped_fraction": plan.metrics["dropped_fraction"],
        # live telemetry (repro.obs): per-expert kept-choice counts, the
        # kept-gate entropy, and the drop denominator — all derived from
        # the plan the dispatcher actually executed
        "moe_expert_tokens":
            plan.metrics["expert_loads"].astype(jnp.float32),
        "moe_gate_entropy": gate_entropy(plan.gate, plan.valid),
        "moe_routed_choices": plan.metrics.get(
            "routed_choices",
            jnp.asarray(float(plan.expert_index.size), jnp.float32)),
    }
    return y, aux
