"""The MoE FFN layer (paper Fig. 7) with three numerically-equivalent
execution paths:

* ``impl="einsum"``  — paper-faithful GShard one-hot einsum dispatch/combine
  (`dispatch[GTEC] x tokens[GTM] -> [EGCM]`, expert FFN, combine back).
  Under pjit the expert axis sharding induces the all-to-alls of Fig. 7.
* ``impl="gather"``  — beyond-paper optimized path: scatter/gather token
  movement, O(k*T*M) instead of O(T*E*C*M); same outputs.
* ``impl="pallas"``  — gather dispatch + Pallas grouped-GEMM expert FFN
  (`repro.kernels.moe_ffn`) for the compute hot-spot (the paper's appendix
  attributes ~98% of MoE-layer forward FLOPs to the two expert matmuls).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.core.routing import RoutingResult, route
from repro.distributed.sharding import shard
from repro.nn import ParamSpec, truncated_normal_init


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def moe_ffn_specs(cfg: ModelConfig, d_model: Optional[int] = None):
    m = cfg.moe
    d = d_model or cfg.d_model
    dff = cfg.d_ff
    wdt = jnp.dtype(cfg.param_dtype)
    init = truncated_normal_init(cfg.initializer_range)
    if m.routing == "prototype":
        router = ParamSpec(
            (d, m.num_prototypes, m.experts_per_prototype),
            jnp.float32, ("embed", None, "expert"), init,
        )
    else:
        router = ParamSpec((d, m.num_experts), jnp.float32, ("embed", "expert"), init)
    specs = {
        "router": router,
        "up": ParamSpec((m.num_experts, d, dff), wdt, ("expert", "embed", "mlp"), init),
        "down": ParamSpec((m.num_experts, dff, d), wdt, ("expert", "mlp", "embed"), init),
    }
    if cfg.ffn_activation in ("swiglu", "geglu"):
        specs["gate"] = ParamSpec((m.num_experts, d, dff), wdt, ("expert", "embed", "mlp"), init)
    return specs


# ---------------------------------------------------------------------------
# Grouping
# ---------------------------------------------------------------------------

def group_tokens(x: jax.Array, m: MoEConfig) -> Tuple[jax.Array, int]:
    """(B,S,M) -> (G,T,M).  Group count is a divisor of B*S close to
    B*S/group_size so capacity semantics stay per-group (GShard)."""
    B, S, M = x.shape
    total = B * S
    target_groups = max(total // m.group_size, 1)
    g = _largest_divisor_leq(total, target_groups)
    return x.reshape(g, total // g, M), g


def _largest_divisor_leq(n: int, k: int) -> int:
    k = min(max(k, 1), n)
    for g in range(k, 0, -1):
        if n % g == 0:
            return g
    return 1


# ---------------------------------------------------------------------------
# Expert FFN on dispatched buffers
# ---------------------------------------------------------------------------

def _expert_ffn(params, dispatched: jax.Array, cfg: ModelConfig) -> jax.Array:
    """dispatched: (E, X, M) -> (E, X, M) through each expert's FFN."""
    dt = cfg.activation_dtype
    up_w = params["up"].astype(dt)
    down_w = params["down"].astype(dt)
    if cfg.moe.impl == "pallas":
        from repro.kernels.moe_ffn import ops as moe_ops

        gate_w = params["gate"].astype(dt) if "gate" in params else None
        return moe_ops.moe_ffn(dispatched, up_w, gate_w, down_w, cfg.ffn_activation)
    h = jnp.einsum("exm,emi->exi", dispatched, up_w)
    if "gate" in params:
        g = jnp.einsum("exm,emi->exi", dispatched, params["gate"].astype(dt))
        h = jax.nn.silu(g) * h if cfg.ffn_activation == "swiglu" else jax.nn.gelu(g) * h
    elif cfg.ffn_activation == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.relu(h)
    return jnp.einsum("exi,eim->exm", h, down_w)


# ---------------------------------------------------------------------------
# Execution paths
# ---------------------------------------------------------------------------

def _einsum_path(params, xg, routing: RoutingResult, cfg: ModelConfig) -> jax.Array:
    """Paper-faithful Fig. 7: one-hot einsum dispatch -> expert FFN -> combine."""
    dt = cfg.activation_dtype
    G, T, E, C = routing.combine.shape
    dispatch = routing.dispatch.astype(dt)                     # (G,T,E,C)
    # 'dTZFC,dTZM->ZFdCM' in the paper == 'gtec,gtm->egcm' with E=Z*F.
    dispatched = jnp.einsum("gtec,gtm->egcm", dispatch, xg)
    dispatched = shard(dispatched, "expert", "groups", None, None)
    out = _expert_ffn(params, dispatched.reshape(E, G * C, cfg.d_model), cfg)
    out = out.reshape(E, G, C, cfg.d_model)
    out = shard(out, "expert", "groups", None, None)
    # 'dTEC,EdCM->dTM' == 'gtec,egcm->gtm'
    y = jnp.einsum("gtec,egcm->gtm", routing.combine.astype(dt), out)
    return y


def _gather_path(params, xg, routing: RoutingResult, cfg: ModelConfig) -> jax.Array:
    """Optimized: scatter tokens into expert buffers, gather back.

    Same (E,C) buffer layout and capacity semantics as the einsum path, so
    outputs are bit-comparable (up to reduction order).
    """
    dt = cfg.activation_dtype
    G, T, E, C = routing.combine.shape
    M = xg.shape[-1]
    # slot id per (g, t, e, c) is e*C + c; each token occupies at most
    # active_k slots.  Recover (slot -> token) via a scatter-add of x
    # weighted by the dispatch mask: since each (e,c) slot holds at most
    # one token, the sum places exactly that token (or zeros).
    dispatch = routing.dispatch.astype(dt)
    buf = jnp.einsum("gtec,gtm->gecm", dispatch, xg)  # fallback when T small
    # For larger T, use true gather/scatter:
    if T > 64:
        # token index occupying each (e,c) slot (or -1)
        tok_idx = jnp.argmax(routing.dispatch, axis=1)            # (G,E,C)
        occupied = jnp.any(routing.dispatch, axis=1)              # (G,E,C)
        gathered = jnp.take_along_axis(
            xg[:, :, None, :], tok_idx.reshape(G, -1, 1, 1).astype(jnp.int32), axis=1
        )
        gathered = gathered.reshape(G, E, C, M)
        buf = jnp.where(occupied[..., None], gathered, 0.0).astype(dt)
    buf = jnp.transpose(buf, (1, 0, 2, 3))                        # (E,G,C,M)
    buf = shard(buf, "expert", "groups", None, None)
    out = _expert_ffn(params, buf.reshape(E, G * C, M), cfg).reshape(E, G, C, M)
    out = jnp.transpose(out, (1, 0, 2, 3))                        # (G,E,C,M)
    # combine: for each token sum over its (e,c) slots with gate weights
    y = jnp.einsum("gtec,gecm->gtm", routing.combine.astype(dt), out)
    return y


def moe_ffn_apply(params, x, cfg: ModelConfig) -> Tuple[jax.Array, dict]:
    """x: (B, S, M) -> (y, aux) where aux carries losses + load metrics."""
    m = cfg.moe
    B, S, M = x.shape
    xg, G = group_tokens(x, m)
    T = xg.shape[1]
    capacity = m.capacity(T)
    xg = shard(xg, "groups", None, None)

    routing = route(xg, params["router"].astype(jnp.float32), m, capacity)

    if m.impl in ("gather",):
        y = _gather_path(params, xg, routing, cfg)
    else:  # "einsum" (faithful) and "pallas" (einsum dispatch + kernel FFN)
        y = _einsum_path(params, xg, routing, cfg)

    y = y.reshape(B, S, M).astype(x.dtype)
    aux = {
        "moe_aux_loss": routing.aux_loss,
        "moe_z_loss": routing.z_loss,
        "moe_cv": routing.metrics["cv"],
        "moe_dropped_fraction": routing.metrics["dropped_fraction"],
    }
    return y, aux
