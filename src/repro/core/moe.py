"""The MoE FFN layer (paper Fig. 7) with three numerically-equivalent
execution paths:

* ``impl="einsum"``  — paper-faithful GShard one-hot einsum dispatch/combine
  (`dispatch[GTEC] x tokens[GTM] -> [EGCM]`, expert FFN, combine back),
  materialising the RoutingPlan's dense view.  Under pjit the expert axis
  sharding induces the all-to-alls of Fig. 7.
* ``impl="gather"``  — beyond-paper optimized path: consumes the plan's
  *index view* directly — tokens are scattered into flat (E*C) expert
  buffers by slot id and gathered back by the same ids.  O(k*T*M) memory
  and compute instead of O(T*E*C*M); no (G,T,E,C) tensor is ever built.
* ``impl="pallas"``  — the same index-view dispatch feeding the Pallas
  grouped-GEMM expert FFN (`repro.kernels.moe_ffn`) for the compute
  hot-spot (the paper's appendix attributes ~98% of MoE-layer forward
  FLOPs to the two expert matmuls).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.core.routers import get_router
from repro.core.routing import RoutingPlan, route
from repro.distributed.sharding import shard
from repro.nn import ParamSpec, truncated_normal_init


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def moe_ffn_specs(cfg: ModelConfig, d_model: Optional[int] = None):
    m = cfg.moe
    d = d_model or cfg.d_model
    dff = cfg.d_ff
    wdt = jnp.dtype(cfg.param_dtype)
    init = truncated_normal_init(cfg.initializer_range)
    specs = {
        "up": ParamSpec((m.num_experts, d, dff), wdt, ("expert", "embed", "mlp"), init),
        "down": ParamSpec((m.num_experts, dff, d), wdt, ("expert", "mlp", "embed"), init),
    }
    router = get_router(m.routing).param_spec(m, d, init)
    if router is not None:
        specs["router"] = router
    if cfg.ffn_activation in ("swiglu", "geglu"):
        specs["gate"] = ParamSpec((m.num_experts, d, dff), wdt, ("expert", "embed", "mlp"), init)
    return specs


# ---------------------------------------------------------------------------
# Grouping
# ---------------------------------------------------------------------------

def group_tokens(x: jax.Array, m: MoEConfig) -> Tuple[jax.Array, int]:
    """(B,S,M) -> (G,T,M).  Group count is a divisor of B*S close to
    B*S/group_size so capacity semantics stay per-group (GShard)."""
    B, S, M = x.shape
    total = B * S
    target_groups = max(total // m.group_size, 1)
    g = _largest_divisor_leq(total, target_groups)
    return x.reshape(g, total // g, M), g


def _largest_divisor_leq(n: int, k: int) -> int:
    k = min(max(k, 1), n)
    for g in range(k, 0, -1):
        if n % g == 0:
            return g
    return 1


# ---------------------------------------------------------------------------
# Expert FFN on dispatched buffers
# ---------------------------------------------------------------------------

def _expert_ffn(params, dispatched: jax.Array, cfg: ModelConfig) -> jax.Array:
    """dispatched: (E, X, M) -> (E, X, M) through each expert's FFN."""
    dt = cfg.activation_dtype
    up_w = params["up"].astype(dt)
    down_w = params["down"].astype(dt)
    if cfg.moe.impl == "pallas":
        from repro.kernels.moe_ffn import ops as moe_ops

        gate_w = params["gate"].astype(dt) if "gate" in params else None
        return moe_ops.moe_ffn(dispatched, up_w, gate_w, down_w, cfg.ffn_activation)
    h = jnp.einsum("exm,emi->exi", dispatched, up_w)
    if "gate" in params:
        g = jnp.einsum("exm,emi->exi", dispatched, params["gate"].astype(dt))
        h = jax.nn.silu(g) * h if cfg.ffn_activation == "swiglu" else jax.nn.gelu(g) * h
    elif cfg.ffn_activation == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.relu(h)
    return jnp.einsum("exi,eim->exm", h, down_w)


# ---------------------------------------------------------------------------
# Execution paths
# ---------------------------------------------------------------------------

def _einsum_path(params, xg, plan: RoutingPlan, cfg: ModelConfig) -> jax.Array:
    """Paper-faithful Fig. 7: one-hot einsum dispatch -> expert FFN -> combine."""
    dt = cfg.activation_dtype
    combine = plan.combine                                     # (G,T,E,C) dense view
    G, T, E, C = combine.shape
    dispatch = (combine > 0.0).astype(dt)
    # 'dTZFC,dTZM->ZFdCM' in the paper == 'gtec,gtm->egcm' with E=Z*F.
    dispatched = jnp.einsum("gtec,gtm->egcm", dispatch, xg)
    dispatched = shard(dispatched, "expert", "groups", None, None)
    out = _expert_ffn(params, dispatched.reshape(E, G * C, cfg.d_model), cfg)
    out = out.reshape(E, G, C, cfg.d_model)
    out = shard(out, "expert", "groups", None, None)
    # 'dTEC,EdCM->dTM' == 'gtec,egcm->gtm'
    y = jnp.einsum("gtec,egcm->gtm", combine.astype(dt), out)
    return y


def _gather_path(params, xg, plan: RoutingPlan, cfg: ModelConfig) -> jax.Array:
    """Index-view dispatch: scatter tokens into flat expert buffers by slot id.

    Each token-choice (g, t, j) owns slot ``e*C + c`` of group g's flat
    buffer; overflowed choices are parked on a sentinel row that is
    sliced off.  The same slot ids drive the gather-back, so the dense
    (G,T,E,C) one-hot tensors are never built.  Same (E,C) buffer layout
    and capacity semantics as the einsum path, so outputs match (up to
    reduction order).  Branch-free in T.

    Plans carrying the slot-major view (expert-choice: K would be E) are
    dispatched from it instead: gather-by-slot in, scatter-add-by-token
    out — O(E*C*M) token movement either way.
    """
    if plan.token_at_slot is not None:
        return _gather_path_slot_major(params, xg, plan, cfg)
    dt = cfg.activation_dtype
    G, T, K = plan.expert_index.shape
    E, C = plan.num_experts, plan.capacity
    M = xg.shape[-1]
    n_slots = E * C

    flat_slot = plan.expert_index * C + plan.slot_index        # (G,T,K)
    flat_slot = jnp.where(plan.valid, flat_slot, n_slots)      # sentinel row
    flat_slot = flat_slot.reshape(G, T * K)

    # dispatch: scatter each choice's token vector into its slot.  Valid
    # (e, c) targets are unique, so `add` places exactly one token per slot.
    gi = jnp.arange(G)[:, None]
    tok = jnp.repeat(jnp.arange(T), K)                         # (T*K,)
    buf = jnp.zeros((G, n_slots + 1, M), dt)
    buf = buf.at[gi, flat_slot].add(xg[:, tok, :].astype(dt))
    buf = buf[:, :n_slots].reshape(G, E, C, M)

    buf = jnp.transpose(buf, (1, 0, 2, 3))                     # (E,G,C,M)
    buf = shard(buf, "expert", "groups", None, None)
    out = _expert_ffn(params, buf.reshape(E, G * C, M), cfg).reshape(E, G, C, M)
    out = shard(out, "expert", "groups", None, None)
    out = jnp.transpose(out, (1, 0, 2, 3)).reshape(G, n_slots, M)

    # combine: gather each choice's slot back and weight by its gate.
    # Invalid choices carry gate 0, so clipping their slot is harmless.
    picked = jnp.take_along_axis(
        out, jnp.minimum(flat_slot, n_slots - 1)[..., None], axis=1)
    gates = plan.masked_gate.astype(dt).reshape(G, T * K)
    y = jnp.sum((picked * gates[..., None]).reshape(G, T, K, M), axis=2)
    return y


def _gather_path_slot_major(params, xg, plan: RoutingPlan, cfg: ModelConfig) -> jax.Array:
    """Slot-major twin of :func:`_gather_path`: each (expert, slot) names
    its token directly, so dispatch is a gather and combine a scatter-add
    over tokens.  Empty slots (token -1) carry gate 0 and zeroed rows."""
    dt = cfg.activation_dtype
    G, T, M = xg.shape
    E = plan.num_experts
    Cs = plan.token_at_slot.shape[-1]

    tok = plan.token_at_slot                                   # (G,E,Cs)
    filled = tok >= 0
    tok_safe = jnp.clip(tok, 0, T - 1).reshape(G, E * Cs, 1)
    buf = jnp.take_along_axis(xg, tok_safe, axis=1).reshape(G, E, Cs, M)
    buf = jnp.where(filled[..., None], buf, 0.0).astype(dt)

    buf = jnp.transpose(buf, (1, 0, 2, 3))                     # (E,G,Cs,M)
    buf = shard(buf, "expert", "groups", None, None)
    out = _expert_ffn(params, buf.reshape(E, G * Cs, M), cfg).reshape(E, G, Cs, M)
    out = shard(out, "expert", "groups", None, None)
    out = jnp.transpose(out, (1, 0, 2, 3))                     # (G,E,Cs,M)

    gates = jnp.where(filled, plan.gate_at_slot, 0.0).astype(dt)
    vals = (out * gates[..., None]).reshape(G, E * Cs, M)
    gi = jnp.arange(G)[:, None]
    y = jnp.zeros((G, T, M), dt).at[gi, tok_safe[..., 0]].add(vals)
    return y


def moe_ffn_apply(params, x, cfg: ModelConfig) -> Tuple[jax.Array, dict]:
    """x: (B, S, M) -> (y, aux) where aux carries losses + load metrics."""
    m = cfg.moe
    B, S, M = x.shape
    xg, G = group_tokens(x, m)
    T = xg.shape[1]
    capacity = m.capacity(T)
    xg = shard(xg, "groups", None, None)

    router_w = params.get("router")
    if router_w is not None:
        router_w = router_w.astype(jnp.float32)
    plan = route(xg, router_w, m, capacity)

    if m.impl in ("gather", "pallas"):   # index-view dispatch (+ kernel FFN)
        y = _gather_path(params, xg, plan, cfg)
    else:                                # "einsum": paper-faithful dense view
        y = _einsum_path(params, xg, plan, cfg)

    y = y.reshape(B, S, M).astype(x.dtype)
    aux = {
        "moe_aux_loss": plan.aux_loss,
        "moe_z_loss": plan.z_loss,
        "moe_cv": plan.metrics["cv"],
        "moe_dropped_fraction": plan.metrics["dropped_fraction"],
    }
    return y, aux
