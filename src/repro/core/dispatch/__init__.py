"""Pluggable MoE execution backends (dispatchers).

``MoEConfig.impl`` is a key into this registry — the execution twin of
the routing registry in :mod:`repro.core.routers`.  A *router* decides
which expert gets which token (the ``RoutingPlan``); a *dispatcher*
decides how that plan is executed on the hardware: how tokens move into
per-expert buffers, where the grouped FFN runs, and which collectives
carry expert parallelism.  Built-ins:

* ``einsum``   — paper-faithful GShard one-hot einsum dispatch/combine
  (materialises the plan's dense ``(G,T,E,C)`` view; expert parallelism
  is implicit via ``with_sharding_constraint`` + GSPMD);
* ``gather``   — index-view dispatch: flat slot-id scatter/gather, no
  dense tensor ever built (implicit parallelism, as above);
* ``pallas``   — the gather dispatch feeding the Pallas grouped-GEMM
  expert-FFN kernel (``repro.kernels.moe_ffn``);
* ``alltoall`` — explicit expert parallelism: ``shard_map`` over the
  mesh's expert axis with ``jax.lax.all_to_all`` dispatch/return
  collectives and a per-shard grouped FFN (Fig. 7 at 480-GPU scale, the
  Switch-Transformer execution model);
* ``dropless`` — capacity-free execution: tokens sorted by expert id
  into the plan's ragged view and run through a blocked grouped GEMM
  (Pallas scalar-prefetch kernel on TPU) — no ``(E, C)`` buffers, no
  dropped tokens under ``capacity_factor=None`` (which requires a
  backend with ``supports_dropless = True``, enforced by MoEConfig).

Adding a backend is a small plugin::

    from repro.core.dispatch import register_dispatcher

    @register_dispatcher
    class MyDispatcher:
        name = "mine"
        def __call__(self, params, xg, plan, cfg, ctx=None): ...

Registration must happen before a ``MoEConfig(impl="mine")`` is
constructed (config validation consults this registry).
"""
from __future__ import annotations

from typing import Dict, Tuple, Type

from repro.core.dispatch.base import Dispatcher, expert_ffn  # noqa: F401

_REGISTRY: Dict[str, Dispatcher] = {}


def register_dispatcher(cls: Type) -> Type:
    """Class decorator: instantiate and register a Dispatcher under cls.name."""
    name = getattr(cls, "name", None)
    if not name or not isinstance(name, str):
        raise ValueError(f"dispatcher class {cls!r} needs a string `name` attribute")
    _REGISTRY[name] = cls()
    return cls


def get_dispatcher(name: str) -> Dispatcher:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown moe impl {name!r}; registered dispatchers: "
            f"{', '.join(available_dispatchers())}"
        ) from None


def available_dispatchers() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# Built-ins self-register on import.
from repro.core.dispatch import (  # noqa: E402,F401
    alltoall,
    dropless,
    einsum,
    gather,
    pallas,
)

__all__ = [
    "Dispatcher", "expert_ffn", "register_dispatcher", "get_dispatcher",
    "available_dispatchers",
]
