"""Explicit expert-parallel dispatch: ``shard_map`` + ``lax.all_to_all``.

The ``einsum``/``gather`` dispatchers get expert parallelism *implicitly*
— they annotate buffers with ``with_sharding_constraint`` and trust GSPMD
to insert the Fig. 7 all-to-alls.  This backend writes the Switch
Transformer / GShard execution model down explicitly, the form that
carries trillion-parameter scale (paper Fig. 7: 1T params on 480 GPUs):

1. tokens (groups) are sharded over *every* mesh device — the data axes
   and the expert axis jointly — so each device routes only ``G/(Nd*Ne)``
   local groups;
2. each device scatters its local tokens into a full ``(E, rows, M)``
   buffer by the plan's flat slot ids (index view only — the dense
   ``(G,T,E,C)`` tensor is never built, structurally asserted in tests);
3. ``jax.lax.all_to_all`` over the expert mesh axis exchanges buffer
   slices: afterwards each device holds *its* ``E/Ne`` experts' rows from
   every peer;
4. the grouped FFN runs on the local expert shard of the weights;
5. a second ``all_to_all`` returns the rows, and each device combines its
   local tokens by gate-weighted gather (token-choice) or scatter-add
   (slot-major plans).

Because the :class:`RoutingPlan` is computed once outside the dispatcher,
per-group capacity semantics are *identical* to every other backend —
the collective schedule changes, the assignment does not — which is what
makes the cross-dispatcher equivalence tests exact.

When no expert-sharded mesh is active (no ``Rules`` context, experts not
divisible over the mesh axis, or a degenerate 1-way expert axis), the
backend degrades to the ``gather`` dispatch so the same config runs
unchanged on a laptop.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.context import MoEContext
from repro.core.dispatch import register_dispatcher
from repro.core.dispatch.base import expert_ffn
from repro.core.dispatch.gather import flat_slot_ids, gather_dispatch
from repro.core.routers.base import RoutingPlan
from repro.distributed.sharding import active_rules


def _expert_mesh_plan(plan: RoutingPlan, G: int) -> Optional[Tuple]:
    """(mesh, expert_axis, group_axes) when explicit EP can run, else None."""
    rules = active_rules()
    if rules is None:
        return None
    e_ax = rules.params.get("expert")
    if e_ax is None or isinstance(e_ax, tuple):
        return None  # unsharded experts (or multi-axis EP: not supported)
    mesh = rules.mesh
    ne = mesh.shape[e_ax]
    if ne <= 1 or plan.num_experts % ne != 0:
        return None
    dp = rules.acts.get("groups")
    dp_axes = () if dp is None else (dp if isinstance(dp, tuple) else (dp,))
    dp_axes = tuple(a for a in dp_axes if a != e_ax)
    nd = math.prod(mesh.shape[a] for a in dp_axes)
    if G % (nd * ne) != 0:
        return None  # tokens can't split across the joint device grid
    return mesh, e_ax, dp_axes


def alltoall_dispatch(params, xg: jax.Array, plan: RoutingPlan,
                      cfg: ModelConfig) -> jax.Array:
    placed = _expert_mesh_plan(plan, xg.shape[0])
    if placed is None:
        return gather_dispatch(params, xg, plan, cfg)
    mesh, e_ax, dp_axes = placed
    joint = (*dp_axes, e_ax)          # group axis sharded over ALL devices
    ne = mesh.shape[e_ax]
    dt = cfg.activation_dtype
    E, C = plan.num_experts, plan.capacity
    M = xg.shape[-1]

    p_names = [k for k in ("up", "gate", "down") if k in params]
    p_local = {k: params[k] for k in p_names}
    w_spec = {k: P(e_ax) for k in p_names}  # expert dim sharded, rest replicated
    grp = P(joint)

    if plan.token_at_slot is not None:
        # Slot-major plans (expert-choice): dispatch is a gather by
        # token_at_slot, combine a scatter-add over tokens.
        Cs = plan.token_at_slot.shape[-1]

        def run(p, xl, tok, gate):
            Gl, T, _ = xl.shape
            filled = tok >= 0                                  # (Gl,E,Cs)
            tok_safe = jnp.clip(tok, 0, T - 1).reshape(Gl, E * Cs, 1)
            buf = jnp.take_along_axis(xl, tok_safe, axis=1).reshape(Gl, E, Cs, M)
            buf = jnp.where(filled[..., None], buf, 0.0).astype(dt)
            buf = jnp.transpose(buf, (1, 0, 2, 3)).reshape(E, Gl * Cs, M)
            out = _exchange_ffn(p, buf)
            out = out.reshape(E, Gl, Cs, M).transpose(1, 0, 2, 3)  # (Gl,E,Cs,M)
            g = jnp.where(filled, gate, 0.0).astype(dt)
            vals = (out * g[..., None]).reshape(Gl, E * Cs, M)
            gi = jnp.arange(Gl)[:, None]
            return jnp.zeros((Gl, T, M), dt).at[gi, tok_safe[..., 0]].add(vals)

        args = (p_local, xg, plan.token_at_slot, plan.gate_at_slot)
        specs = (w_spec, grp, grp, grp)
    else:

        def run(p, xl, flat_slot, gates):
            Gl = xl.shape[0]
            T = xl.shape[1]
            K = flat_slot.shape[1] // T
            n_slots = E * C
            gi = jnp.arange(Gl)[:, None]
            tok = jnp.repeat(jnp.arange(T), K)
            buf = jnp.zeros((Gl, n_slots + 1, M), dt)
            buf = buf.at[gi, flat_slot].add(xl[:, tok, :].astype(dt))
            buf = buf[:, :n_slots].reshape(Gl, E, C, M)
            buf = jnp.transpose(buf, (1, 0, 2, 3)).reshape(E, Gl * C, M)
            out = _exchange_ffn(p, buf)
            out = out.reshape(E, Gl, C, M).transpose(1, 0, 2, 3)
            out = out.reshape(Gl, n_slots, M)
            picked = jnp.take_along_axis(
                out, jnp.minimum(flat_slot, n_slots - 1)[..., None], axis=1)
            y = (picked * gates.astype(dt)[..., None]).reshape(Gl, T, K, M)
            return jnp.sum(y, axis=2)

        G, T, K = plan.expert_index.shape
        args = (p_local, xg, flat_slot_ids(plan),
                plan.masked_gate.reshape(G, T * K))
        specs = (w_spec, grp, grp, grp)

    def _exchange_ffn(p, buf):
        """(E, rows, M) local buffer -> all_to_all -> local-expert FFN ->
        all_to_all back.  rows-per-expert grows x ne in between (each peer
        contributes its shard of the tokens)."""
        recv = jax.lax.all_to_all(buf, e_ax, split_axis=0, concat_axis=1,
                                  tiled=True)                  # (E/ne, ne*rows, M)
        out = expert_ffn(p, recv, cfg)
        return jax.lax.all_to_all(out, e_ax, split_axis=1, concat_axis=0,
                                  tiled=True)                  # (E, rows, M)

    return shard_map(run, mesh=mesh, in_specs=specs, out_specs=grp,
                     check_rep=False)(*args)


@register_dispatcher
class AllToAllDispatcher:
    name = "alltoall"
    # Dropless plans run the sorted-ragged machinery: explicit EP via the
    # padded variable-size all_to_all over the RaggedView when an
    # expert-sharded mesh is active, the GSPMD dropless path otherwise —
    # never the (E, C)-buffered exchange above, and never gather.
    supports_dropless = True

    def __call__(self, params, xg, plan: RoutingPlan, cfg: ModelConfig,
                 ctx: Optional[MoEContext] = None) -> jax.Array:
        if cfg.moe.dropless:
            from repro.core.dispatch.dropless import (
                dropless_dispatch,
                plan_block_rows,
            )

            return dropless_dispatch(params, xg, plan, cfg,
                                     block_rows=plan_block_rows(plan))
        return alltoall_dispatch(params, xg, plan, cfg)
