"""The ``Dispatcher`` protocol and the shared grouped expert FFN.

A dispatcher executes a :class:`~repro.core.routers.base.RoutingPlan`:
it moves tokens into per-expert buffers, runs each expert's FFN, and
combines the gate-weighted results back into token order.  Dispatchers
never make routing decisions — the plan is computed once (outside the
dispatcher, by the router registry) so that every backend executes the
*same* assignment and backends are numerically interchangeable, which
the test-suite asserts forward and backward for every router.

The contract:

* input ``xg`` is the grouped token array ``(G, T, M)``;
* the return value is ``(G, T, M)`` in ``cfg.activation_dtype`` domain;
* capacity-dropped tokens contribute exactly zero rows (the residual
  connection in the block then passes them through);
* index-view dispatchers must never materialise the dense ``(G,T,E,C)``
  combine/dispatch tensors (structurally asserted by walking jaxprs in
  ``tests/test_dispatch.py``).

Dispatchers receive the :class:`~repro.core.context.MoEContext` (already
regrouped to ``(G, T)``) so execution strategies can use step / PRNG /
token identity if they need to; all built-ins ignore it today.
"""
from __future__ import annotations

from typing import Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.context import MoEContext
from repro.core.routers.base import RoutingPlan


@runtime_checkable
class Dispatcher(Protocol):
    """An MoE execution backend, selected by ``MoEConfig.impl``.

    Backends that never allocate per-expert ``(E, C)`` capacity buffers
    may additionally declare ``supports_dropless = True``;
    ``MoEConfig.__post_init__`` only accepts ``capacity_factor=None``
    (dropless routing, capacity effectively infinite) for such backends.
    """

    name: str

    def __call__(self, params, xg: jax.Array, plan: RoutingPlan,
                 cfg: ModelConfig, ctx: Optional[MoEContext] = None) -> jax.Array:
        """params: MoE layer params; xg: (G, T, M) -> (G, T, M)."""
        ...


def expert_ffn(params, dispatched: jax.Array, cfg: ModelConfig,
               use_kernel: bool = False) -> jax.Array:
    """dispatched: (E, X, M) -> (E, X, M) through each expert's FFN.

    ``use_kernel`` selects the Pallas grouped-GEMM kernel (the compute
    hot-spot: the paper's appendix attributes ~98% of MoE-layer forward
    FLOPs to the two expert matmuls); the default is the pure-jnp einsum
    form, which also serves as the kernel's reference/backward.
    """
    dt = cfg.activation_dtype
    up_w = params["up"].astype(dt)
    down_w = params["down"].astype(dt)
    if use_kernel:
        from repro.kernels.moe_ffn import ops as moe_ops

        gate_w = params["gate"].astype(dt) if "gate" in params else None
        return moe_ops.moe_ffn(dispatched, up_w, gate_w, down_w, cfg.ffn_activation)
    h = jnp.einsum("exm,emi->exi", dispatched, up_w)
    if "gate" in params:
        g = jnp.einsum("exm,emi->exi", dispatched, params["gate"].astype(dt))
        h = jax.nn.silu(g) * h if cfg.ffn_activation == "swiglu" else jax.nn.gelu(g) * h
    elif cfg.ffn_activation == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.relu(h)
    return jnp.einsum("exi,eim->exm", h, down_w)
