"""Capacity-free dropless dispatch: sorted ragged grouped GEMM.

Capacity-ful backends allocate an ``(E, C)`` slot buffer per group and
*drop* whatever overflows it — the paper's central quality/efficiency
lever, and the reason capacity-factor tuning exists at all.  This
backend removes the capacity dimension instead (MegaBlocks-style):

1. take the plan's :class:`~repro.core.routers.base.RaggedView` — valid
   choices sorted by expert id, each expert's segment padded to a
   multiple of ``block_rows`` so a row block never straddles experts;
2. gather the sorted token rows (``O(R*M)`` movement, R = valid choices
   + block padding — proportional to actual load, no ``gamma`` slack and
   no ``(G, T, E, C)`` intermediate anywhere);
3. run the expert FFN as a ragged/blocked grouped GEMM
   (``repro.kernels.moe_dropless``: Pallas scalar-prefetch kernel on
   TPU, sorted-gather reference elsewhere; ``custom_vjp`` so it trains);
4. combine by gate-weighted scatter-add back into token order.

With ``capacity_factor=None`` every routed choice is valid, so the
execution quality is exactly the capacity-infinity limit of the router.
With a finite capacity the plan's overflowed choices carry gate 0 and
empty rows, so outputs (including which tokens drop) match the einsum
reference bit-for-bit in assignment — the cross-backend contract holds.

Expert parallelism is implicit (GSPMD over the sharded group axis, like
``gather``); the sorted layout intentionally keeps experts' weights
replicated-or-sharded by the same rules as every other backend.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.context import MoEContext
from repro.core.dispatch import register_dispatcher
from repro.core.routers.base import RoutingPlan
from repro.distributed.sharding import shard
from repro.kernels.moe_dropless import ops as dropless_ops
from repro.kernels.moe_dropless.ops import pick_block_rows


def plan_block_rows(plan: RoutingPlan, max_block: int = 128) -> int:
    """Row-block granularity for a plan's ragged view: scales down with
    the choice count so segment padding never dwarfs real rows (a decode
    step routes a handful of choices; a training group routes thousands)."""
    if plan.token_at_slot is not None:
        n = plan.token_at_slot.shape[1] * plan.token_at_slot.shape[2]
    else:
        n = plan.expert_index.shape[1] * plan.expert_index.shape[2]
    return pick_block_rows(n, plan.num_experts, max_block)


def dropless_dispatch(params, xg: jax.Array, plan: RoutingPlan,
                      cfg: ModelConfig, block_rows: int = 0) -> jax.Array:
    dt = cfg.activation_dtype
    G, T, M = xg.shape
    block_rows = block_rows or plan_block_rows(plan)
    rag = plan.ragged(block_rows)
    R = rag.token.shape[1]

    tok = jnp.maximum(rag.token, 0)                      # (G, R); -1 -> row 0
    xs = jnp.take_along_axis(xg, tok[..., None], axis=1).astype(dt)
    xs = shard(xs, "groups", None, None)

    out = dropless_ops.ragged_ffn(
        xs.reshape(G * R, M), rag.block_expert.reshape(-1),
        params["up"].astype(dt),
        params["gate"].astype(dt) if "gate" in params else None,
        params["down"].astype(dt), cfg.ffn_activation, block_x=block_rows)
    out = out.reshape(G, R, M)

    # Empty rows (padding / capacity-dropped choices) carry gate 0, so
    # their garbage outputs vanish in the scatter-add combine.
    vals = out * rag.gate[..., None].astype(dt)
    gi = jnp.arange(G)[:, None]
    return jnp.zeros((G, T, M), dt).at[gi, tok].add(vals)


@register_dispatcher
class DroplessDispatcher:
    name = "dropless"
    supports_dropless = True          # consulted by MoEConfig.__post_init__
    max_block_rows = 128              # ceiling for the adaptive block size

    def __call__(self, params, xg, plan: RoutingPlan, cfg: ModelConfig,
                 ctx: Optional[MoEContext] = None) -> jax.Array:
        return dropless_dispatch(
            params, xg, plan, cfg,
            block_rows=plan_block_rows(plan, self.max_block_rows))
