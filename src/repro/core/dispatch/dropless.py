"""Capacity-free dropless dispatch: sorted ragged grouped GEMM.

Capacity-ful backends allocate an ``(E, C)`` slot buffer per group and
*drop* whatever overflows it — the paper's central quality/efficiency
lever, and the reason capacity-factor tuning exists at all.  This
backend removes the capacity dimension instead (MegaBlocks-style):

1. take the plan's :class:`~repro.core.routers.base.RaggedView` — valid
   choices sorted by expert id, each expert's segment padded to a
   multiple of ``block_rows`` so a row block never straddles experts;
2. gather the sorted token rows (``O(R*M)`` movement, R = valid choices
   + block padding — proportional to actual load, no ``gamma`` slack and
   no ``(G, T, E, C)`` intermediate anywhere);
3. run the expert FFN as a ragged/blocked grouped GEMM
   (``repro.kernels.moe_dropless``: Pallas scalar-prefetch kernel on
   TPU, sorted-gather reference elsewhere; ``custom_vjp`` so it trains);
4. combine by gate-weighted scatter-add back into token order.

With ``capacity_factor=None`` every routed choice is valid, so the
execution quality is exactly the capacity-infinity limit of the router.
With a finite capacity the plan's overflowed choices carry gate 0 and
empty rows, so outputs (including which tokens drop) match the einsum
reference bit-for-bit in assignment — the cross-backend contract holds.

Expert parallelism: under an expert-sharded ``Rules`` mesh (same
placement test as the ``alltoall`` backend), :func:`ragged_ep_dispatch`
runs *explicit* EP — a padded variable-size ``lax.all_to_all`` over the
ragged layout ships each expert shard exactly its own experts' sorted
row segments (``jax.lax.ragged_all_to_all`` would drop the padding once
available; the exchange is already O(load), never O(E*C)).  Without
such a mesh, parallelism stays implicit (GSPMD over the sharded group
axis, like ``gather``), weights replicated-or-sharded by the same rules
as every other backend.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.context import MoEContext
from repro.core.dispatch import register_dispatcher
from repro.core.dispatch.alltoall import _expert_mesh_plan
from repro.core.routers.base import RoutingPlan
from repro.distributed.sharding import shard
from repro.kernels.moe_dropless import ops as dropless_ops
from repro.kernels.moe_dropless.ops import pick_block_rows


def plan_block_rows(plan: RoutingPlan, max_block: int = 128) -> int:
    """Row-block granularity for a plan's ragged view: scales down with
    the choice count so segment padding never dwarfs real rows (a decode
    step routes a handful of choices; a training group routes thousands)."""
    if plan.token_at_slot is not None:
        n = plan.token_at_slot.shape[1] * plan.token_at_slot.shape[2]
    else:
        n = plan.expert_index.shape[1] * plan.expert_index.shape[2]
    return pick_block_rows(n, plan.num_experts, max_block)


def ragged_ep_dispatch(params, xg: jax.Array, plan: RoutingPlan,
                       cfg: ModelConfig, block_rows: int, placed) -> jax.Array:
    """Explicit expert parallelism over the ragged (sorted) layout.

    The :class:`~repro.core.routers.base.RaggedView` is expert-major with
    every segment boundary block-aligned, so the rows bound for expert
    shard ``s`` (local experts ``[s*E/ne, (s+1)*E/ne)``) form one
    contiguous block-aligned range ``[offsets[s*Epl], offsets[(s+1)*Epl])``
    per group.  Each device packs those ne ranges into a fixed ``(ne, R)``
    row budget (padding parked on a zero row — the variable-size
    all_to_all, per the ragged_all_to_all recipe on padded buffers), one
    ``lax.all_to_all`` ships them, the local-expert ragged FFN runs with
    *local* expert ids, and the reverse all_to_all + positional unpack
    restore the original layout for the usual gate-weighted scatter-add
    combine.

    Packing moves whole row *blocks* (segment starts and lengths are all
    multiples of ``block_rows``), so every FFN block holds exactly the
    rows it holds in the single-device layout — the grouped GEMM computes
    identical per-row results and the combine is bit-identical, which is
    what the mesh-parity serving tests assert end to end.
    """
    mesh, e_ax, dp_axes = placed
    ne = mesh.shape[e_ax]
    E = plan.num_experts
    epl = E // ne
    dt = cfg.activation_dtype
    G, T, M = xg.shape
    bx = block_rows
    rag = plan.ragged(bx)
    R = rag.token.shape[1]
    act = cfg.ffn_activation

    p_names = [k for k in ("up", "gate", "down") if k in params]
    p_local = {k: params[k] for k in p_names}
    w_spec = {k: P(e_ax) for k in p_names}
    grp = P((*dp_axes, e_ax))

    def run(p, xl, token, gate, offsets, bexp):
        Gl = xl.shape[0]
        toks = jnp.maximum(token, 0)                           # -1 -> row 0
        xs = jnp.take_along_axis(xl, toks[..., None], axis=1).astype(dt)
        e_row = jnp.repeat(bexp, bx, axis=1)                   # (Gl, R) global ids
        # destination boundaries: offsets at local-expert-count strides
        offd = offsets[:, ::epl]                               # (Gl, ne + 1)
        start, seglen = offd[:, :-1], offd[:, 1:] - offd[:, :-1]
        j = jnp.arange(R, dtype=offsets.dtype)
        src = start[:, :, None] + j                            # (Gl, ne, R)
        valid = j < seglen[:, :, None]
        srcp = jnp.where(valid, src, R)                        # park on pad row
        gi = jnp.arange(Gl)[:, None, None]
        xpad = jnp.concatenate([xs, jnp.zeros((Gl, 1, M), dt)], axis=1)
        buf = xpad[gi, srcp]                                   # (Gl, ne, R, M)
        epad = jnp.concatenate([e_row, jnp.zeros((Gl, 1), e_row.dtype)], axis=1)
        e_src = jnp.take_along_axis(
            epad, srcp.reshape(Gl, ne * R), axis=1).reshape(Gl, ne, R)
        dest = jnp.arange(ne, dtype=e_src.dtype)[None, :, None]
        ebuf = jnp.where(valid, e_src - dest * epl, 0)         # local expert ids
        # ship: leading axis = destination expert shard
        recv = jax.lax.all_to_all(jnp.swapaxes(buf, 0, 1), e_ax,
                                  split_axis=0, concat_axis=0, tiled=True)
        erecv = jax.lax.all_to_all(jnp.swapaxes(ebuf, 0, 1), e_ax,
                                   split_axis=0, concat_axis=0, tiled=True)
        out = dropless_ops.ragged_ffn(
            recv.reshape(ne * Gl * R, M),
            erecv.reshape(-1, bx)[:, 0].astype(jnp.int32),
            p["up"].astype(dt),
            p["gate"].astype(dt) if "gate" in p else None,
            p["down"].astype(dt), act, block_x=bx)
        back = jax.lax.all_to_all(out.reshape(ne, Gl, R, M), e_ax,
                                  split_axis=0, concat_axis=0, tiled=True)
        back = jnp.swapaxes(back, 0, 1)                        # (Gl, ne, R, M)
        back = jnp.where(valid[..., None], back, 0)
        res = jnp.zeros((Gl, R + 1, M), dt).at[gi, srcp].add(back)[:, :R]
        vals = res * gate[..., None].astype(dt)
        g2 = jnp.arange(Gl)[:, None]
        return jnp.zeros((Gl, T, M), dt).at[g2, toks].add(vals)

    args = (p_local, xg, rag.token, rag.gate, rag.expert_offsets,
            rag.block_expert)
    specs = (w_spec, grp, grp, grp, grp, grp)
    return shard_map(run, mesh=mesh, in_specs=specs, out_specs=grp,
                     check_rep=False)(*args)


def dropless_dispatch(params, xg: jax.Array, plan: RoutingPlan,
                      cfg: ModelConfig, block_rows: int = 0) -> jax.Array:
    dt = cfg.activation_dtype
    G, T, M = xg.shape
    block_rows = block_rows or plan_block_rows(plan)
    placed = _expert_mesh_plan(plan, G)
    if placed is not None:
        return ragged_ep_dispatch(params, xg, plan, cfg, block_rows, placed)
    rag = plan.ragged(block_rows)
    R = rag.token.shape[1]

    tok = jnp.maximum(rag.token, 0)                      # (G, R); -1 -> row 0
    xs = jnp.take_along_axis(xg, tok[..., None], axis=1).astype(dt)
    xs = shard(xs, "groups", None, None)

    out = dropless_ops.ragged_ffn(
        xs.reshape(G * R, M), rag.block_expert.reshape(-1),
        params["up"].astype(dt),
        params["gate"].astype(dt) if "gate" in params else None,
        params["down"].astype(dt), cfg.ffn_activation, block_x=block_rows)
    out = out.reshape(G, R, M)

    # Empty rows (padding / capacity-dropped choices) carry gate 0, so
    # their garbage outputs vanish in the scatter-add combine.
    vals = out * rag.gate[..., None].astype(dt)
    gi = jnp.arange(G)[:, None]
    return jnp.zeros((G, T, M), dt).at[gi, tok].add(vals)


@register_dispatcher
class DroplessDispatcher:
    name = "dropless"
    supports_dropless = True          # consulted by MoEConfig.__post_init__
    max_block_rows = 128              # ceiling for the adaptive block size

    def __call__(self, params, xg, plan: RoutingPlan, cfg: ModelConfig,
                 ctx: Optional[MoEContext] = None) -> jax.Array:
        return dropless_dispatch(
            params, xg, plan, cfg,
            block_rows=plan_block_rows(plan, self.max_block_rows))
