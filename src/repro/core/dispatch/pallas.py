"""Pallas execution backend: gather (index-view) dispatch feeding the
Pallas grouped-GEMM expert-FFN kernel (``repro.kernels.moe_ffn``).

Token movement is identical to the ``gather`` dispatcher; only the
expert-FFN compute hot-spot changes.  The kernel carries a
``custom_vjp`` (kernel forward, reference-einsum backward), so this
backend is trainable, not just a serving path.  On non-TPU backends the
kernel runs in interpret mode.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.configs.base import ModelConfig
from repro.core.context import MoEContext
from repro.core.dispatch import register_dispatcher
from repro.core.dispatch.gather import gather_dispatch
from repro.core.routers.base import RoutingPlan


@register_dispatcher
class PallasDispatcher:
    name = "pallas"

    def __call__(self, params, xg, plan: RoutingPlan, cfg: ModelConfig,
                 ctx: Optional[MoEContext] = None) -> jax.Array:
        return gather_dispatch(params, xg, plan, cfg, use_kernel=True)
