"""Paper-faithful Fig. 7 execution: one-hot einsum dispatch -> expert FFN
-> einsum combine, materialising the RoutingPlan's dense ``(G,T,E,C)``
view.  Under pjit the ``expert``-axis sharding constraints induce the
all-to-alls of Fig. 7 implicitly through GSPMD; the ``alltoall``
dispatcher is the explicit-collective twin.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.context import MoEContext
from repro.core.dispatch import register_dispatcher
from repro.core.dispatch.base import expert_ffn
from repro.core.routers.base import RoutingPlan
from repro.distributed.sharding import shard


def einsum_dispatch(params, xg: jax.Array, plan: RoutingPlan,
                    cfg: ModelConfig) -> jax.Array:
    dt = cfg.activation_dtype
    combine = plan.combine                                     # (G,T,E,C) dense view
    G, T, E, C = combine.shape
    dispatch = (combine > 0.0).astype(dt)
    # 'dTZFC,dTZM->ZFdCM' in the paper == 'gtec,gtm->egcm' with E=Z*F.
    dispatched = jnp.einsum("gtec,gtm->egcm", dispatch, xg)
    dispatched = shard(dispatched, "expert", "groups", None, None)
    out = expert_ffn(params, dispatched.reshape(E, G * C, cfg.d_model), cfg)
    out = out.reshape(E, G, C, cfg.d_model)
    out = shard(out, "expert", "groups", None, None)
    # 'dTEC,EdCM->dTM' == 'gtec,egcm->gtm'
    y = jnp.einsum("gtec,egcm->gtm", combine.astype(dt), out)
    return y


@register_dispatcher
class EinsumDispatcher:
    name = "einsum"

    def __call__(self, params, xg, plan: RoutingPlan, cfg: ModelConfig,
                 ctx: Optional[MoEContext] = None) -> jax.Array:
        return einsum_dispatch(params, xg, plan, cfg)
