"""Index-view dispatch: flat slot-id scatter/gather, no dense tensors.

Each token-choice ``(g, t, j)`` owns slot ``e*C + c`` of group g's flat
buffer; overflowed choices are parked on a sentinel row that is sliced
off.  The same slot ids drive the gather-back, so the dense ``(G,T,E,C)``
one-hot tensors are never built.  Same ``(E, C)`` buffer layout and
capacity semantics as the einsum path, so outputs match (up to reduction
order).  O(k*T*M) token movement instead of O(T*E*C*M); branch-free in T.

Plans carrying the slot-major view (expert-choice: K would be E) are
dispatched from it instead: gather-by-slot in, scatter-add-by-token out —
O(E*C*M) token movement either way.

The ``pallas`` dispatcher reuses this dispatch verbatim and swaps the
expert FFN for the Pallas grouped-GEMM kernel.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.context import MoEContext
from repro.core.dispatch import register_dispatcher
from repro.core.dispatch.base import expert_ffn
from repro.core.routers.base import RoutingPlan
from repro.distributed.sharding import shard


def flat_slot_ids(plan: RoutingPlan) -> jax.Array:
    """(G, T*K) flat slot id per choice; invalid choices -> sentinel E*C."""
    n_slots = plan.num_experts * plan.capacity
    flat = plan.expert_index * plan.capacity + plan.slot_index   # (G,T,K)
    flat = jnp.where(plan.valid, flat, n_slots)
    G, T, K = plan.expert_index.shape
    return flat.reshape(G, T * K)


def gather_dispatch(params, xg: jax.Array, plan: RoutingPlan,
                    cfg: ModelConfig, use_kernel: bool = False) -> jax.Array:
    if plan.token_at_slot is not None:
        return _slot_major_dispatch(params, xg, plan, cfg, use_kernel)
    dt = cfg.activation_dtype
    G, T, K = plan.expert_index.shape
    E, C = plan.num_experts, plan.capacity
    M = xg.shape[-1]
    n_slots = E * C

    flat_slot = flat_slot_ids(plan)                            # (G, T*K)

    # dispatch: scatter each choice's token vector into its slot.  Valid
    # (e, c) targets are unique, so `add` places exactly one token per slot.
    gi = jnp.arange(G)[:, None]
    tok = jnp.repeat(jnp.arange(T), K)                         # (T*K,)
    buf = jnp.zeros((G, n_slots + 1, M), dt)
    buf = buf.at[gi, flat_slot].add(xg[:, tok, :].astype(dt))
    buf = buf[:, :n_slots].reshape(G, E, C, M)

    buf = jnp.transpose(buf, (1, 0, 2, 3))                     # (E,G,C,M)
    buf = shard(buf, "expert", "groups", None, None)
    out = expert_ffn(params, buf.reshape(E, G * C, M), cfg, use_kernel)
    out = out.reshape(E, G, C, M)
    out = shard(out, "expert", "groups", None, None)
    out = jnp.transpose(out, (1, 0, 2, 3)).reshape(G, n_slots, M)

    # combine: gather each choice's slot back and weight by its gate.
    # Invalid choices carry gate 0, so clipping their slot is harmless.
    picked = jnp.take_along_axis(
        out, jnp.minimum(flat_slot, n_slots - 1)[..., None], axis=1)
    gates = plan.masked_gate.astype(dt).reshape(G, T * K)
    y = jnp.sum((picked * gates[..., None]).reshape(G, T, K, M), axis=2)
    return y


def _slot_major_dispatch(params, xg, plan: RoutingPlan, cfg: ModelConfig,
                         use_kernel: bool = False) -> jax.Array:
    """Slot-major twin of :func:`gather_dispatch`: each (expert, slot)
    names its token directly, so dispatch is a gather and combine a
    scatter-add over tokens.  Empty slots (token -1) carry gate 0 and
    zeroed rows."""
    dt = cfg.activation_dtype
    G, T, M = xg.shape
    E = plan.num_experts
    Cs = plan.token_at_slot.shape[-1]

    tok = plan.token_at_slot                                   # (G,E,Cs)
    filled = tok >= 0
    tok_safe = jnp.clip(tok, 0, T - 1).reshape(G, E * Cs, 1)
    buf = jnp.take_along_axis(xg, tok_safe, axis=1).reshape(G, E, Cs, M)
    buf = jnp.where(filled[..., None], buf, 0.0).astype(dt)

    buf = jnp.transpose(buf, (1, 0, 2, 3))                     # (E,G,Cs,M)
    buf = shard(buf, "expert", "groups", None, None)
    out = expert_ffn(params, buf.reshape(E, G * Cs, M), cfg, use_kernel)
    out = out.reshape(E, G, Cs, M)
    out = shard(out, "expert", "groups", None, None)
    out = jnp.transpose(out, (1, 0, 2, 3))                     # (G,E,Cs,M)

    gates = jnp.where(filled, plan.gate_at_slot, 0.0).astype(dt)
    vals = (out * gates[..., None]).reshape(G, E * Cs, M)
    gi = jnp.arange(G)[:, None]
    y = jnp.zeros((G, T, M), dt).at[gi, tok_safe[..., 0]].add(vals)
    return y


@register_dispatcher
class GatherDispatcher:
    name = "gather"

    def __call__(self, params, xg, plan: RoutingPlan, cfg: ModelConfig,
                 ctx: Optional[MoEContext] = None) -> jax.Array:
        return gather_dispatch(params, xg, plan, cfg, use_kernel=False)
