"""The ``MoEContext``: per-call information threaded to MoE layers.

Routers and dispatchers historically saw only hidden states — a bare
``(params, x, cfg)`` signature — which made whole families of strategies
inexpressible: true Hash-Layers routing needs *token identity*, stochastic
routing needs a PRNG key, curriculum/annealed routing needs the step, and
serving-time routing needs the absolute decode positions.  ``MoEContext``
carries exactly that side-channel, built once at the model entry point
(trainer / serving engine / family ``*_apply``) and threaded through
``block_apply`` into ``moe_ffn_apply``, the router registry, and the
dispatcher registry.

All fields are optional: ``MoEContext()`` is a valid "know nothing"
context, and every consumer must degrade gracefully (e.g. the ``hash``
router falls back to position hashing when ``token_ids`` is None).

The context is a registered pytree (``is_training`` is static metadata,
everything else is data), so it crosses ``jit`` boundaries and rides
through ``lax.scan`` closures without retracing games.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


@partial(jax.tree_util.register_dataclass,
         data_fields=("token_ids", "positions", "rng", "step"),
         meta_fields=("is_training",))
@dataclasses.dataclass(frozen=True)
class MoEContext:
    """Side-channel inputs for routing/dispatch decisions.

    ``token_ids``/``positions`` are ``(B, S)`` at the model level; inside
    the MoE layer they are regrouped to the router's ``(G, T)`` layout via
    :meth:`grouped` (the same reshape ``group_tokens`` applies to
    activations, so choice ``(g, t)`` lines up with token ``(g, t)``).
    ``token_ids`` entries < 0 mean "identity unknown" (e.g. image-patch
    prefix rows) and consumers must fall back per-token.
    """

    token_ids: Optional[jax.Array] = None   # (B, S) int32; -1 = no identity
    positions: Optional[jax.Array] = None   # (B, S) int32 absolute positions
    rng: Optional[jax.Array] = None         # PRNG key for stochastic routing
    step: Optional[jax.Array] = None        # training step (scalar)
    is_training: bool = False

    def replace(self, **kw) -> "MoEContext":
        return dataclasses.replace(self, **kw)

    def with_tokens(self, token_ids: Optional[jax.Array],
                    positions: Optional[jax.Array],
                    prefix_len: int = 0) -> "MoEContext":
        """Fill per-sequence arrays, padding ``prefix_len`` non-token rows
        (image patches / audio frames) with id -1 so shapes match x."""
        if token_ids is not None and prefix_len:
            pad = jnp.full((token_ids.shape[0], prefix_len), -1, token_ids.dtype)
            token_ids = jnp.concatenate([pad, token_ids], axis=1)
        return dataclasses.replace(self, token_ids=token_ids, positions=positions)

    def grouped(self, G: int, T: int) -> "MoEContext":
        """Reshape (B, S) fields to the router's (G, T) group layout."""
        def regroup(a):
            return None if a is None else a.reshape(G, T)

        return dataclasses.replace(
            self, token_ids=regroup(self.token_ids),
            positions=regroup(self.positions))
