"""Sharded, atomic, async checkpointing with elastic restore.

Layout:  <dir>/step_<n>/
            manifest.json        {step, leaves: [{path, shape, dtype}], complete}
            <leaf_000>.npy ...
Writes go to ``step_<n>.tmp`` then ``os.rename`` (atomic on POSIX) — a
crash mid-save never corrupts the latest checkpoint.  ``save_async``
snapshots to host memory synchronously (cheap) and writes on a thread.

Elastic restore: arrays are stored *unsharded* (each leaf fully
materialised); ``restore`` device_puts them under whatever shardings the
new mesh dictates — so a job can come back on a different topology
(the checkpoint-resharding test exercises 8 devices -> (2,4) vs (4,2)).
bfloat16 is handled via ml_dtypes (numpy round-trips it natively).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

_LEAF_RE = re.compile(r"step_(\d+)$")


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), leaf) for p, leaf in flat], treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree) -> str:
        """Synchronous atomic save."""
        host = jax.tree_util.tree_map(lambda a: np.asarray(jax.device_get(a)), tree)
        return self._write(step, host)

    def save_async(self, step: int, tree) -> None:
        """Snapshot now, write on a background thread."""
        self.wait()
        host = jax.tree_util.tree_map(lambda a: np.asarray(jax.device_get(a)), tree)
        self._thread = threading.Thread(target=self._write, args=(step, host), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree) -> str:
        final = os.path.join(self.directory, f"step_{step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat, _ = _flatten_with_paths(host_tree)
        leaves: List[Dict] = []
        for i, (path, leaf) in enumerate(flat):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), leaf, allow_pickle=False)
            leaves.append({"key": path, "file": fname,
                           "shape": list(leaf.shape), "dtype": str(leaf.dtype)})
        manifest = {"step": step, "leaves": leaves, "complete": True}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: max(len(steps) - self.keep, 0)]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"), ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            m = _LEAF_RE.search(name)
            if not m or name.endswith(".tmp"):
                continue
            if os.path.exists(os.path.join(self.directory, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _restore_tree(self, step: int, template, shardings, lookup):
        """Shared leaf loader: ``lookup(by_key, leaf_key)`` maps a
        template leaf key to its manifest entry (or None)."""
        path = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        assert manifest["complete"], f"checkpoint {path} incomplete"
        flat_t, _ = _flatten_with_paths(template)
        by_key = {l["key"]: l for l in manifest["leaves"]}
        leaves = []
        flat_s = None
        if shardings is not None:
            flat_s = [s for _, s in _flatten_with_paths(shardings)[0]]
        for i, (key, tmpl) in enumerate(flat_t):
            entry = lookup(by_key, key)
            if entry is None:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = np.load(os.path.join(path, entry["file"]), allow_pickle=False)
            if arr.dtype.kind == "V":  # bf16 etc. round-trip as raw void
                import ml_dtypes

                arr = arr.view(np.dtype(getattr(ml_dtypes, entry["dtype"], entry["dtype"])))
            expected = tuple(tmpl.shape)
            if tuple(arr.shape) != expected:
                raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {expected}")
            if flat_s is not None:
                leaves.append(jax.device_put(arr, flat_s[i]))
            else:
                leaves.append(jnp.asarray(arr))
        _, tdef = jax.tree_util.tree_flatten(template)
        return jax.tree_util.tree_unflatten(tdef, leaves)

    def restore(self, step: int, template, shardings=None):
        """Restore into the structure of ``template`` (a pytree of arrays
        or ShapeDtypeStructs).  ``shardings``: optional matching tree of
        Shardings for elastic placement on the current mesh."""
        return self._restore_tree(step, template, shardings,
                                  lambda by_key, key: by_key.get(key))

    def restore_latest(self, template, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return self.restore(step, template, shardings), step

    # -- params-only restore (serving) --------------------------------------

    def restore_params(self, step: int, params_template, shardings=None):
        """Restore only the ``params`` subtree of a ``TrainState``-layout
        checkpoint (or a bare-params checkpoint) into ``params_template``.

        Serving has no business rebuilding an optimizer just to obtain a
        restore template: this reads the leaves whose manifest keys are
        ``.params<leaf>`` (the :class:`~repro.train.state.TrainState`
        attribute path) — falling back to the bare leaf key so
        params-only checkpoints restore too — and never touches the
        optimizer/step leaves on disk.
        """
        return self._restore_tree(
            step, params_template, shardings,
            lambda by_key, key: by_key.get(".params" + key) or by_key.get(key))

    def restore_params_latest(self, params_template, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return self.restore_params(step, params_template, shardings), step
