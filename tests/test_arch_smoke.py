"""Per-architecture smoke tests (deliverable f): reduced config of each
assigned family, one forward + one train step on CPU, asserting output
shapes and no NaNs.  Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.data.pipeline import make_pipeline
from repro.models.layers import padded_vocab
from repro.models.registry import get_family
from repro.nn import count_params, init
from repro.optim import make_optimizer, warmup_constant
from repro.train.state import init_train_state
from repro.train.trainer import make_train_step

SEQ = 24


def _batch(cfg, batch=2, seq=SEQ):
    pipe = make_pipeline(cfg, batch, seq, seed=0)
    return {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}


@pytest.mark.parametrize("arch", ARCH_IDS + ["m6-base"])
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    fam = get_family(cfg)
    params = init(fam.specs(cfg), jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = jax.jit(lambda p, b: fam.forward(p, b, cfg))(params, batch)
    assert logits.shape == batch["labels"].shape + (padded_vocab(cfg.vocab_size),)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux["moe_aux_loss"]).any())


@pytest.mark.parametrize("arch", ARCH_IDS + ["m6-base"])
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    fam = get_family(cfg)
    tc = TrainConfig(optimizer="adamw", learning_rate=1e-3, warmup_steps=2)
    params = init(fam.specs(cfg), jax.random.PRNGKey(0))
    opt = make_optimizer(tc, warmup_constant(tc.learning_rate, tc.warmup_steps))
    state = init_train_state(params, opt, tc.grad_compression)
    step = jax.jit(make_train_step(cfg, tc, opt))
    state, metrics = step(state, _batch(cfg))
    assert float(metrics["loss"]) > 0 and not jnp.isnan(metrics["loss"])
    assert int(state.step) == 1


@pytest.mark.parametrize("arch", ARCH_IDS + ["m6-base"])
def test_decode_step(arch):
    cfg = get_smoke_config(arch)
    fam = get_family(cfg)
    params = init(fam.specs(cfg), jax.random.PRNGKey(0))
    B, max_len = 2, 16
    if cfg.family == "encdec":
        from repro.models import encdec as ED

        frames = jnp.zeros((B, 4, cfg.d_model))
        memory = ED.encode(params, frames, cfg)
        state = ED.init_state(params, memory, cfg, max_len)
    else:
        state = fam.init_state(cfg, B, max_len)
    toks = jnp.zeros((B, 1), jnp.int32)
    logits, new_state = jax.jit(lambda p, t, s: fam.decode(p, t, s, cfg))(
        params, toks, state)
    assert logits.shape == (B, 1, padded_vocab(cfg.vocab_size))
    assert not bool(jnp.isnan(logits).any())


def test_full_config_param_counts_match_published():
    """Spec-level (no allocation) param counts vs public figures."""
    expected = {
        "granite-moe-3b-a800m": (3.3e9, 0.05),
        "olmoe-1b-7b": (6.9e9, 0.05),
        "qwen3-8b": (8.2e9, 0.05),
        "qwen3-14b": (14.8e9, 0.05),
        "deepseek-7b": (6.9e9, 0.05),
        "qwen2.5-32b": (32.5e9, 0.05),
        "xlstm-125m": (0.125e9, 0.35),   # nominal; projection factors differ
        "pixtral-12b": (12.2e9, 0.05),
        "zamba2-7b": (7.1e9, 0.08),
    }
    for arch, (want, tol) in expected.items():
        cfg = get_config(arch)
        n = count_params(get_family(cfg).specs(cfg))
        assert abs(n - want) / want < tol, (arch, n, want)


def test_m6_table5_param_counts_exact():
    """The paper's Table 5: 1.4B / 10.8B / 103.2B / 1002.7B."""
    from repro.configs.registry import get_config as gc

    for arch, want in [("m6-base", 1.4e9), ("m6-10b", 10.8e9),
                       ("m6-100b", 103.2e9), ("m6-1t", 1002.7e9)]:
        cfg = gc(arch)
        n = count_params(get_family(cfg).specs(cfg))
        assert abs(n - want) / want < 0.015, (arch, n / 1e9)
