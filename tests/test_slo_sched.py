"""Deterministic goldens for the SLO scheduling subsystem
(repro.serving.slo): priority classes, admission-policy ordering,
KV swap-to-host, and preemption/restore token identity.

Covers, bottom-up:

* Request priority/deadline plumbing — string coercion, effective
  deadline derived from a per-token rate SLO;
* policy ordering — ``priority_strict`` (class, then arrival),
  ``edf`` (earliest effective deadline, deadline-less last), and
  graceful degradation to arrival order on plain traffic;
* ``cache_aware`` — a warm prompt (published prefix blocks) beats an
  earlier-arriving cold one;
* SwapManager — device→host→device roundtrip preserves pool contents
  bit-exactly, conservation (record/host-block bijection), double
  release and duplicate-uid detection, capacity refusal;
* prefix-cache swap-out/restore — published full blocks restore by
  re-bind (no host upload), only the partial tail uploads;
* engine level — preempt-then-restore generates token-identically to
  an un-preempted run (dense and dropless-hash MoE, prefix caching on
  and off), with invariants checked every step;
* the synthetic_priority trace family and per-class run() stats.
"""
import numpy as np
import pytest

import jax

from repro.configs.base import ModelConfig, ServeConfig, SLOConfig
from repro.serving.kv_cache import PagedKVCache
from repro.serving.prefix_cache import PrefixCachingKVCache
from repro.serving.request import Priority, Request, RequestState, Status
from repro.serving.scheduler import Scheduler, get_policy
from repro.serving.slo.swap import SwapManager
from repro.serving.trace import (
    load_trace,
    save_trace,
    slo_class_stats,
    synthetic_priority,
)


def _cfg():
    return ModelConfig(name="t", family="decoder_lm", num_layers=1,
                       d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                       vocab_size=64, dtype="float32")


def _paged(max_slots=2, bs=4, num_blocks=8, max_len=32):
    serve = ServeConfig(max_slots=max_slots, kv_block_size=bs,
                        max_len=max_len, num_blocks=num_blocks)
    return PagedKVCache(_cfg(), serve)


def _prefix(max_slots=4, bs=4, num_blocks=16, max_len=64):
    serve = ServeConfig(max_slots=max_slots, kv_block_size=bs,
                        max_len=max_len, num_blocks=num_blocks,
                        prefix_cache=True)
    return PrefixCachingKVCache(_cfg(), serve)


def _st(uid, arrival=0.0, priority=Priority.NORMAL, deadline=None, gen=4,
        prompt_len=4):
    r = Request(uid=uid, prompt=np.arange(prompt_len, dtype=np.int32),
                max_new_tokens=gen, arrival_ms=arrival, priority=priority,
                deadline_ms=deadline)
    return RequestState(r)


# ---------------------------------------------------------------------------
# Request: priority coercion, effective deadline
# ---------------------------------------------------------------------------

def test_priority_coercion_and_effective_deadline():
    p = np.arange(4, dtype=np.int32)
    assert Request(uid=0, prompt=p, max_new_tokens=4,
                   priority="high").priority is Priority.HIGH
    assert Request(uid=1, prompt=p, max_new_tokens=4,
                   priority=2).priority is Priority.LOW
    with pytest.raises(ValueError):
        Request(uid=2, prompt=p, max_new_tokens=4, priority="urgent")
    # explicit deadline wins; otherwise derived from the rate SLO
    r = Request(uid=3, prompt=p, max_new_tokens=10, arrival_ms=100.0,
                deadline_ms=500.0, slo_tokens_per_s=1000.0)
    assert r.effective_deadline_ms == 500.0
    r = Request(uid=4, prompt=p, max_new_tokens=10, arrival_ms=100.0,
                slo_tokens_per_s=1000.0)        # 10 tokens @ 1k tok/s = 10ms
    assert r.effective_deadline_ms == pytest.approx(110.0)
    assert Request(uid=5, prompt=p,
                   max_new_tokens=10).effective_deadline_ms is None


# ---------------------------------------------------------------------------
# Policy ordering goldens
# ---------------------------------------------------------------------------

def test_priority_strict_ordering():
    pol = get_policy("priority_strict")
    waiting = [_st(0, arrival=0.0, priority=Priority.NORMAL),
               _st(1, arrival=5.0, priority=Priority.HIGH),
               _st(2, arrival=3.0, priority=Priority.HIGH),
               _st(3, arrival=0.0, priority=Priority.LOW)]
    fits = lambda st: True
    # earliest-arriving HIGH first, regardless of queue position
    assert pol.pick(waiting, 10.0, fits) == 2
    # un-arrived requests are invisible
    waiting[2].request = Request(uid=2, prompt=waiting[2].request.prompt,
                                 max_new_tokens=4, arrival_ms=100.0,
                                 priority=Priority.HIGH)
    assert pol.pick(waiting, 10.0, fits) == 1
    # a HIGH that does not fit falls through to the next class
    assert pol.pick(waiting, 10.0,
                    lambda st: st.request.priority is not Priority.HIGH) == 0
    assert pol.pick(waiting, 10.0, lambda st: False) is None


def test_edf_ordering():
    pol = get_policy("edf")
    waiting = [_st(0, arrival=0.0, deadline=None),
               _st(1, arrival=2.0, deadline=500.0),
               _st(2, arrival=4.0, deadline=200.0)]
    fits = lambda st: True
    assert pol.pick(waiting, 10.0, fits) == 2      # earliest deadline
    # deadline-less requests sort last (+inf), arrival order among them
    waiting = [_st(0, arrival=5.0), _st(1, arrival=1.0),
               _st(2, arrival=3.0, deadline=9999.0)]
    assert pol.pick(waiting, 10.0, fits) == 2


def test_slo_policies_degrade_to_arrival_order():
    """Uniform priorities, no deadlines, no cache: every SLO policy
    reduces to fcfs, so plain traffic is unaffected."""
    waiting = [_st(0, arrival=3.0), _st(1, arrival=1.0), _st(2, arrival=2.0)]
    fits = lambda st: True
    for name in ("priority_strict", "edf", "cache_aware"):
        assert get_policy(name).pick(waiting, 10.0, fits) == 1, name


def test_cache_aware_prefers_warm_prompt():
    cache = _prefix()
    bs = cache.block_size
    # 3 full blocks + a 2-token tail (a fully block-aligned prompt would
    # be capped: at least one prompt row must run)
    warm_prompt = np.arange(14, dtype=np.int32)
    # publish the prompt's full blocks: cold prefill, commit, evict
    cache.allocate_slot(0, 20, prompt=warm_prompt)
    cache.ensure_capacity(0, warm_prompt.size)
    cache.commit(0, warm_prompt)
    cache.free_slot(0)
    assert cache.warm_prefix_tokens(warm_prompt) == (14 // bs) * bs
    assert cache.warm_prefix_tokens(warm_prompt + 1) == 0

    sched = Scheduler(max_slots=2, max_len=64, kv_cache=cache,
                      policy="cache_aware")
    cold = Request(uid=0, prompt=np.arange(14, dtype=np.int32) + 40,
                   max_new_tokens=4, arrival_ms=0.0)
    warm = Request(uid=1, prompt=warm_prompt, max_new_tokens=4,
                   arrival_ms=5.0)
    sched.add(cold)
    sched.add(warm)
    admitted = sched.admit(10.0)
    assert [st.request.uid for st in admitted] == [1, 0]
    assert admitted[0].cached_tokens == (14 // bs) * bs


# ---------------------------------------------------------------------------
# SwapManager: roundtrip golden + conservation
# ---------------------------------------------------------------------------

def test_swap_roundtrip_preserves_pool_contents():
    cache = _paged()
    cache.allocate_slot(0, total_len=12)
    cache.ensure_capacity(0, 10)                   # 3 blocks, last partial
    blocks = [int(b) for b in cache.block_table[0][:3]]
    rng = np.random.default_rng(0)
    k = rng.normal(size=np.asarray(cache.k_pool[:, blocks]).shape
                   ).astype(np.float32)
    v = rng.normal(size=k.shape).astype(np.float32)
    cache.k_pool = cache.k_pool.at[:, blocks].set(k)
    cache.v_pool = cache.v_pool.at[:, blocks].set(v)

    swap = SwapManager(cache, host_blocks=4)
    rec = cache.swap_out(0, swap, uid=7, total_len=12, context_len=10)
    # device side fully released, host side holds exactly the copies
    assert cache.allocator.free_count == cache.num_blocks
    assert cache.reserved_total == 0
    assert swap.used_host_blocks == 3
    assert rec.num_blocks == 3 and rec.skip == 0 and rec.context_len == 10
    swap.check_conservation()
    cache.check_conservation()

    assert cache.can_restore(rec)
    resume = cache.restore_slot(1, rec, swap)
    assert resume == 10
    new_blocks = [int(b) for b in cache.block_table[1][:3]]
    np.testing.assert_array_equal(np.asarray(cache.k_pool[:, new_blocks]), k)
    np.testing.assert_array_equal(np.asarray(cache.v_pool[:, new_blocks]), v)
    swap.release(rec)
    assert swap.used_host_blocks == 0
    cache.free_slot(1)
    cache.check_conservation()
    swap.check_conservation()


def test_swap_release_and_store_misuse_detected():
    cache = _paged()
    swap = SwapManager(cache, host_blocks=4)
    cache.allocate_slot(0, total_len=8)
    cache.ensure_capacity(0, 8)
    rec = cache.swap_out(0, swap, uid=1, total_len=8, context_len=8)
    swap.release(rec)
    with pytest.raises(RuntimeError):
        swap.release(rec)                          # stale record
    cache.allocate_slot(0, total_len=8)
    cache.ensure_capacity(0, 8)
    cache.swap_out(0, swap, uid=2, total_len=8, context_len=8)
    cache.allocate_slot(1, total_len=8)
    cache.ensure_capacity(1, 8)
    with pytest.raises(RuntimeError):
        # uid 2 already has a live record
        swap.store(cache, uid=2, total_len=8, context_len=8,
                   blocks=[int(b) for b in cache.block_table[1][:2]],
                   skip=0, hashes=[])


def test_swap_capacity_refusal():
    cache = _paged()
    swap = SwapManager(cache, host_blocks=2)
    assert swap.can_store(2)
    assert not swap.can_store(3)
    cache.allocate_slot(0, total_len=8)
    cache.ensure_capacity(0, 8)                    # 2 blocks
    assert cache.swap_footprint(0) == 2
    cache.swap_out(0, swap, uid=1, total_len=8, context_len=8)
    assert not swap.can_store(1)                   # pool exhausted


def test_prefix_swap_restores_full_blocks_by_rebind():
    """Published full blocks come back without touching their host
    copies; only the partial (unpublishable) tail uploads."""
    cache = _prefix()
    prompt = np.arange(10, dtype=np.int32)         # 2 full blocks + 2 tokens
    cache.allocate_slot(0, 16, prompt=prompt)
    cache.ensure_capacity(0, prompt.size)
    cache.commit(0, prompt)
    swap = SwapManager(cache)
    rec = cache.swap_out(0, swap, uid=3, total_len=16, context_len=10)
    assert rec.num_blocks == 3 and len(rec.hashes) == 2
    resume = cache.restore_slot(1, rec, swap)
    assert resume == 10
    assert swap.stats["restored_blocks"] == 1      # the partial tail only
    assert cache.stats["bound_blocks"] >= 2        # full blocks re-bound
    swap.release(rec)
    cache.free_slot(1)
    cache.check_conservation()
    swap.check_conservation()


# ---------------------------------------------------------------------------
# Engine level: preemption/restore token identity
# ---------------------------------------------------------------------------

def tiny_cfg(**kw) -> ModelConfig:
    base = dict(name="t", family="decoder_lm", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                max_seq_len=128, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def _params(cfg, seed=0):
    from repro.models.registry import get_family
    from repro.nn import init

    return init(get_family(cfg).specs(cfg), jax.random.PRNGKey(seed))


def _preempt_requests():
    """Two long LOW decodes that fill both slots, then a HIGH arrival
    that can only be admitted by evicting one of them."""
    reqs = [Request(uid=i, prompt=np.arange(6, dtype=np.int32) + 3 * i,
                    max_new_tokens=20, arrival_ms=0.0, priority=Priority.LOW)
            for i in range(2)]
    reqs.append(Request(uid=2, prompt=np.arange(5, dtype=np.int32) + 50,
                        max_new_tokens=4, arrival_ms=75.0,
                        priority=Priority.HIGH))
    return reqs


def _drive(eng, requests):
    """Deterministic engine loop: a fixed virtual clock (10ms per step)
    instead of run()'s wall clock, so which request is mid-decode when
    the HIGH arrival lands never depends on host speed."""
    for r in requests:
        eng.scheduler.add(r)
    done = {}
    clock = 0.0
    while eng.scheduler.has_work():
        nxt = eng.scheduler.next_arrival_ms()
        if not eng.scheduler.running and nxt is not None and nxt > clock:
            clock = nxt
        for st in eng.step(clock):
            done[st.request.uid] = list(st.generated)
        clock += 10.0
    return done


@pytest.mark.parametrize("moe", [False, True], ids=["dense", "dropless_hash"])
@pytest.mark.parametrize("prefix", [False, True], ids=["paged", "prefix"])
def test_preempt_restore_token_identity(moe, prefix):
    from repro.serving.continuous import ContinuousEngine

    cfg = tiny_cfg()
    if moe:
        cfg = cfg.replace_moe(impl="dropless", num_experts=4,
                              routing="hash", capacity_factor=None)
    params = _params(cfg)
    reqs = _preempt_requests()

    # reference: enough slots that nothing ever waits or gets evicted
    ref_serve = ServeConfig(max_slots=4, kv_block_size=4, prefill_chunk=8,
                            max_len=32)
    ref = ContinuousEngine(cfg, params, ref_serve, check_invariants=True)
    want = _drive(ref, [Request(uid=r.uid, prompt=r.prompt,
                                max_new_tokens=r.max_new_tokens)
                        for r in reqs])

    serve = ServeConfig(max_slots=2, kv_block_size=4, prefill_chunk=8,
                        max_len=32, num_blocks=16, prefix_cache=prefix,
                        sched_policy="priority_strict", slo=SLOConfig())
    eng = ContinuousEngine(cfg, params, serve, check_invariants=True)
    got = _drive(eng, reqs)

    assert got == want                     # greedy: preemption is invisible
    assert eng.scheduler.preemptions > 0
    assert eng.scheduler.swap.stats["swapped_blocks"] > 0
    assert (eng.scheduler.restore_tokens + eng.scheduler.recompute_tokens) > 0
    assert not eng.scheduler.swap.records  # every record released
    eng.scheduler.check_conservation()


def test_preemption_respects_cap_and_host_pool():
    """max_preemptions=0 turns every request into a non-victim, so the
    HIGH arrival simply waits — pre-SLO behaviour, not an error."""
    from repro.serving.continuous import ContinuousEngine

    cfg = tiny_cfg()
    params = _params(cfg)
    serve = ServeConfig(max_slots=2, kv_block_size=4, prefill_chunk=8,
                        max_len=32, num_blocks=16,
                        sched_policy="priority_strict",
                        slo=SLOConfig(max_preemptions=0))
    eng = ContinuousEngine(cfg, params, serve, check_invariants=True)
    got = _drive(eng, _preempt_requests())
    assert eng.scheduler.preemptions == 0
    assert sorted(got) == [0, 1, 2]
    assert len(got[2]) == 4


# ---------------------------------------------------------------------------
# synthetic_priority trace + per-class stats
# ---------------------------------------------------------------------------

def test_synthetic_priority_deterministic_and_typed():
    a = synthetic_priority(32, 128, seed=3, qps=20.0)
    b = synthetic_priority(32, 128, seed=3, qps=20.0)
    assert len(a) == 32
    for ra, rb in zip(a, b):
        assert ra.arrival_ms == rb.arrival_ms
        assert ra.priority is rb.priority
        assert ra.deadline_ms == rb.deadline_ms
        np.testing.assert_array_equal(ra.prompt, rb.prompt)
    assert {r.priority for r in a} == set(Priority)
    for r in a:                            # default budgets: LOW best-effort
        assert (r.deadline_ms is None) == (r.priority is Priority.LOW)
        if r.deadline_ms is not None:
            assert r.deadline_ms > r.arrival_ms
    c = synthetic_priority(32, 128, seed=4, qps=20.0)
    assert any(ra.arrival_ms != rc.arrival_ms for ra, rc in zip(a, c))


def test_priority_trace_roundtrip(tmp_path):
    reqs = synthetic_priority(16, 64, seed=1)
    path = str(tmp_path / "trace.jsonl")
    save_trace(path, reqs)
    back = load_trace(path, 64, seed=1)
    by_uid = {r.arrival_ms: r for r in back}
    for r in reqs:
        rb = by_uid[r.arrival_ms]
        assert rb.priority is r.priority
        assert rb.deadline_ms == r.deadline_ms
        assert rb.prompt_len == r.prompt_len


def test_slo_class_stats_shape():
    p = np.arange(4, dtype=np.int32)
    # single class, no deadlines: plain traffic keeps the plain stats
    plain = []
    for uid in range(3):
        st = RequestState(Request(uid=uid, prompt=p, max_new_tokens=2))
        st.finished_ms = 50.0
        plain.append(st)
    assert slo_class_stats(plain) == {}

    mixed = []
    for uid, (pri, dl) in enumerate([(Priority.HIGH, 40.0),
                                     (Priority.HIGH, 200.0),
                                     (Priority.LOW, None)]):
        st = RequestState(Request(uid=uid, prompt=p, max_new_tokens=2,
                                  priority=pri, deadline_ms=dl))
        st.finished_ms = 100.0
        mixed.append(st)
    out = slo_class_stats(mixed)
    assert out["high_n"] == 2.0 and out["low_n"] == 1.0
    assert out["high_goodput"] == 0.5      # 100ms beat 200 but not 40
    assert out["goodput"] == 0.5
    assert "low_goodput" not in out        # best-effort class has no SLO
    assert all(isinstance(v, float) for v in out.values())


def test_run_reports_per_class_stats():
    from repro.serving.continuous import ContinuousEngine

    cfg = tiny_cfg()
    params = _params(cfg)
    reqs = synthetic_priority(10, cfg.vocab_size, seed=0, qps=500.0,
                              gen_lens=(4, 8), prompt_lens=(4, 12))
    serve = ServeConfig(max_slots=2, kv_block_size=4, prefill_chunk=8,
                        max_len=64, num_blocks=32,
                        sched_policy="priority_strict", slo=SLOConfig())
    eng = ContinuousEngine(cfg, params, serve, check_invariants=True)
    _, stats = eng.run(reqs)
    for key in ("preemptions", "restore_tokens", "recompute_tokens",
                "swapped_blocks", "restored_blocks", "goodput"):
        assert key in stats, key
    assert any(k.endswith("_p95_ms") for k in stats)
    assert all(isinstance(v, float) for v in stats.values())
