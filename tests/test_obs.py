"""Observability subsystem: span tracer, metrics registry, and their
integration with the continuous-batching engine (request-lifecycle
spans, registry-derived run() stats, recompile detector, MoE routing
telemetry)."""
import json

import numpy as np
import pytest

import jax

from repro.configs.base import ModelConfig, MoEConfig, ServeConfig
from repro.models.registry import get_family
from repro.nn import init
from repro.obs import Observability
from repro.obs.registry import MetricsRegistry
from repro.obs.tracer import SpanTracer
from repro.obs.validate import validate_chrome_trace, validate_metrics_jsonl
from repro.serving.continuous import ContinuousEngine
from repro.serving.request import Request
from repro.serving.trace import synthetic_trace


def tiny_cfg(**kw) -> ModelConfig:
    base = dict(name="t", family="decoder_lm", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                max_seq_len=128, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def build(cfg, seed=0):
    fam = get_family(cfg)
    return init(fam.specs(cfg), jax.random.PRNGKey(seed))


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_counter_monotonic_randomized():
    """Counters only ever move up, under a random op sequence; every
    negative inc / decreasing set_to raises and leaves the value alone."""
    rng = np.random.default_rng(0)
    reg = MetricsRegistry()
    total = 0.0
    for _ in range(300):
        op = rng.integers(0, 3)
        c = reg.counter("ops_total", kind=int(rng.integers(0, 3)))
        before = c.value
        if op == 0:
            v = float(rng.integers(0, 10))
            c.inc(v)
            assert c.value == before + v
            total += v
        elif op == 1:
            with pytest.raises(ValueError):
                c.inc(-float(rng.integers(1, 5)))
            assert c.value == before
        else:
            with pytest.raises(ValueError):
                c.set_to(before - 1.0)
            assert c.value == before
    assert reg.get("ops_total") == total  # unlabeled get sums label sets


def test_counter_set_to_mirrors_external_totals():
    reg = MetricsRegistry()
    c = reg.counter("cache_hits_total")
    c.set_to(5)
    c.set_to(5)        # no movement is fine
    c.set_to(9)
    assert reg.get("cache_hits_total") == 9
    with pytest.raises(ValueError):
        c.set_to(8)


def test_label_order_is_canonical():
    reg = MetricsRegistry()
    reg.counter("x_total", a=1, b=2).inc(3)
    reg.counter("x_total", b=2, a=1).inc(4)
    assert reg.get("x_total", a=1, b=2) == 7
    assert reg.get("x_total") == 7  # one series, not two


def test_gauge_set_and_set_max():
    reg = MetricsRegistry()
    g = reg.gauge("depth")
    g.set(4)
    g.set(2)
    assert reg.get("depth") == 2
    p = reg.gauge("peak")
    p.set_max(3)
    p.set_max(1)
    assert reg.get("peak") == 3


def test_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("n_total")
    with pytest.raises(TypeError):
        reg.gauge("n_total")


def test_histogram_accounting_and_prometheus():
    reg = MetricsRegistry()
    h = reg.histogram("lat_ms", buckets=(1.0, 10.0, 100.0))
    for v in (0.5, 3.0, 3.0, 50.0, 5000.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["lat_ms_count"] == 5
    assert snap["lat_ms_sum"] == pytest.approx(5056.5)
    assert snap["lat_ms_bucket{le=1.0}"] == 1       # per-bucket in snapshot
    assert snap["lat_ms_bucket{le=10.0}"] == 2
    text = reg.to_prometheus()
    assert "# TYPE lat_ms histogram" in text
    assert 'lat_ms_bucket{le="10.0"} 3' in text     # cumulative in prom text
    assert 'lat_ms_bucket{le="+Inf"} 5' in text
    assert "lat_ms_count 5" in text


def test_mark_delta_accounting():
    reg = MetricsRegistry()
    reg.counter("steps_total", kind="mixed").inc(2)
    mark = reg.mark()
    reg.counter("steps_total", kind="mixed").inc(3)
    reg.counter("steps_total", kind="decode").inc(5)
    assert reg.delta(mark, "steps_total") == 8
    assert reg.delta(mark, "steps_total", kind="mixed") == 3
    assert reg.delta(mark, "steps_total", kind="decode") == 5
    assert reg.delta(mark, "never_seen_total") == 0


def test_metrics_jsonl_row_is_schema_valid(tmp_path):
    reg = MetricsRegistry()
    reg.counter("a_total").inc(1)
    reg.gauge("b", shard=0).set(2.5)
    p = tmp_path / "m.jsonl"
    with open(p, "w") as fh:
        fh.write(reg.jsonl_row(step=1) + "\n")
        fh.write(reg.jsonl_row(final=True) + "\n")
    counts = validate_metrics_jsonl(str(p), require=("a_total", "b"))
    assert counts["rows"] == 2


# ---------------------------------------------------------------------------
# Span tracer
# ---------------------------------------------------------------------------

def test_tracer_disabled_is_noop():
    tr = SpanTracer(enabled=False)
    with tr.span("work") as sp:
        assert sp is None
    tr.begin("request", 1, "queued")
    tr.instant("preempt")
    assert tr.events() == []


def test_tracer_span_wellformed(tmp_path):
    tr = SpanTracer(enabled=True)
    with tr.span("engine_step", kind="mixed", step=0) as sp:
        sp.args["rows"] = 8
    tr.begin("request", 7, "request", prompt_len=3)
    tr.begin("request", 7, "queued")
    tr.end("request", 7, "queued")
    tr.begin("request", 7, "decode")
    tr.instant("preempt", uid=7)
    tr.end("request", 7, "decode")
    tr.end("request", 7, "request")
    evs = tr.events()
    x = [e for e in evs if e["ph"] == "X"]
    assert x[0]["name"] == "engine_step" and x[0]["args"]["rows"] == 8
    assert x[0]["dur"] >= 0
    # monotone timestamps, async ids stringified for Chrome-trace nesting
    ts = [e["ts"] for e in evs]
    assert ts == sorted(ts)
    assert all(e["id"] == "7" for e in evs if e["ph"] in ("b", "e"))
    p = tmp_path / "t.json"
    tr.write_chrome_trace(str(p))
    counts = validate_chrome_trace(str(p))
    assert counts == {"X": 1, "b": 3, "e": 3, "i": 1, "events": 8}


def test_tracer_ring_buffer_wrap():
    tr = SpanTracer(capacity=4, enabled=True)
    for i in range(10):
        tr.instant("tick", n=i)
    evs = tr.events()
    assert len(evs) == 4
    assert [e["args"]["n"] for e in evs] == [6, 7, 8, 9]  # oldest first
    assert tr.dropped_events == 6


def test_validator_rejects_unbalanced_async(tmp_path):
    tr = SpanTracer(enabled=True)
    tr.begin("request", 1, "request")
    p = tmp_path / "bad.json"
    tr.write_chrome_trace(str(p))
    with pytest.raises(ValueError):
        validate_chrome_trace(str(p))


def test_observability_request_lifecycle():
    obs = Observability(tracing=True)
    obs.request_arrived(3, prompt_len=5, max_new_tokens=4)
    obs.request_phase(3, "prefill", slot=0)
    obs.request_phase(3, "prefill")             # same phase: no-op
    obs.request_phase(3, "decode", slot=0)
    obs.request_phase(3, "preempted")
    obs.request_phase(3, "decode", slot=1)
    obs.request_finished(3)
    evs = obs.tracer.events()
    names = [(e["ph"], e["name"]) for e in evs]
    assert names == [("b", "request"), ("b", "queued"),
                     ("e", "queued"), ("b", "prefill"),
                     ("e", "prefill"), ("b", "decode"),
                     ("e", "decode"), ("b", "preempted"),
                     ("e", "preempted"), ("b", "decode"),
                     ("e", "decode"), ("e", "request")]
    # balanced per (cat, id): nothing left open
    depth = 0
    for e in evs:
        depth += {"b": 1, "e": -1}[e["ph"]]
        assert depth >= 0
    assert depth == 0


# ---------------------------------------------------------------------------
# Engine integration
# ---------------------------------------------------------------------------

def _run(cfg, serve, n=4, obs=None, seed=3):
    params = build(cfg)
    eng = ContinuousEngine(cfg, params, serve, obs=obs)
    reqs = synthetic_trace(n, cfg.vocab_size, seed=seed, qps=1e6,
                           prompt_lens=(3, 9), gen_lens=(2, 5))
    out, stats = eng.run(reqs)
    return eng, out, stats


def test_run_stats_contract_from_registry():
    """run() stats are registry-derived but keep the legacy keys."""
    serve = ServeConfig(max_slots=2, kv_block_size=8, prefill_chunk=4,
                        max_len=32)
    eng, out, stats = _run(tiny_cfg(num_layers=1), serve)
    assert stats["steps"] > 0 and stats["steps"] == eng.steps
    assert stats["peak_running"] >= 1
    m = eng.obs.metrics
    assert m.get("engine_steps_total") == eng.steps
    assert m.get("sched_requests_total") == 4
    assert m.get("sched_finished_total") == 4
    assert m.get("generated_tokens_total") == sum(len(v) for v in out.values())
    # rows split: live + padded = total, both tracked
    live = m.get("engine_rows_total", state="live")
    pad = m.get("engine_rows_total", state="padded")
    assert live > 0 and pad >= 0
    # per-shard KV occupancy gauges exist and end fully free
    occ = eng.cache.occupancy()
    assert m.get("kv_blocks", shard=0, state="free") == occ[0]["free"]


def test_recompile_detector_variant_set():
    """Non-speculative paged engine compiles exactly {mixed, decode}."""
    serve = ServeConfig(max_slots=2, kv_block_size=8, prefill_chunk=4,
                        max_len=32)
    eng, _, _ = _run(tiny_cfg(num_layers=1), serve)
    assert eng._expected_variants == 2
    assert eng.compiled_variants() <= 2
    m = eng.obs.metrics
    assert m.get("engine_recompiles_total") == 0
    if eng.compiled_variants():          # _cache_size available on this jax
        assert m.get("engine_compiled_variants") == 2.0


def test_recompile_detector_fires_on_excess_variants():
    serve = ServeConfig(max_slots=2, kv_block_size=8, prefill_chunk=4,
                        max_len=32)
    params = build(tiny_cfg(num_layers=1))
    eng = ContinuousEngine(tiny_cfg(num_layers=1), params, serve,
                           obs=Observability(tracing=True))
    if eng.compiled_variants() is None or not hasattr(
            eng._step_fn, "_cache_size"):
        pytest.skip("jit cache introspection unavailable")
    reqs = synthetic_trace(3, 128, seed=1, qps=1e6, prompt_lens=(3, 9),
                           gen_lens=(2, 4))
    eng._expected_variants = 1           # pretend mixed steps are unexpected
    eng.run(reqs)
    assert eng.obs.metrics.get("engine_recompiles_total") > 0
    assert any(e["name"] == "recompile" for e in eng.obs.tracer.events()
               if e["ph"] == "i")


def test_moe_dropless_dropped_fraction_exact_zero():
    cfg = tiny_cfg(d_ff=96, num_layers=2,
                   moe=MoEConfig(num_experts=4, routing="topk", top_k=2,
                                 impl="dropless", capacity_factor=None,
                                 group_size=64))
    serve = ServeConfig(max_slots=2, kv_block_size=8, prefill_chunk=4,
                        max_len=32)
    _, _, stats = _run(cfg, serve, n=3)
    assert stats["moe_dropped_fraction"] == 0.0   # exact, not approx
    assert stats["moe_gate_entropy"] >= 0.0
    assert stats["moe_load_entropy"] >= 0.0


def test_moe_capacity_drops_surface_in_stats():
    cfg = tiny_cfg(d_ff=96, num_layers=2,
                   moe=MoEConfig(num_experts=4, routing="topk", top_k=2,
                                 impl="einsum", capacity_factor=0.25,
                                 group_size=64))
    serve = ServeConfig(max_slots=2, kv_block_size=8, prefill_chunk=4,
                        max_len=32)
    eng, _, stats = _run(cfg, serve, n=3)
    assert stats["moe_dropped_fraction"] > 0.0
    m = eng.obs.metrics
    # per-layer expert-load shares exist and sum to ~1 per MoE layer
    for layer in range(cfg.num_layers):
        shares = [m.get("moe_expert_load_share", layer=layer, expert=e)
                  for e in range(4)]
        assert sum(shares) == pytest.approx(1.0, abs=1e-5)
        assert m.get("moe_dropped_fraction", layer=layer) > 0.0


def test_obs_on_off_token_identity(tmp_path):
    """Tracing + periodic metrics rows must not change generated tokens."""
    cfg = tiny_cfg(num_layers=1)
    serve = ServeConfig(max_slots=2, kv_block_size=8, prefill_chunk=4,
                        max_len=32)
    _, out_off, _ = _run(cfg, serve)
    obs = Observability(tracing=True)
    obs.metrics_every = 2
    eng, out_on, _ = _run(cfg, serve, obs=obs)
    assert out_on == out_off
    # artifacts from the instrumented run validate end to end
    tp, mp = tmp_path / "trace.json", tmp_path / "metrics.jsonl"
    obs.tracer.write_chrome_trace(str(tp))
    obs.write_metrics_jsonl(str(mp))
    tc = validate_chrome_trace(str(tp))
    assert tc["b"] == tc["e"] > 0 and tc["X"] >= eng.steps
    mc = validate_metrics_jsonl(
        str(mp), require=("engine_steps_total", "kv_blocks",
                          "engine_rows_total", "sched_finished_total"))
    assert mc["rows"] >= 2  # periodic rows + final


def test_legacy_readthrough_views():
    """spec_stats / swap.stats / scheduler ints still read correctly."""
    serve = ServeConfig(max_slots=2, kv_block_size=8, prefill_chunk=4,
                        max_len=32)
    eng, _, _ = _run(tiny_cfg(num_layers=1), serve)
    assert set(eng.spec_stats) == {"verify_steps", "proposed", "accepted",
                                   "emitted"}
    assert eng.scheduler.preemptions == 0
    swap = eng.scheduler.swap
    if swap is not None:
        assert set(swap.stats) == {"swap_outs", "swap_ins", "swapped_blocks",
                                   "restored_blocks"}


def test_queue_and_latency_histograms_populate():
    serve = ServeConfig(max_slots=1, kv_block_size=8, prefill_chunk=4,
                        max_len=32)
    eng, _, _ = _run(tiny_cfg(num_layers=1), serve, n=3)
    snap = eng.obs.metrics.snapshot()
    assert snap["request_queue_ms_count"] == 3
    assert snap["request_latency_ms_count"] == 3
    assert snap["request_latency_ms_sum"] >= snap["request_queue_ms_sum"]
