import os
import subprocess
import sys
import textwrap

# Tests see 1 device (the dry-run sets its own XLA_FLAGS in-process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# Shared fixtures (hoisted out of test_dispatch / test_routers /
# test_distributed, which used to carry near-identical private copies).
# ---------------------------------------------------------------------------

@pytest.fixture(scope="session")
def run_sub():
    """Run a python snippet in a subprocess that owns 8 virtual host
    devices (XLA_FLAGS=--xla_force_host_platform_device_count=8), so the
    main test process keeps its single device."""

    def run(code: str, timeout: int = 560) -> str:
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
        # Pin the backend rather than popping it: the forced host device
        # count composes with JAX_PLATFORMS=cpu, and an unset backend
        # makes the subprocess re-discover accelerators — on hosts with
        # libtpu installed but no TPU that stalls for minutes behind the
        # TPU plugin's /tmp lockfile before falling back to CPU.
        env["JAX_PLATFORMS"] = "cpu"
        out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                             capture_output=True, text=True, env=env,
                             timeout=timeout)
        assert out.returncode == 0, out.stderr[-3000:]
        return out.stdout

    return run


@pytest.fixture
def moe_model_cfg():
    """Factory for the toy MoE ModelConfig the dispatch/layer tests share:
    8 experts, d_model=32, d_ff=48, f32, capacity_factor 2.0."""
    from repro.configs.base import ModelConfig, MoEConfig

    def make(routing="topk", impl="einsum", d_model=32, d_ff=48, **moe_kw):
        kw = dict(num_experts=8, routing=routing, top_k=2, num_prototypes=2,
                  group_size=64, impl=impl, capacity_factor=2.0)
        kw.update(moe_kw)
        return ModelConfig(d_model=d_model, d_ff=d_ff, dtype="float32",
                           moe=MoEConfig(**kw))

    return make


@pytest.fixture
def moe_cfg():
    """Factory for the bare MoEConfig the router tests share."""
    from repro.configs.base import MoEConfig

    def make(routing="topk", **kw):
        base = dict(num_experts=8, routing=routing, top_k=2, num_prototypes=2,
                    aux_loss_coef=0.01)
        base.update(kw)
        return MoEConfig(**base)

    return make


@pytest.fixture
def toy_batch():
    """Factory for the (B, S, M) toy activation batch."""

    def make(B=2, S=50, M=32, seed=1):
        return jax.random.normal(jax.random.PRNGKey(seed), (B, S, M))

    return make


@pytest.fixture
def mesh8():
    """2x4 (data, model) debug mesh; skips unless the test process owns
    >= 8 devices (the CI mesh-8 matrix job sets
    XLA_FLAGS=--xla_force_host_platform_device_count=8)."""
    if jax.device_count() < 8:
        pytest.skip("needs 8 host devices (CI mesh-8 matrix job sets "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    from repro.launch.mesh import make_debug_mesh

    return make_debug_mesh(2, 4)


def _walk_avals(jaxpr):
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            yield v.aval
        for p in eqn.params.values():
            for pv in (p if isinstance(p, (list, tuple)) else [p]):
                inner = getattr(pv, "jaxpr", pv)
                if hasattr(inner, "eqns"):
                    yield from _walk_avals(inner)


@pytest.fixture(scope="session")
def dense_shape_present():
    """Structural probe: does fn's jaxpr (recursing into sub-jaxprs, e.g.
    shard_map bodies) hold an intermediate of exactly `dense_shape`?"""

    def present(fn, args, dense_shape) -> bool:
        closed = jax.make_jaxpr(fn)(*args)
        return any(getattr(a, "shape", None) == dense_shape
                   for a in _walk_avals(closed.jaxpr))

    return present
