"""MoE layer: impl-path equivalence, residual-drop semantics, MoE attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.core.moe import group_tokens, moe_ffn_apply, moe_ffn_specs
from repro.core.moe_attention import moe_attention_apply, moe_attention_specs
from repro.nn import init


def _cfg(routing="topk", impl="einsum", **kw):
    moe_kw = dict(num_experts=8, routing=routing, top_k=2, num_prototypes=2,
                  group_size=64, impl=impl, capacity_factor=2.0)
    moe_kw.update(kw)
    return ModelConfig(d_model=32, d_ff=48, num_heads=4, num_kv_heads=2,
                       head_dim=8, vocab_size=64, dtype="float32",
                       moe=MoEConfig(**moe_kw))


@pytest.mark.parametrize("routing", ["topk", "prototype", "expert_choice", "hash"])
@pytest.mark.parametrize("other_impl", ["gather", "pallas"])
def test_impl_equivalence(routing, other_impl):
    """einsum (paper-faithful dense view) == gather/pallas (index view)."""
    cfg = _cfg(routing)
    params = init(moe_ffn_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 50, 32))
    y0, a0 = jax.jit(lambda p, x: moe_ffn_apply(p, x, cfg))(params, x)
    cfg2 = _cfg(routing, impl=other_impl)
    y1, a1 = jax.jit(lambda p, x: moe_ffn_apply(p, x, cfg2))(params, x)
    tol = 1e-5 if other_impl == "gather" else 1e-4
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=tol)
    assert float(a0["moe_cv"]) == pytest.approx(float(a1["moe_cv"]))
    assert float(a0["moe_dropped_fraction"]) == pytest.approx(
        float(a1["moe_dropped_fraction"]))


def test_dropped_tokens_residual_zero():
    """Capacity-dropped tokens contribute 0 (the residual in the block)."""
    cfg = _cfg("topk", capacity_factor=0.01)  # capacity 1 -> heavy drops
    params = init(moe_ffn_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))
    y, aux = jax.jit(lambda p, x: moe_ffn_apply(p, x, cfg))(params, x)
    assert float(aux["moe_dropped_fraction"]) > 0.5
    # rows for dropped tokens are exactly zero
    norms = jnp.linalg.norm(y[0], axis=-1)
    assert int(jnp.sum(norms == 0.0)) >= 32


def test_group_tokens_divisor():
    m = MoEConfig(num_experts=4, group_size=100)
    x = jnp.zeros((3, 70, 8))  # 210 tokens, target 2 groups -> 2 divides 210
    xg, g = group_tokens(x, m)
    assert xg.shape[0] * xg.shape[1] == 210 and g == 2


def test_gradients_flow_to_router_and_experts():
    cfg = _cfg("prototype")
    params = init(moe_ffn_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))

    def loss(p):
        y, aux = moe_ffn_apply(p, x, cfg)
        return jnp.sum(y ** 2) + aux["moe_aux_loss"]

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["router"]).max()) > 0
    assert float(jnp.abs(g["up"]).max()) > 0
    assert float(jnp.abs(g["down"]).max()) > 0


def test_pallas_backward_matches_einsum():
    """The kernel's custom_vjp (reference-einsum backward) produces the
    same gradients as differentiating the einsum path directly."""
    cfg_e, cfg_p = _cfg("topk"), _cfg("topk", impl="pallas")
    params = init(moe_ffn_specs(cfg_e), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 50, 32))

    def grads(cfg):
        return jax.grad(
            lambda p: jnp.mean(moe_ffn_apply(p, x, cfg)[0] ** 2))(params)

    g_e, g_p = grads(cfg_e), grads(cfg_p)
    for k in g_e:
        a, b = np.asarray(g_e[k]), np.asarray(g_p[k])
        np.testing.assert_allclose(a, b, atol=1e-4 * max(np.abs(a).max(), 1e-9),
                                   err_msg=k)


def test_moe_attention_forward_and_metrics():
    cfg = _cfg("prototype").replace_moe(moe_attention=True)
    params = init(moe_attention_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
    pos = jnp.broadcast_to(jnp.arange(32)[None], (2, 32))
    y, aux = jax.jit(lambda p, x: moe_attention_apply(p, x, cfg, positions=pos))(params, x)
    assert y.shape == x.shape
    assert not bool(jnp.isnan(y).any())
    assert "moe_aux_loss" in aux


def test_capacity_k_vs_one_flops_shape():
    """Capacity 1x produces smaller buffers than kx (Table 1 mechanism)."""
    cfg_k = _cfg("topk", capacity_factor=1.25)
    cfg_1 = cfg_k.replace_moe(capacity_mode="one")
    T = 64
    assert cfg_1.moe.capacity(T) * cfg_k.moe.top_k == cfg_k.moe.capacity(T) * 1


# ---------------------------------------------------------------------------
# Dropped-token accounting: the `dropped_fraction` metric
# (repro.core.metrics) against a dense-reference count.
# ---------------------------------------------------------------------------

class TestDroppedFractionAccounting:
    def _plan(self, cfg, x):
        from repro.core.routing import route
        m = cfg.moe
        params = init(moe_ffn_specs(cfg), jax.random.PRNGKey(0))
        xg, G = group_tokens(x, m)
        w = params.get("router")
        plan = route(xg, None if w is None else w.astype(jnp.float32),
                     m, m.capacity(xg.shape[1]))
        return plan, params

    @pytest.mark.parametrize("routing", ["topk", "prototype", "hash"])
    @pytest.mark.parametrize("cf", [0.05, 0.25, 0.5, 1.0, 4.0])
    def test_agrees_with_dense_reference_count(self, routing, cf):
        """As capacity shrinks, the index-view metric equals the count
        from the dense dispatch view: 1 - kept/routed choices."""
        cfg = _cfg(routing, capacity_factor=cf)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))
        plan, _ = self._plan(cfg, x)
        dense_kept = float(np.asarray(plan.dispatch).sum())
        G, T, K = plan.expert_index.shape   # K = routed choices per token
        assert float(plan.metrics["dropped_fraction"]) == pytest.approx(
            1.0 - dense_kept / (G * T * K), abs=1e-6)

    @pytest.mark.parametrize("cf", [0.05, 0.5])
    def test_expert_choice_counts_unrouted_tokens(self, cf):
        """EC's metric counts tokens *no* expert picked (its failure
        mode), not overflowed choices — check against the dense view."""
        cfg = _cfg("expert_choice", capacity_factor=cf)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))
        plan, _ = self._plan(cfg, x)
        picked = np.asarray(plan.dispatch).sum(axis=(2, 3)) > 0   # (G,T)
        assert float(plan.metrics["dropped_fraction"]) == pytest.approx(
            1.0 - picked.mean(), abs=1e-6)

    @pytest.mark.parametrize("impl", ["einsum", "gather", "pallas",
                                      "alltoall", "dropless"])
    def test_layer_metric_is_dispatcher_independent(self, impl):
        """The aux metric out of the layer equals the plan-level count
        for every backend (the plan is shared; execution can't change
        accounting)."""
        cfg = _cfg("topk", impl=impl, capacity_factor=0.1)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))
        plan, params = self._plan(cfg, x)
        want = float(plan.metrics["dropped_fraction"])
        assert want > 0.3
        _, aux = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg))(params, x)
        assert float(aux["moe_dropped_fraction"]) == pytest.approx(want)

    @pytest.mark.parametrize("routing", ["topk", "prototype",
                                         "expert_choice", "hash"])
    def test_identically_zero_for_dropless(self, routing):
        """capacity_factor=None: exactly 0.0, not approximately —
        repro.core.metrics.dropped_fraction computes dropped/total, which
        XLA cannot turn into reciprocal-multiply rounding noise."""
        cfg = _cfg(routing, impl="dropless", capacity_factor=None)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))
        params = init(moe_ffn_specs(cfg), jax.random.PRNGKey(0))
        _, aux = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg))(params, x)
        assert float(aux["moe_dropped_fraction"]) == 0.0
