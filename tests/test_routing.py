"""Routing semantics vs the paper's pseudo-code (Figs. 7-8) + invariants.

These tests consume the dense ``combine``/``dispatch`` views, which are
now lazy scatter-materialisations of the RoutingPlan index view — so they
double as equivalence checks between the two representations.
(Registry/index-view/new-router coverage lives in test_routers.py.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core.routing import (
    prototype_gating, route, router_logits_prototype, router_logits_topk,
    topk_gating)


def _mk_logits(key, G, T, E):
    return jax.random.normal(key, (G, T, E), jnp.float32)


class TestTopK:
    def test_top1_selects_argmax(self):
        cfg = MoEConfig(num_experts=4, routing="topk", top_k=1, aux_loss_coef=0.0)
        logits = _mk_logits(jax.random.PRNGKey(0), 1, 16, 4)
        res = topk_gating(logits, cfg, capacity=16)
        # every token goes to exactly its argmax expert
        chosen = jnp.argmax(jnp.sum(res.combine, axis=-1), axis=-1)  # (G,T)
        np.testing.assert_array_equal(np.asarray(chosen), np.asarray(jnp.argmax(logits, -1)))

    def test_topk_gate_values_are_softmax_probs(self):
        # Fig. 8: gates are raw softmax probabilities (not renormalised)
        cfg = MoEConfig(num_experts=8, routing="topk", top_k=2, aux_loss_coef=0.0)
        logits = _mk_logits(jax.random.PRNGKey(1), 2, 8, 8)
        res = topk_gating(logits, cfg, capacity=8)
        probs = jax.nn.softmax(logits, axis=-1)
        top2 = jnp.sort(probs, axis=-1)[..., -2:].sum(-1)
        total_gate = jnp.sum(res.combine, axis=(-1, -2))
        np.testing.assert_allclose(np.asarray(total_gate), np.asarray(top2), rtol=1e-5)

    def test_capacity_enforced_per_expert(self):
        cfg = MoEConfig(num_experts=2, routing="topk", top_k=1, aux_loss_coef=0.0)
        # all tokens prefer expert 0
        logits = jnp.stack([jnp.full((32,), 5.0), jnp.zeros((32,))], axis=-1)[None]
        res = topk_gating(logits, cfg, capacity=4)
        loads = jnp.sum(res.dispatch, axis=(0, 1, 3))
        assert int(loads[0]) == 4  # capacity-bound
        assert float(res.metrics["dropped_fraction"]) == pytest.approx(28 / 32)

    def test_positions_unique_within_expert(self):
        cfg = MoEConfig(num_experts=4, routing="topk", top_k=2, aux_loss_coef=0.0)
        logits = _mk_logits(jax.random.PRNGKey(2), 1, 64, 4)
        res = topk_gating(logits, cfg, capacity=64)
        # each (expert, position) slot holds at most one token
        slot_occupancy = jnp.sum(res.dispatch, axis=1)  # (G,E,C)
        assert int(jnp.max(slot_occupancy)) <= 1

    def test_sequential_iterations_share_capacity(self):
        # 2nd argmax pass continues positions where the 1st left off
        cfg = MoEConfig(num_experts=2, routing="topk", top_k=2, aux_loss_coef=0.0)
        logits = jnp.stack([jnp.full((8,), 3.0), jnp.full((8,), 2.0)], -1)[None]
        res = topk_gating(logits, cfg, capacity=10)
        loads = jnp.sum(res.dispatch, axis=(0, 1, 3))
        # 8 tokens x top-2 over 2 experts: expert0 gets 8, expert1 gets 8,
        # capacity 10 -> 8 each, no overflow collisions
        assert int(loads[0]) == 8 and int(loads[1]) == 8


class TestPrototype:
    def test_equals_concatenated_top1(self):
        """Z top-1 routing == independent top-1 within each prototype."""
        Z, F, T = 2, 4, 32
        cfg = MoEConfig(num_experts=Z * F, routing="prototype", num_prototypes=Z,
                        aux_loss_coef=0.0)
        logits = jax.random.normal(jax.random.PRNGKey(3), (1, Z, T, F))
        res = prototype_gating(logits, cfg, capacity=T)
        combine = res.combine.reshape(1, T, Z, F, T)
        for z in range(Z):
            sub_cfg = MoEConfig(num_experts=F, routing="topk", top_k=1, aux_loss_coef=0.0)
            sub = topk_gating(logits[:, z], sub_cfg, capacity=T)
            np.testing.assert_allclose(np.asarray(combine[:, :, z]),
                                       np.asarray(sub.combine), rtol=1e-6)

    def test_each_token_hits_k_prototypes(self):
        Z, F, T = 4, 2, 16
        cfg = MoEConfig(num_experts=Z * F, routing="prototype", num_prototypes=Z,
                        aux_loss_coef=0.0)
        logits = jax.random.normal(jax.random.PRNGKey(4), (1, Z, T, F))
        res = prototype_gating(logits, cfg, capacity=T)
        per_token = jnp.sum(res.dispatch, axis=(2, 3))  # (G,T)
        np.testing.assert_array_equal(np.asarray(per_token), Z)

    def test_no_argmax_loop_for_kprime_1(self):
        # structural check: prototype routing with k'=1 runs ONE argmax pass
        # regardless of Z, while top-k runs k passes.  We verify via jaxpr
        # op counts (argmax lowers to reduce ops: count them).
        def n_argmax(fn, *args):
            jaxpr = jax.make_jaxpr(fn)(*args)
            return str(jaxpr).count("argmax")

        cfg_p = MoEConfig(num_experts=8, routing="prototype", num_prototypes=4)
        cfg_t = MoEConfig(num_experts=8, routing="topk", top_k=4)
        lp = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 8, 2))
        lt = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 8))
        assert n_argmax(lambda l: prototype_gating(l, cfg_p, 4).combine, lp) == 1
        assert n_argmax(lambda l: topk_gating(l, cfg_t, 4).combine, lt) == 4

    def test_router_logits_shapes(self):
        x = jnp.ones((2, 8, 16))
        assert router_logits_topk(x, jnp.ones((16, 6))).shape == (2, 8, 6)
        assert router_logits_prototype(x, jnp.ones((16, 3, 2))).shape == (2, 3, 8, 2)


class TestAuxLoss:
    def test_balanced_assignment_minimises_aux(self):
        cfg = MoEConfig(num_experts=4, routing="topk", top_k=1, aux_loss_coef=1.0)
        T = 64
        # perfectly balanced: tokens cycle over experts with sharp logits
        ids = jnp.arange(T) % 4
        bal = 10.0 * jax.nn.one_hot(ids, 4)[None]
        res_bal = topk_gating(bal, cfg, capacity=T)
        # collapsed: everyone to expert 0
        col = 10.0 * jax.nn.one_hot(jnp.zeros(T, jnp.int32), 4)[None]
        res_col = topk_gating(col, cfg, capacity=T)
        assert float(res_bal.aux_loss) < float(res_col.aux_loss)
        # balanced: aux ~= coef (density*proxy*E^2 = E^2 * (1/E * 1/E) * E... )
        assert float(res_bal.aux_loss) == pytest.approx(1.0, rel=0.05)

    def test_cv_metric(self):
        cfg = MoEConfig(num_experts=4, routing="topk", top_k=1, aux_loss_coef=0.0)
        ids = jnp.arange(64) % 4
        bal = 10.0 * jax.nn.one_hot(ids, 4)[None]
        res = topk_gating(bal, cfg, capacity=64)
        assert float(res.metrics["cv"]) == pytest.approx(0.0, abs=1e-6)
        col = 10.0 * jax.nn.one_hot(jnp.zeros(64, jnp.int32), 4)[None]
        res2 = topk_gating(col, cfg, capacity=64)
        assert float(res2.metrics["cv"]) == pytest.approx(np.sqrt(3), rel=1e-3)


class TestCapacityFormula:
    def test_eq2(self):
        # C = k*T/N * gamma  (paper Eq. 2)
        m = MoEConfig(num_experts=64, routing="topk", top_k=2, capacity_factor=1.25)
        assert m.capacity(2048) == int(2 * 2048 / 64 * 1.25)

    def test_capacity_one_mode(self):
        m = MoEConfig(num_experts=64, routing="topk", top_k=4,
                      capacity_factor=1.25, capacity_mode="one")
        assert m.capacity(2048) == int(1 * 2048 / 64 * 1.25)

    def test_prototype_active_k(self):
        m = MoEConfig(num_experts=64, routing="prototype", num_prototypes=4)
        assert m.active_k == 4
        assert m.experts_per_prototype == 16
