"""Hypothesis property tests for the quantized KV-cache subsystem
(repro.quant): the error-bound law of the quantize/dequantize round
trip, the in-pool bound under interleaved partial-block rewrites, and
the scale-pool/block-table bijection under the same randomised
operation sequences test_kv_properties.py drives over the
full-precision caches.

Laws (see repro/quant/policy.py):

* round trip — one quantize/dequantize pass is elementwise within
  ``policy.error_bound(scale)`` of the input (scale/2 for int8: the
  worst case is half a code step);
* pool residency — a block's rows accrue one extra ``error_bound`` per
  *scale growth* (rescaling re-rounds old codes), so after any write
  sequence every resident row is within ``block_size * error_bound``;
  a rewrite that does NOT grow the scale is a lossless bit identity;
* bijection — every code-pool row has exactly one scale row under the
  same (layer, block, kv_head) key, through admission / growth /
  truncation / COW / eviction / free, per shard and stacked.

Deterministic goldens and the engine-level identity matrix live in
test_kv_quant.py; this module only adds the randomised search (plain
``check_*`` helpers keep the invariants runnable without hypothesis).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.quant import check_quant_roundtrip, get_kv_quant

SETTINGS = dict(max_examples=40, deadline=None)

POLICIES = ["int8", "fp8"]

finite = st.floats(min_value=-1e4, max_value=1e4,
                   allow_nan=False, allow_infinity=False, width=32)


# ---------------------------------------------------------------------------
# Round-trip error bound
# ---------------------------------------------------------------------------

@st.composite
def arrays(draw):
    n = draw(st.integers(1, 24))
    vals = draw(st.lists(finite, min_size=n, max_size=n))
    return np.asarray(vals, np.float32)


@given(arrays(), st.sampled_from(POLICIES))
@settings(**SETTINGS)
def test_roundtrip_error_bound(x, name):
    policy = get_kv_quant(name)
    deq, scale, max_err = check_quant_roundtrip(x, policy)
    assert deq.shape == x.shape
    # absmax scaling: the largest-magnitude element maps to +-qmax, so
    # its round trip is exact up to the bound; zeros stay zero exactly
    assert float(jnp.abs(deq[x == 0]).max(initial=0.0)) == 0.0


@given(st.sampled_from(POLICIES))
@settings(**SETTINGS)
def test_roundtrip_all_zero(name):
    policy = get_kv_quant(name)
    deq, scale, max_err = check_quant_roundtrip(np.zeros(8, np.float32), policy)
    assert scale == 0.0 and max_err == 0.0


# ---------------------------------------------------------------------------
# quant_write_kv: in-pool error bound under interleaved partial writes
# (the checker lives in test_kv_quant.py with the deterministic goldens
# so it stays runnable without the hypothesis dependency)
# ---------------------------------------------------------------------------

from test_kv_quant import check_quant_write_sequence


@st.composite
def write_cases(draw):
    bs = draw(st.sampled_from([2, 4]))
    hkv, hd = 2, 2
    name = draw(st.sampled_from(POLICIES))
    n = draw(st.integers(1, 16))
    writes = []
    for _ in range(n):
        blk = draw(st.integers(0, 3))
        off = draw(st.integers(0, bs - 1))
        vals = draw(st.lists(finite, min_size=hkv * hd, max_size=hkv * hd))
        writes.append((blk, off, vals))
    return bs, hkv, hd, name, writes


@given(write_cases())
@settings(**SETTINGS)
def test_quant_write_interleavings(case):
    check_quant_write_sequence(*case)


# ---------------------------------------------------------------------------
# Scale-pool / block-table bijection under the cache drivers
# ---------------------------------------------------------------------------

from test_kv_properties import check_sharded_cache_sequence
from test_prefix_cache import check_prefix_sequence


@st.composite
def prefix_cases(draw):
    max_slots = draw(st.integers(1, 4))
    bs = draw(st.sampled_from([2, 4]))
    num_blocks = draw(st.integers(2, 24))
    ops = draw(st.lists(
        st.tuples(st.integers(0, 3), st.integers(0, 3), st.integers(0, 512)),
        max_size=50))
    return max_slots, bs, num_blocks, ops


@given(prefix_cases(), st.sampled_from(POLICIES))
@settings(**SETTINGS)
def test_quantized_prefix_interleavings(case, name):
    from repro.quant.kv_cache import QuantizedPrefixCachingKVCache

    max_slots, bs, num_blocks, ops = case
    check_prefix_sequence(max_slots, bs, num_blocks, ops,
                          cache_cls=QuantizedPrefixCachingKVCache,
                          kv_quant=name)


@st.composite
def sharded_cases(draw):
    data_shards = draw(st.sampled_from([1, 2]))
    slots_per_shard = draw(st.integers(1, 2))
    bs = draw(st.sampled_from([1, 4]))
    blocks_per_shard = draw(st.integers(1, 12))
    ops = draw(st.lists(
        st.tuples(st.integers(0, 3), st.integers(0, 7), st.integers(0, 256)),
        max_size=40))
    return data_shards, slots_per_shard, bs, blocks_per_shard, ops


@given(sharded_cases())
@settings(**SETTINGS)
def test_quantized_sharded_interleavings(case):
    check_sharded_cache_sequence(*case, kv_quant="int8")
