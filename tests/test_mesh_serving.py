"""Mesh-sharded serving: token-identity between the sharded and the
single-device continuous engine, plus structural guarantees on the
sharded step.

The tentpole contract (docs/serving.md "Multi-host serving"):

* the slot pool and the paged KV block pools are partitioned over the
  ``data`` mesh axis — each shard owns ``max_slots/D`` slots and
  ``num_blocks/D`` blocks behind its own allocator, admission consults
  the per-shard views through the scheduler's global interface;
* ``paged_decode_attention`` runs under ``shard_map`` with shard-local
  block tables, so no device ever materialises the full
  ``(num_blocks, ...)`` pool (asserted on the jaxpr below);
* dropless MoE under an expert-sharded mesh dispatches through the
  ragged expert-parallel ``all_to_all`` (never gather, never a dense
  ``(G,T,E,C)`` buffer — both asserted on the jaxpr).

Because the per-shard layout moves whole KV blocks and whole ragged row
blocks, every cell must be *token-identical* (greedy) to the
single-device engine — dense, dropless-hash and dropless-topk, with
slot reuse and prefix caching on and off.  Every sharded engine runs
with ``check_invariants=True``, which re-asserts per-shard + aggregate
block conservation after every step.

Multi-shard cells need 8 host devices and run in-process in the CI
mesh-8 matrix job; the subprocess twins cover the single-device job
(PR 2/3 idiom).  The trivial ``(data=1, expert=1)`` mesh exercises the
whole sharded code path (ShardedPagedKVCache, shard_map attention,
shard-major row layout) on one device, so it always runs.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import _walk_avals
from repro.configs.base import MoEConfig, ServeConfig
from repro.serving.continuous import ContinuousEngine, _row_buffers
from repro.serving.kv_cache import PagedKVCache, ShardedPagedKVCache
from test_serving import build, tiny_cfg

MESHES = [
    (("data", 2), ("expert", 4)),
    (("data", 8), ("expert", 1)),
    (("data", 1), ("expert", 8)),
]
MESH_IDS = ["2x4", "8x1", "1x8"]
TRIVIAL = (("data", 1), ("expert", 1))


def _need_devices(spec):
    need = 1
    for _, size in spec:
        need *= size
    if jax.device_count() < need:
        pytest.skip(f"needs {need} host devices (CI mesh-8 matrix job sets "
                    f"XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _serve(mesh=None, **kw):
    base = dict(max_slots=8, kv_block_size=4, prefill_chunk=4, max_len=32,
                mesh=mesh)
    base.update(kw)
    return ServeConfig(**base)


def _moe_cfg(routing):
    return tiny_cfg(d_ff=96, moe=MoEConfig(
        num_experts=8, routing=routing, top_k=2, group_size=1,
        impl="dropless", capacity_factor=None))


def _prompts(cfg, B=6, S=9, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0,
                              cfg.vocab_size)


def _mesh_parity(cfg, spec, prompts=None, num_tokens=10, **serve_kw):
    """Greedy generate on the sharded engine == the single-device engine,
    exactly; returns the sharded engine for further structural probes."""
    params = build(cfg)
    if prompts is None:
        prompts = _prompts(cfg)
    ref = ContinuousEngine(cfg, params, _serve(**serve_kw),
                           check_invariants=True)
    base, _ = ref.generate(prompts, num_tokens)
    eng = ContinuousEngine(cfg, params, _serve(mesh=spec, **serve_kw),
                           check_invariants=True)
    out, _ = eng.generate(prompts, num_tokens)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base))
    eng.cache.check_conservation()
    return eng


def _step_jaxpr(eng, N):
    """Trace the engine's (unjitted) step at row count N — the compiled
    census shapes are N=max_slots (decode-only) and
    N=max_slots + data_shards*prefill_chunk (mixed)."""
    b = _row_buffers(N, eng.serve.blocks_per_slot, eng.cache.garbage_block)
    return jax.make_jaxpr(eng._step_fn_raw)(
        eng.params, eng.cache.k_pool, eng.cache.v_pool, b["tokens"],
        b["ctx_ids"], b["positions"], b["lengths"], b["row_tables"],
        b["wb"], b["wo"], b["slots"], eng._key)


def _shapes(jx):
    return {getattr(a, "shape", None) for a in _walk_avals(jx.jaxpr)}


# ---------------------------------------------------------------------------
# The trivial 1x1 mesh: the whole sharded machinery on one device.
# Always runs — this is the single-device CI job's in-process coverage.
# ---------------------------------------------------------------------------

class TestTrivialMesh:
    def test_dense_token_identity(self):
        eng = _mesh_parity(tiny_cfg(), TRIVIAL)
        assert isinstance(eng.cache, ShardedPagedKVCache)
        assert eng.cache.num_shards == 1

    def test_dropless_topk_token_identity(self):
        _mesh_parity(_moe_cfg("topk"), TRIVIAL)

    def test_slot_reuse_token_identity(self):
        """More requests than slots: completion-time eviction + refill
        crosses the sharded slot pool, outputs still identical."""
        cfg = tiny_cfg()
        _mesh_parity(cfg, TRIVIAL, prompts=_prompts(cfg, B=12), num_tokens=6)

    def test_prefix_cache_token_identity(self):
        """Shared-prefix prompts with prefix caching on a sharded pool:
        per-shard RefcountedBlockAllocators, same tokens."""
        cfg = tiny_cfg()
        base = jax.random.randint(jax.random.PRNGKey(3), (12,), 0,
                                  cfg.vocab_size)
        tails = jax.random.randint(jax.random.PRNGKey(4), (6, 4), 0,
                                   cfg.vocab_size)
        prompts = jnp.concatenate(
            [jnp.tile(base[None], (6, 1)), tails], axis=1)
        eng = _mesh_parity(cfg, TRIVIAL, prompts=prompts, num_tokens=8,
                           prefix_cache=True)
        # second serve of the same prompts must hit the (sharded) cache
        # and stay identical to the first
        out1, _ = eng.generate(prompts, 8)
        out2, _ = eng.generate(prompts, 8)
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
        assert eng.cache.stats["hit_tokens"] > 0

    def test_prefix_cache_off_vs_on_identical(self):
        cfg = tiny_cfg()
        params = build(cfg)
        prompts = _prompts(cfg)
        outs = {}
        for pc in (False, True):
            eng = ContinuousEngine(cfg, params,
                                   _serve(mesh=TRIVIAL, prefix_cache=pc),
                                   check_invariants=True)
            outs[pc], _ = eng.generate(prompts, 8)
        np.testing.assert_array_equal(np.asarray(outs[False]),
                                      np.asarray(outs[True]))

    def test_mesh_rejects_spec_and_slo(self):
        from repro.configs.base import SLOConfig, SpecConfig

        cfg = tiny_cfg()
        params = build(cfg)
        for kw in (dict(spec=SpecConfig(drafter="ngram", gamma=2)),
                   dict(slo=SLOConfig(preemption=True, host_blocks=8))):
            with pytest.raises(NotImplementedError):
                ContinuousEngine(cfg, params, _serve(mesh=TRIVIAL, **kw))

    def test_serve_config_validates_mesh(self):
        with pytest.raises(ValueError):
            _serve(mesh=(("rows", 2), ("expert", 1)))      # unknown axis
        with pytest.raises(ValueError):
            _serve(mesh=(("data", 3), ("expert", 1)))      # 8 slots % 3 != 0


# ---------------------------------------------------------------------------
# Real multi-shard meshes (8 in-process devices: the CI mesh-8 job).
# ---------------------------------------------------------------------------

class TestMeshParity:
    @pytest.mark.parametrize("spec", MESHES, ids=MESH_IDS)
    def test_dense(self, spec):
        _need_devices(spec)
        _mesh_parity(tiny_cfg(), spec)

    @pytest.mark.parametrize("spec", MESHES[:1] + MESHES[2:], ids=["2x4", "1x8"])
    def test_dropless_hash(self, spec):
        _need_devices(spec)
        _mesh_parity(_moe_cfg("hash"), spec)

    @pytest.mark.parametrize("spec", MESHES[:1] + MESHES[2:], ids=["2x4", "1x8"])
    def test_dropless_topk(self, spec):
        _need_devices(spec)
        _mesh_parity(_moe_cfg("topk"), spec)

    def test_slot_reuse_across_shards(self):
        """12 requests over 8 slots on a 2-way-sharded slot pool: the
        scheduler refills whichever shard freed a slot; outputs stay
        identical to the single-device engine."""
        spec = MESHES[0]
        _need_devices(spec)
        cfg = tiny_cfg()
        _mesh_parity(cfg, spec, prompts=_prompts(cfg, B=12), num_tokens=6)

    def test_prefix_cache_on_mesh(self):
        spec = MESHES[0]
        _need_devices(spec)
        cfg = tiny_cfg()
        base = jax.random.randint(jax.random.PRNGKey(3), (12,), 0,
                                  cfg.vocab_size)
        tails = jax.random.randint(jax.random.PRNGKey(4), (6, 4), 0,
                                   cfg.vocab_size)
        prompts = jnp.concatenate(
            [jnp.tile(base[None], (6, 1)), tails], axis=1)
        _mesh_parity(cfg, spec, prompts=prompts, num_tokens=8,
                     prefix_cache=True)


class TestMeshStructure:
    """Jaxpr-level guarantees on the sharded step: per-shard pools only,
    ragged EP all_to_all engaged, no dense capacity tensor."""

    def _pool_shapes(self, cfg, serve):
        Hkv = cfg.num_kv_heads
        hd = cfg.d_model // cfg.num_heads
        bs = serve.kv_block_size
        nb = serve.resolved_num_blocks
        D = serve.data_shards
        unsharded = (nb + 1, Hkv, bs, hd)
        per_shard = (nb // D + 1, Hkv, bs, hd)
        return unsharded, per_shard

    def test_no_unsharded_pool_in_sharded_step(self):
        spec = MESHES[0]
        _need_devices(spec)
        cfg = tiny_cfg()
        eng = ContinuousEngine(cfg, build(cfg), _serve(mesh=spec),
                               check_invariants=True)
        serve = eng.serve
        N = serve.max_slots + serve.data_shards * serve.prefill_chunk
        jx = _step_jaxpr(eng, N)
        shapes = _shapes(jx)
        unsharded, per_shard = self._pool_shapes(cfg, serve)
        assert unsharded not in shapes      # never a full (num_blocks,...) pool
        assert per_shard in shapes          # the shard-local pool IS there

    def test_ragged_ep_engaged_no_dense_capacity(self):
        """Expert-sharded dropless: the mixed step's jaxpr holds the
        all_to_all exchange (the ragged EP path, not a gather fallback)
        and no (G, T, E, C) capacity tensor — global or per-shard."""
        spec = MESHES[0]                    # (data 2, expert 4): G=16 % 8 == 0
        _need_devices(spec)
        cfg = _moe_cfg("topk")
        eng = ContinuousEngine(cfg, build(cfg), _serve(mesh=spec),
                               check_invariants=True)
        serve = eng.serve
        for N in (serve.max_slots,
                  serve.max_slots + serve.data_shards * serve.prefill_chunk):
            jx = _step_jaxpr(eng, N)
            assert "all_to_all" in str(jx), f"EP not engaged at N={N}"
            shapes = _shapes(jx)
            G, T = N, 1                     # group_size=1: one token per group
            E = cfg.moe.num_experts
            C = cfg.moe.capacity(T)
            assert (G, T, E, C) not in shapes
            assert (G // 8, T, E, C) not in shapes
            unsharded, per_shard = self._pool_shapes(cfg, serve)
            assert unsharded not in shapes
            assert per_shard in shapes

    def test_decode_step_ep_on_pure_expert_mesh(self):
        """(data 1, expert 8): the decode-only shape (N=8 rows, G=8)
        divides the device grid, so EP engages there too."""
        spec = MESHES[2]
        _need_devices(spec)
        cfg = _moe_cfg("topk")
        eng = ContinuousEngine(cfg, build(cfg), _serve(mesh=spec),
                               check_invariants=True)
        jx = _step_jaxpr(eng, eng.serve.max_slots)
        assert "all_to_all" in str(jx)


# ---------------------------------------------------------------------------
# Subprocess twins for the single-device CI job (PR 2/3 idiom).
# ---------------------------------------------------------------------------

_SUB_COMMON = """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, MoEConfig, ServeConfig
from repro.models.registry import get_family
from repro.nn import init
from repro.serving.continuous import ContinuousEngine, _row_buffers

assert jax.device_count() == 8

def tiny_cfg(**kw):
    base = dict(name="t", family="decoder_lm", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                max_seq_len=128, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)

def build(cfg):
    return init(get_family(cfg).specs(cfg), jax.random.PRNGKey(0))

def serve(mesh=None, **kw):
    base = dict(max_slots=8, kv_block_size=4, prefill_chunk=4, max_len=32,
                mesh=mesh)
    base.update(kw)
    return ServeConfig(**base)

def parity(cfg, spec, prompts, n=10, **kw):
    params = build(cfg)
    base, _ = ContinuousEngine(cfg, params, serve(**kw),
                               check_invariants=True).generate(prompts, n)
    eng = ContinuousEngine(cfg, params, serve(mesh=spec, **kw),
                           check_invariants=True)
    out, _ = eng.generate(prompts, n)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base))
    eng.cache.check_conservation()
    return eng
"""


@pytest.mark.skipif(jax.device_count() >= 8,
                    reason="multi-device parent runs the in-process mesh "
                           "parity tests instead; the subprocess variant "
                           "belongs to the single-device CI job")
def test_mesh_parity_in_subprocess(run_sub):
    """Dense + dropless-topk token identity on (2,4) and (8,1) meshes,
    slot reuse included, in an 8-virtual-device subprocess."""
    code = _SUB_COMMON + """
cfg = tiny_cfg()
prompts = jax.random.randint(jax.random.PRNGKey(1), (6, 9), 0, cfg.vocab_size)
for spec in ((("data", 2), ("expert", 4)), (("data", 8), ("expert", 1))):
    parity(cfg, spec, prompts)
    print("dense-ok", spec[0][1], spec[1][1])
parity(cfg, (("data", 2), ("expert", 4)),
       jax.random.randint(jax.random.PRNGKey(2), (12, 9), 0, cfg.vocab_size),
       n=6)
print("reuse-ok")
mcfg = tiny_cfg(d_ff=96, moe=MoEConfig(num_experts=8, routing="topk",
                                       top_k=2, group_size=1,
                                       impl="dropless", capacity_factor=None))
parity(mcfg, (("data", 2), ("expert", 4)), prompts)
print("dropless-ok")
"""
    out = run_sub(code, timeout=1500)
    assert "dense-ok 2 4" in out and "dense-ok 8 1" in out
    assert "reuse-ok" in out and "dropless-ok" in out


@pytest.mark.skipif(jax.device_count() >= 8,
                    reason="multi-device parent runs the in-process "
                           "structural tests instead")
def test_mesh_structure_in_subprocess(run_sub):
    """Jaxpr assertions in an 8-virtual-device subprocess: per-shard
    pools only, ragged EP all_to_all present, no dense capacity
    tensor."""
    code = _SUB_COMMON + """
def walk(jaxpr):
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            yield v.aval
        for p in eqn.params.values():
            for pv in (p if isinstance(p, (list, tuple)) else [p]):
                inner = getattr(pv, "jaxpr", pv)
                if hasattr(inner, "eqns"):
                    yield from walk(inner)

cfg = tiny_cfg(d_ff=96, moe=MoEConfig(num_experts=8, routing="topk",
                                      top_k=2, group_size=1,
                                      impl="dropless", capacity_factor=None))
eng = ContinuousEngine(cfg, build(cfg),
                       serve(mesh=(("data", 2), ("expert", 4))),
                       check_invariants=True)
sv = eng.serve
N = sv.max_slots + sv.data_shards * sv.prefill_chunk
b = _row_buffers(N, sv.blocks_per_slot, eng.cache.garbage_block)
jx = jax.make_jaxpr(eng._step_fn_raw)(
    eng.params, eng.cache.k_pool, eng.cache.v_pool, b["tokens"],
    b["ctx_ids"], b["positions"], b["lengths"], b["row_tables"],
    b["wb"], b["wo"], b["slots"], eng._key)
assert "all_to_all" in str(jx)
shapes = {getattr(a, "shape", None) for a in walk(jx.jaxpr)}
Hkv, bs = cfg.num_kv_heads, sv.kv_block_size
hd = cfg.d_model // cfg.num_heads
nb = sv.resolved_num_blocks
assert (nb + 1, Hkv, bs, hd) not in shapes
assert (nb // 2 + 1, Hkv, bs, hd) in shapes
assert (N, 1, 8, cfg.moe.capacity(1)) not in shapes
print("structure-ok")
"""
    assert "structure-ok" in run_sub(code, timeout=1500)
