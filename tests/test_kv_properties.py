"""Hypothesis property tests for BlockAllocator / PagedKVCache under
interleaved allocate / grow / truncate / free / swap-out / swap-in
sequences (the lifecycles speculative decoding and SLO preemption
exercise: admission reserves, decode grows, rejection rewinds,
preemption moves blocks to the host pool and restore brings them back,
eviction frees).

Invariants (see kv_cache.py):

* conservation — free + allocated always equals ``num_blocks``, every
  id accounted for exactly once, double-free raises;
* reservation accounting — a slot never holds more blocks than its
  admission-time reservation, total reservations never exceed the
  pool (the no-mid-flight-starvation guarantee), and any growth within
  a reservation succeeds;
* table hygiene — a slot's block-table row mirrors its held blocks
  exactly, everything beyond points at the garbage block (rows never
  dangle into freed storage).

Deterministic golden/edge-case tests live in test_speculative.py; this
module explores the operation-sequence space around them, in the style
of tests/test_plan_properties.py (plain ``check_*`` helpers drive the
invariants so they stay runnable without the hypothesis dependency).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency")
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig, ServeConfig
from repro.serving.kv_cache import BlockAllocator, PagedKVCache

SETTINGS = dict(max_examples=40, deadline=None)


def _cfg():
    return ModelConfig(name="t", family="decoder_lm", num_layers=1,
                       d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                       vocab_size=64, dtype="float32")


# ---------------------------------------------------------------------------
# BlockAllocator: random alloc/free interleavings
# ---------------------------------------------------------------------------

def check_allocator_sequence(num_blocks, ops):
    """ops: list of (kind, amount) with kind 0=alloc, 1=free-oldest,
    2=free-newest.  The model below tracks live allocations; the
    allocator must agree at every step and at the end."""
    a = BlockAllocator(num_blocks)
    live = []
    for kind, amount in ops:
        if kind == 0:
            n = amount % (num_blocks + 2)
            if a.can_alloc(n):
                got = a.alloc(n)
                assert len(got) == n and len(set(got)) == n
                assert all(0 <= b < num_blocks for b in got)
                # ids must not collide with anything still live
                flat = {b for chunk in live for b in chunk}
                assert not (set(got) & flat)
                if got:            # empty chunks have no double-free to detect
                    live.append(got)
            else:
                with pytest.raises(RuntimeError):
                    a.alloc(n)
        elif live:
            chunk = live.pop(0 if kind == 1 else -1)
            a.free(chunk)
            with pytest.raises(RuntimeError):
                a.free(chunk)               # double-free always detected
        a.check_conservation()
        assert a.free_count == num_blocks - sum(len(c) for c in live)
    for chunk in live:
        a.free(chunk)
    a.check_conservation()
    assert a.free_count == num_blocks


@st.composite
def allocator_cases(draw):
    num_blocks = draw(st.integers(1, 24))
    ops = draw(st.lists(
        st.tuples(st.integers(0, 2), st.integers(0, 24)), max_size=40))
    return num_blocks, ops


@given(allocator_cases())
@settings(**SETTINGS)
def test_allocator_interleavings(case):
    check_allocator_sequence(*case)


# ---------------------------------------------------------------------------
# PagedKVCache: admission / growth / truncate / eviction interleavings
# ---------------------------------------------------------------------------

def check_cache_sequence(max_slots, bs, num_blocks, ops):
    """ops: (kind, slot, amount); kind 0=allocate_slot, 1=ensure_capacity,
    2=truncate_slot, 3=free_slot, 4=swap_out, 5=swap_in (the preemption
    lifecycle: a swapped-out slot leaves the device model entirely and
    lives as a host record until restored).  A host-side model of
    per-slot (reserved_len, current_len) decides legality; the cache
    must accept every legal op and keep its invariants after each one."""
    from repro.serving.slo.swap import SwapManager

    serve = ServeConfig(max_slots=max_slots, kv_block_size=bs,
                        max_len=max(num_blocks * bs, 2),
                        num_blocks=num_blocks)
    cache = PagedKVCache(_cfg(), serve)
    swap = SwapManager(cache, host_blocks=num_blocks)
    model = {}                                  # slot -> [total_len, cur_len]
    swapped = []                                # [(rec, total_len, cur_len)]
    next_uid = 0

    def reserved_blocks():
        return sum(-(-t // bs) for t, _ in model.values())

    for kind, slot, amount in ops:
        slot = slot % max_slots
        if kind == 0 and slot not in model:
            total = 1 + amount % serve.max_len
            if cache.can_allocate_slot(total):
                cache.allocate_slot(slot, total)
                model[slot] = [total, 0]
                assert cache.held_blocks(slot) == 0
            else:
                assert reserved_blocks() + -(-total // bs) > num_blocks
        elif kind == 1 and slot in model:
            total, cur = model[slot]
            length = min(1 + amount % serve.max_len, total)
            cache.ensure_capacity(slot, length)
            model[slot][1] = max(cur, length)
            assert cache.held_blocks(slot) == -(-model[slot][1] // bs)
        elif kind == 2 and slot in model:
            total, cur = model[slot]
            new_len = amount % (cur + 1)
            cache.truncate_slot(slot, new_len)
            model[slot][1] = new_len
            assert cache.held_blocks(slot) == (
                -(-new_len // bs) if new_len else 0)
        elif kind == 3 and slot in model:
            cache.free_slot(slot)
            del model[slot]
            assert (cache.block_table[slot] == cache.garbage_block).all()
        elif kind == 4 and slot in model:
            total, cur = model[slot]
            foot = cache.swap_footprint(slot)
            assert foot == -(-cur // bs)
            if swap.can_store(foot):
                rec = cache.swap_out(slot, swap, uid=next_uid,
                                     total_len=total, context_len=cur)
                next_uid += 1
                swapped.append((rec, total, cur))
                del model[slot]
                assert (cache.block_table[slot] == cache.garbage_block).all()
        elif kind == 5 and swapped and slot not in model:
            rec, total, cur = swapped[amount % len(swapped)]
            if cache.can_restore(rec):
                swapped.remove((rec, total, cur))
                resume = cache.restore_slot(slot, rec, swap)
                swap.release(rec)
                assert resume == cur        # plain paged: always a full restore
                model[slot] = [total, cur]
                assert cache.held_blocks(slot) == -(-cur // bs)
            else:
                assert (reserved_blocks() + -(-total // bs)) > num_blocks
        cache.check_conservation()
        swap.check_conservation()
        assert cache.reserved_total == reserved_blocks()
        assert cache.reserved_total <= num_blocks
        held = sum(-(-cur // bs) for _, cur in model.values())
        assert cache.allocator.free_count == num_blocks - held
        assert swap.used_host_blocks == sum(
            -(-cur // bs) for _, _, cur in swapped)
    for slot in list(model):
        cache.free_slot(slot)
    for rec, _, _ in swapped:
        swap.release(rec)
    cache.check_conservation()
    swap.check_conservation()
    assert cache.allocator.free_count == num_blocks
    assert cache.reserved_total == 0
    assert swap.used_host_blocks == 0
    assert (cache.block_table == cache.garbage_block).all()


@st.composite
def cache_cases(draw):
    max_slots = draw(st.integers(1, 4))
    bs = draw(st.sampled_from([1, 4, 8]))
    num_blocks = draw(st.integers(1, 24))
    ops = draw(st.lists(
        st.tuples(st.integers(0, 5), st.integers(0, 3), st.integers(0, 256)),
        max_size=50))
    return max_slots, bs, num_blocks, ops


@given(cache_cases())
@settings(**SETTINGS)
def test_cache_interleavings(case):
    check_cache_sequence(*case)


# ---------------------------------------------------------------------------
# PrefixCachingKVCache: share / diverge / evict-under-pressure / COW
# ---------------------------------------------------------------------------

# The checker lives in test_prefix_cache.py (with the deterministic
# goldens and a fixed-grid drive) so it stays runnable without the
# hypothesis dependency; this module only adds the randomised search.
from test_prefix_cache import check_prefix_sequence


@st.composite
def prefix_cases(draw):
    max_slots = draw(st.integers(1, 4))
    bs = draw(st.sampled_from([2, 4]))
    num_blocks = draw(st.integers(2, 24))
    ops = draw(st.lists(
        st.tuples(st.integers(0, 3), st.integers(0, 3), st.integers(0, 512)),
        max_size=50))
    return max_slots, bs, num_blocks, ops


@given(prefix_cases())
@settings(**SETTINGS)
def test_prefix_cache_interleavings(case):
    check_prefix_sequence(*case)


# ---------------------------------------------------------------------------
# Sharded pools: N per-shard allocators vs one host-side global model
# (the mesh-serving tentpole — admission holds per shard AND in aggregate)
# ---------------------------------------------------------------------------

def check_sharded_allocator_sequence(num_shards, blocks_per_shard, ops):
    """ops: (kind, shard, amount) with kind 0=alloc, 1=free-oldest,
    2=free-newest, each targeting one shard's allocator.  A host-side
    global model tracks every shard's live chunks; after every op the
    per-shard invariants (conservation, free-count) AND the aggregate
    ones (summed conservation, the no-starvation witness: a 1-block
    admission can proceed somewhere iff the aggregate pool has headroom)
    must hold."""
    shards = [BlockAllocator(blocks_per_shard) for _ in range(num_shards)]
    live = [[] for _ in range(num_shards)]
    total = num_shards * blocks_per_shard
    for kind, sh, amount in ops:
        sh = sh % num_shards
        a = shards[sh]
        if kind == 0:
            n = amount % (blocks_per_shard + 2)
            if a.can_alloc(n):
                got = a.alloc(n)
                assert len(got) == n == len(set(got))
                assert all(0 <= b < blocks_per_shard for b in got)
                flat = {b for chunk in live[sh] for b in chunk}
                assert not (set(got) & flat)
                if got:
                    live[sh].append(got)
            else:
                # a full shard rejects even when its *peers* have room —
                # routing around that is the admission layer's job
                with pytest.raises(RuntimeError):
                    a.alloc(n)
        elif live[sh]:
            chunk = live[sh].pop(0 if kind == 1 else -1)
            a.free(chunk)
            with pytest.raises(RuntimeError):
                a.free(chunk)               # double-free detected per shard
        held = 0
        for s2, a2 in enumerate(shards):
            a2.check_conservation()
            h = sum(len(c) for c in live[s2])
            assert a2.free_count == blocks_per_shard - h
            held += h
        assert sum(a2.free_count for a2 in shards) == total - held
        assert any(a2.can_alloc(1) for a2 in shards) == (held < total)
    for sh, a in enumerate(shards):
        for chunk in live[sh]:
            a.free(chunk)
        a.check_conservation()
        assert a.free_count == blocks_per_shard


@st.composite
def sharded_allocator_cases(draw):
    num_shards = draw(st.integers(1, 4))
    blocks_per_shard = draw(st.integers(1, 12))
    ops = draw(st.lists(
        st.tuples(st.integers(0, 2), st.integers(0, 3), st.integers(0, 24)),
        max_size=40))
    return num_shards, blocks_per_shard, ops


@given(sharded_allocator_cases())
@settings(**SETTINGS)
def test_sharded_allocator_interleavings(case):
    check_sharded_allocator_sequence(*case)


def test_free_on_the_wrong_shard_raises():
    """Block ids are shard-local: handing shard 1 a chunk allocated on
    shard 0 must be rejected as a double-free (those ids are free on
    shard 1), leaving both shards' books intact."""
    shards = [BlockAllocator(8), BlockAllocator(8)]
    chunk = shards[0].alloc(3)
    with pytest.raises(RuntimeError, match="double-free"):
        shards[1].free(chunk)
    shards[1].check_conservation()
    assert shards[1].free_count == 8        # nothing leaked into shard 1
    shards[0].free(chunk)
    shards[0].check_conservation()
    assert shards[0].free_count == 8


def check_sharded_cache_sequence(data_shards, slots_per_shard, bs,
                                 blocks_per_shard, ops, *, kv_quant="none"):
    """ops: (kind, slot, amount); kind 0=allocate_slot, 1=ensure_capacity,
    2=truncate_slot, 3=free_slot against a ShardedPagedKVCache.  Slot
    ``s`` lives on shard ``s // slots_per_shard``; a host model of
    per-slot (reserved_len, cur_len) decides legality *per shard* — a
    request fits iff its owning shard has reservation headroom, however
    much room the peers have.  ``kv_quant`` runs the same sequence over
    stacked int8 + scale pools; ``check_conservation`` then also asserts
    the scale-pool/block-table bijection after every op."""
    from repro.serving.kv_cache import ShardedPagedKVCache

    max_slots = data_shards * slots_per_shard
    serve = ServeConfig(max_slots=max_slots, kv_block_size=bs,
                        max_len=max(blocks_per_shard * bs, 2),
                        num_blocks=data_shards * blocks_per_shard,
                        kv_quant=kv_quant,
                        mesh=(("data", data_shards), ("expert", 1)))
    cache = ShardedPagedKVCache(_cfg(), serve)
    assert cache.num_shards == data_shards
    assert cache.max_request_blocks == blocks_per_shard
    model = {}                                  # slot -> [total_len, cur_len]

    def reserved(sh):
        return sum(-(-t // bs) for s, (t, _) in model.items()
                   if s // slots_per_shard == sh)

    for kind, slot, amount in ops:
        slot = slot % max_slots
        sh = slot // slots_per_shard
        if kind == 0 and slot not in model:
            total = 1 + amount % serve.max_len
            fits = reserved(sh) + -(-total // bs) <= blocks_per_shard
            assert cache.can_allocate_slot_on(slot, total) == fits
            if fits:
                cache.allocate_slot(slot, total)
                model[slot] = [total, 0]
                assert cache.held_blocks(slot) == 0
        elif kind == 1 and slot in model:
            total, cur = model[slot]
            length = min(1 + amount % serve.max_len, total)
            cache.ensure_capacity(slot, length)
            model[slot][1] = max(cur, length)
            assert cache.held_blocks(slot) == -(-model[slot][1] // bs)
        elif kind == 2 and slot in model:
            total, cur = model[slot]
            new_len = amount % (cur + 1)
            cache.truncate_slot(slot, new_len)
            model[slot][1] = new_len
        elif kind == 3 and slot in model:
            cache.free_slot(slot)
            del model[slot]
        cache.check_conservation()              # per-shard + aggregate
        # reservation accounting, per shard and summed
        for s2, sub in enumerate(cache.shards):
            assert sub.reserved_total == reserved(s2)
            assert sub.reserved_total <= blocks_per_shard
        assert cache.reserved_total == sum(
            reserved(s2) for s2 in range(data_shards))
        # no-starvation witness: some shard can admit a 1-token request
        # iff some shard has reservation headroom
        assert cache.can_allocate_slot(1) == any(
            reserved(s2) < blocks_per_shard for s2 in range(data_shards))
    for slot in list(model):
        cache.free_slot(slot)
    cache.check_conservation()
    assert cache.reserved_total == 0


@st.composite
def sharded_cache_cases(draw):
    data_shards = draw(st.sampled_from([1, 2, 4]))
    slots_per_shard = draw(st.integers(1, 2))
    bs = draw(st.sampled_from([1, 4]))
    blocks_per_shard = draw(st.integers(1, 12))
    ops = draw(st.lists(
        st.tuples(st.integers(0, 3), st.integers(0, 7), st.integers(0, 256)),
        max_size=40))
    return data_shards, slots_per_shard, bs, blocks_per_shard, ops


@given(sharded_cache_cases())
@settings(**SETTINGS)
def test_sharded_cache_interleavings(case):
    check_sharded_cache_sequence(*case)


def test_sharded_cache_rejects_swap():
    """Preemption swap is per-shard state the sharded facade does not
    support yet (ServeConfig forbids slo with a mesh); the hooks fail
    loudly rather than corrupting a shard's books."""
    from repro.serving.kv_cache import ShardedPagedKVCache

    serve = ServeConfig(max_slots=2, kv_block_size=4, max_len=8, num_blocks=4,
                        mesh=(("data", 2), ("expert", 1)))
    cache = ShardedPagedKVCache(_cfg(), serve)
    cache.allocate_slot(0, 5)
    cache.ensure_capacity(0, 5)
    with pytest.raises(NotImplementedError):
        cache.swap_footprint(0)
    with pytest.raises(NotImplementedError):
        cache.swap_out(0, None, uid=0, total_len=5, context_len=5)
    cache.free_slot(0)
    cache.check_conservation()


def test_cache_checkers_run_without_hypothesis():
    """Fixed-grid drive of the check_* helpers (mirrors the
    test_plan_properties.py convention)."""
    check_allocator_sequence(8, [(0, 3), (0, 5), (1, 0), (0, 2), (2, 0)])
    check_cache_sequence(2, 4, 8, [
        (0, 0, 15), (1, 0, 10), (2, 0, 3), (1, 0, 15),
        (0, 1, 12), (1, 1, 12), (3, 0, 0), (2, 1, 0), (3, 1, 0)])
    # preemption lifecycle: swap out mid-growth, restore into the other
    # slot, double-swap pressure against a shared host pool
    check_cache_sequence(2, 4, 8, [
        (0, 0, 15), (1, 0, 10), (4, 0, 0),          # out @ 10 tokens
        (0, 0, 12), (1, 0, 12), (5, 1, 0),          # back into slot 1
        (4, 0, 0), (4, 1, 0), (5, 0, 0), (5, 1, 1),
        (3, 0, 0), (3, 1, 0)])
    # sharded pools: fill one shard while the other stays free (the
    # per-shard rejection + aggregate no-starvation witness), then the
    # slot-routed facade over two data shards
    check_sharded_allocator_sequence(2, 4, [
        (0, 0, 4), (0, 0, 1), (0, 1, 2), (1, 0, 0), (0, 0, 3), (2, 1, 0)])
    check_sharded_cache_sequence(2, 2, 4, 4, [
        (0, 0, 15), (1, 0, 10), (0, 2, 9), (1, 2, 6),
        (0, 1, 12), (2, 0, 3), (3, 2, 0), (0, 3, 7), (3, 0, 0), (3, 1, 0)])
