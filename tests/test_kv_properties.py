"""Hypothesis property tests for BlockAllocator / PagedKVCache under
interleaved allocate / grow / truncate / free / swap-out / swap-in
sequences (the lifecycles speculative decoding and SLO preemption
exercise: admission reserves, decode grows, rejection rewinds,
preemption moves blocks to the host pool and restore brings them back,
eviction frees).

Invariants (see kv_cache.py):

* conservation — free + allocated always equals ``num_blocks``, every
  id accounted for exactly once, double-free raises;
* reservation accounting — a slot never holds more blocks than its
  admission-time reservation, total reservations never exceed the
  pool (the no-mid-flight-starvation guarantee), and any growth within
  a reservation succeeds;
* table hygiene — a slot's block-table row mirrors its held blocks
  exactly, everything beyond points at the garbage block (rows never
  dangle into freed storage).

Deterministic golden/edge-case tests live in test_speculative.py; this
module explores the operation-sequence space around them, in the style
of tests/test_plan_properties.py (plain ``check_*`` helpers drive the
invariants so they stay runnable without the hypothesis dependency).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency")
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig, ServeConfig
from repro.serving.kv_cache import BlockAllocator, PagedKVCache

SETTINGS = dict(max_examples=40, deadline=None)


def _cfg():
    return ModelConfig(name="t", family="decoder_lm", num_layers=1,
                       d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                       vocab_size=64, dtype="float32")


# ---------------------------------------------------------------------------
# BlockAllocator: random alloc/free interleavings
# ---------------------------------------------------------------------------

def check_allocator_sequence(num_blocks, ops):
    """ops: list of (kind, amount) with kind 0=alloc, 1=free-oldest,
    2=free-newest.  The model below tracks live allocations; the
    allocator must agree at every step and at the end."""
    a = BlockAllocator(num_blocks)
    live = []
    for kind, amount in ops:
        if kind == 0:
            n = amount % (num_blocks + 2)
            if a.can_alloc(n):
                got = a.alloc(n)
                assert len(got) == n and len(set(got)) == n
                assert all(0 <= b < num_blocks for b in got)
                # ids must not collide with anything still live
                flat = {b for chunk in live for b in chunk}
                assert not (set(got) & flat)
                if got:            # empty chunks have no double-free to detect
                    live.append(got)
            else:
                with pytest.raises(RuntimeError):
                    a.alloc(n)
        elif live:
            chunk = live.pop(0 if kind == 1 else -1)
            a.free(chunk)
            with pytest.raises(RuntimeError):
                a.free(chunk)               # double-free always detected
        a.check_conservation()
        assert a.free_count == num_blocks - sum(len(c) for c in live)
    for chunk in live:
        a.free(chunk)
    a.check_conservation()
    assert a.free_count == num_blocks


@st.composite
def allocator_cases(draw):
    num_blocks = draw(st.integers(1, 24))
    ops = draw(st.lists(
        st.tuples(st.integers(0, 2), st.integers(0, 24)), max_size=40))
    return num_blocks, ops


@given(allocator_cases())
@settings(**SETTINGS)
def test_allocator_interleavings(case):
    check_allocator_sequence(*case)


# ---------------------------------------------------------------------------
# PagedKVCache: admission / growth / truncate / eviction interleavings
# ---------------------------------------------------------------------------

def check_cache_sequence(max_slots, bs, num_blocks, ops):
    """ops: (kind, slot, amount); kind 0=allocate_slot, 1=ensure_capacity,
    2=truncate_slot, 3=free_slot, 4=swap_out, 5=swap_in (the preemption
    lifecycle: a swapped-out slot leaves the device model entirely and
    lives as a host record until restored).  A host-side model of
    per-slot (reserved_len, current_len) decides legality; the cache
    must accept every legal op and keep its invariants after each one."""
    from repro.serving.slo.swap import SwapManager

    serve = ServeConfig(max_slots=max_slots, kv_block_size=bs,
                        max_len=max(num_blocks * bs, 2),
                        num_blocks=num_blocks)
    cache = PagedKVCache(_cfg(), serve)
    swap = SwapManager(cache, host_blocks=num_blocks)
    model = {}                                  # slot -> [total_len, cur_len]
    swapped = []                                # [(rec, total_len, cur_len)]
    next_uid = 0

    def reserved_blocks():
        return sum(-(-t // bs) for t, _ in model.values())

    for kind, slot, amount in ops:
        slot = slot % max_slots
        if kind == 0 and slot not in model:
            total = 1 + amount % serve.max_len
            if cache.can_allocate_slot(total):
                cache.allocate_slot(slot, total)
                model[slot] = [total, 0]
                assert cache.held_blocks(slot) == 0
            else:
                assert reserved_blocks() + -(-total // bs) > num_blocks
        elif kind == 1 and slot in model:
            total, cur = model[slot]
            length = min(1 + amount % serve.max_len, total)
            cache.ensure_capacity(slot, length)
            model[slot][1] = max(cur, length)
            assert cache.held_blocks(slot) == -(-model[slot][1] // bs)
        elif kind == 2 and slot in model:
            total, cur = model[slot]
            new_len = amount % (cur + 1)
            cache.truncate_slot(slot, new_len)
            model[slot][1] = new_len
            assert cache.held_blocks(slot) == (
                -(-new_len // bs) if new_len else 0)
        elif kind == 3 and slot in model:
            cache.free_slot(slot)
            del model[slot]
            assert (cache.block_table[slot] == cache.garbage_block).all()
        elif kind == 4 and slot in model:
            total, cur = model[slot]
            foot = cache.swap_footprint(slot)
            assert foot == -(-cur // bs)
            if swap.can_store(foot):
                rec = cache.swap_out(slot, swap, uid=next_uid,
                                     total_len=total, context_len=cur)
                next_uid += 1
                swapped.append((rec, total, cur))
                del model[slot]
                assert (cache.block_table[slot] == cache.garbage_block).all()
        elif kind == 5 and swapped and slot not in model:
            rec, total, cur = swapped[amount % len(swapped)]
            if cache.can_restore(rec):
                swapped.remove((rec, total, cur))
                resume = cache.restore_slot(slot, rec, swap)
                swap.release(rec)
                assert resume == cur        # plain paged: always a full restore
                model[slot] = [total, cur]
                assert cache.held_blocks(slot) == -(-cur // bs)
            else:
                assert (reserved_blocks() + -(-total // bs)) > num_blocks
        cache.check_conservation()
        swap.check_conservation()
        assert cache.reserved_total == reserved_blocks()
        assert cache.reserved_total <= num_blocks
        held = sum(-(-cur // bs) for _, cur in model.values())
        assert cache.allocator.free_count == num_blocks - held
        assert swap.used_host_blocks == sum(
            -(-cur // bs) for _, _, cur in swapped)
    for slot in list(model):
        cache.free_slot(slot)
    for rec, _, _ in swapped:
        swap.release(rec)
    cache.check_conservation()
    swap.check_conservation()
    assert cache.allocator.free_count == num_blocks
    assert cache.reserved_total == 0
    assert swap.used_host_blocks == 0
    assert (cache.block_table == cache.garbage_block).all()


@st.composite
def cache_cases(draw):
    max_slots = draw(st.integers(1, 4))
    bs = draw(st.sampled_from([1, 4, 8]))
    num_blocks = draw(st.integers(1, 24))
    ops = draw(st.lists(
        st.tuples(st.integers(0, 5), st.integers(0, 3), st.integers(0, 256)),
        max_size=50))
    return max_slots, bs, num_blocks, ops


@given(cache_cases())
@settings(**SETTINGS)
def test_cache_interleavings(case):
    check_cache_sequence(*case)


# ---------------------------------------------------------------------------
# PrefixCachingKVCache: share / diverge / evict-under-pressure / COW
# ---------------------------------------------------------------------------

# The checker lives in test_prefix_cache.py (with the deterministic
# goldens and a fixed-grid drive) so it stays runnable without the
# hypothesis dependency; this module only adds the randomised search.
from test_prefix_cache import check_prefix_sequence


@st.composite
def prefix_cases(draw):
    max_slots = draw(st.integers(1, 4))
    bs = draw(st.sampled_from([2, 4]))
    num_blocks = draw(st.integers(2, 24))
    ops = draw(st.lists(
        st.tuples(st.integers(0, 3), st.integers(0, 3), st.integers(0, 512)),
        max_size=50))
    return max_slots, bs, num_blocks, ops


@given(prefix_cases())
@settings(**SETTINGS)
def test_prefix_cache_interleavings(case):
    check_prefix_sequence(*case)


def test_cache_checkers_run_without_hypothesis():
    """Fixed-grid drive of the check_* helpers (mirrors the
    test_plan_properties.py convention)."""
    check_allocator_sequence(8, [(0, 3), (0, 5), (1, 0), (0, 2), (2, 0)])
    check_cache_sequence(2, 4, 8, [
        (0, 0, 15), (1, 0, 10), (2, 0, 3), (1, 0, 15),
        (0, 1, 12), (1, 1, 12), (3, 0, 0), (2, 1, 0), (3, 1, 0)])
    # preemption lifecycle: swap out mid-growth, restore into the other
    # slot, double-swap pressure against a shared host pool
    check_cache_sequence(2, 4, 8, [
        (0, 0, 15), (1, 0, 10), (4, 0, 0),          # out @ 10 tokens
        (0, 0, 12), (1, 0, 12), (5, 1, 0),          # back into slot 1
        (4, 0, 0), (4, 1, 0), (5, 0, 0), (5, 1, 1),
        (3, 0, 0), (3, 1, 0)])
