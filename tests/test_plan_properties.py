"""Hypothesis property tests for the RoutingPlan contract.

For every registered router and randomly drawn shapes, assert the
invariants all dispatch backends rely on (see routers/base.py):

* ``expert_index`` in range, ``slot_index`` unique per (group, expert);
* gates non-negative, zero on invalid choices, and renormalised to sum
  to 1 per token when ``normalize_gates=True``;
* token-permutation equivariance of the routing *decision* (which
  experts, which gates) — slot assignment is first-come and therefore
  order-dependent, so it is checked only in the no-overflow regime;
* the dense ``combine``/``dispatch`` scatter views agree with the index
  view entry by entry;
* the sorted/ragged view conserves the valid choices exactly (the
  dropless backend's correctness precondition).

Deterministic golden/edge-case tests live in test_routers.py; this
module explores the shape/seed space around them.
"""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency")
from hypothesis import given, settings, strategies as st

# The invariant logic lives in plain `check_*` helpers (callable without
# hypothesis — scripts/dev boxes without the dependency can drive them
# over a fixed grid); the test_* wrappers below add the randomised
# search.

from repro.configs.base import MoEConfig
from repro.core.context import MoEContext
from repro.core.routers import get_router
from repro.core.routing import route

ALL_ROUTERS = ("topk", "prototype", "expert_choice", "hash")
SETTINGS = dict(max_examples=15, deadline=None)


def _cfg(routing, E, k, **kw):
    base = dict(num_experts=E, routing=routing, top_k=k, aux_loss_coef=0.01)
    if routing == "prototype":
        # Z prototypes of E/Z experts; k' choices inside each
        base.update(num_prototypes=2 if E % 2 == 0 else 1,
                    prototype_top_k=min(k, E // (2 if E % 2 == 0 else 1)))
    base.update(kw)
    return MoEConfig(**base)


def _route(routing, m, x, capacity, ids=None):
    router = get_router(routing)
    spec = router.param_spec(m, x.shape[-1], jax.nn.initializers.normal(1.0))
    w = None
    if spec is not None:
        w = jax.random.normal(jax.random.PRNGKey(7), spec.shape)
    ctx = None
    if ids is not None:
        ctx = MoEContext(token_ids=ids)
    return route(x, w, m, capacity, ctx=ctx)


@st.composite
def plan_cases(draw):
    routing = draw(st.sampled_from(ALL_ROUTERS))
    E = draw(st.sampled_from([2, 4, 8]))
    G = draw(st.integers(1, 2))
    T = draw(st.integers(3, 24))
    k = draw(st.integers(1, min(E, 3)))
    cap = draw(st.integers(1, T))
    seed = draw(st.integers(0, 2**16))
    return routing, G, T, E, k, cap, seed


def check_index_view_invariants(case):
    routing, G, T, E, k, cap, seed = case
    m = _cfg(routing, E, k)
    x = jax.random.normal(jax.random.PRNGKey(seed), (G, T, 12))
    ids = jax.random.randint(jax.random.PRNGKey(seed + 1), (G, T), 0, 97)
    plan = _route(routing, m, x, cap, ids=ids)

    e = np.asarray(plan.expert_index)
    s = np.asarray(plan.slot_index)
    v = np.asarray(plan.valid)
    g = np.asarray(plan.masked_gate)
    assert ((e >= 0) & (e < plan.num_experts)).all()
    assert (g >= 0).all() and (g[~v] == 0).all()
    assert (s[v] < plan.capacity).all()
    # each valid (expert, slot) pair is unique within a group
    for gi in range(G):
        pairs = np.stack([e[gi][v[gi]], s[gi][v[gi]]], -1)
        assert len(np.unique(pairs, axis=0)) == len(pairs)
    # per-expert load never exceeds capacity * groups
    loads = np.asarray(plan.metrics["expert_loads"])
    assert loads.max() <= plan.capacity * G + 1e-6
    assert 0.0 <= float(plan.metrics["dropped_fraction"]) <= 1.0


def check_normalized_gates_sum_to_one(case):
    routing, G, T, E, k, cap, seed = case
    m = _cfg(routing, E, k, normalize_gates=True)
    x = jax.random.normal(jax.random.PRNGKey(seed), (G, T, 12))
    ids = jax.random.randint(jax.random.PRNGKey(seed + 1), (G, T), 0, 97)
    plan = _route(routing, m, x, cap, ids=ids)
    mass = np.asarray(plan.masked_gate.sum(-1))
    has_any = np.asarray(plan.valid.any(-1))
    np.testing.assert_allclose(mass[has_any], 1.0, rtol=1e-5)
    np.testing.assert_allclose(mass[~has_any], 0.0, atol=1e-7)


def check_token_permutation_equivariance(case):
    """Permuting the tokens of a group permutes the routing decision:
    expert choices and gates follow their token.  Checked with capacity
    >= T (no overflow), because slot assignment — and with it `valid` —
    is first-come within the group by design."""
    routing, G, T, E, k, _, seed = case
    m = _cfg(routing, E, k)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, T, 12))
    ids = jax.random.randint(jax.random.PRNGKey(seed + 1), (1, T), 0, 97)
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 2), T)
    plan = _route(routing, m, x, T, ids=ids)
    plan_p = _route(routing, m, x[:, perm], T, ids=ids[:, perm])

    e0 = np.asarray(plan.expert_index)[0][np.asarray(perm)]
    g0 = np.asarray(plan.masked_gate)[0][np.asarray(perm)]
    v0 = np.asarray(plan.valid)[0][np.asarray(perm)]
    np.testing.assert_array_equal(np.asarray(plan_p.expert_index)[0], e0)
    np.testing.assert_array_equal(np.asarray(plan_p.valid)[0], v0)
    np.testing.assert_allclose(np.asarray(plan_p.masked_gate)[0], g0,
                               atol=1e-6)


def check_dense_views_consistent_with_index_view(case):
    routing, G, T, E, k, cap, seed = case
    m = _cfg(routing, E, k)
    x = jax.random.normal(jax.random.PRNGKey(seed), (G, T, 12))
    plan = _route(routing, m, x, cap)

    combine = np.asarray(plan.combine)
    dispatch = np.asarray(plan.dispatch)
    assert combine.shape == (*plan.expert_index.shape[:2], E, plan.capacity)
    assert ((combine > 0) == dispatch).all()
    assert (dispatch.sum(axis=1) <= 1).all()          # slot occupancy
    # entry-by-entry: scatter the index view by hand
    want = np.zeros_like(combine)
    e = np.asarray(plan.expert_index)
    s = np.asarray(plan.slot_index)
    v = np.asarray(plan.valid)
    g = np.asarray(plan.masked_gate)
    for gi, ti, ki in zip(*np.nonzero(v)):
        want[gi, ti, e[gi, ti, ki], s[gi, ti, ki]] += g[gi, ti, ki]
    np.testing.assert_allclose(combine, want, atol=1e-6)


def check_ragged_view_conserves_valid_choices(case, bx):
    """The dropless precondition, over random shapes and block sizes:
    the ragged view is exactly the multiset of valid (expert, token,
    gate) choices, each in its block-aligned expert segment."""
    routing, G, T, E, k, cap, seed = case
    m = _cfg(routing, E, k)
    x = jax.random.normal(jax.random.PRNGKey(seed), (G, T, 12))
    plan = _route(routing, m, x, cap)
    rag = plan.ragged(block_rows=bx)

    e = np.asarray(plan.expert_index)
    v = np.asarray(plan.valid)
    g = np.asarray(plan.masked_gate)
    tok = np.asarray(rag.token)
    gate = np.asarray(rag.gate)
    off = np.asarray(rag.expert_offsets)
    for gi in range(G):
        tv, kv = np.nonzero(v[gi])
        want = sorted(zip(e[gi][tv, kv], tv, np.round(g[gi][tv, kv], 5)))
        rows = np.nonzero(tok[gi] >= 0)[0]
        row_e = np.searchsorted(off[gi], rows, side="right") - 1
        got = sorted(zip(row_e, tok[gi][rows], np.round(gate[gi][rows], 5)))
        assert got == want
        assert (off[gi] % bx == 0).all()
        assert (gate[gi][tok[gi] < 0] == 0.0).all()


@given(plan_cases())
@settings(**SETTINGS)
def test_index_view_invariants(case):
    check_index_view_invariants(case)


@given(plan_cases())
@settings(**SETTINGS)
def test_normalized_gates_sum_to_one(case):
    check_normalized_gates_sum_to_one(case)


@given(plan_cases())
@settings(**SETTINGS)
def test_token_permutation_equivariance(case):
    check_token_permutation_equivariance(case)


@given(plan_cases())
@settings(**SETTINGS)
def test_dense_views_consistent_with_index_view(case):
    check_dense_views_consistent_with_index_view(case)


@given(plan_cases(), st.sampled_from([2, 4, 16]))
@settings(**SETTINGS)
def test_ragged_view_conserves_valid_choices(case, bx):
    check_ragged_view_conserves_valid_choices(case, bx)
