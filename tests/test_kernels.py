"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moe_ffn.ops import moe_ffn
from repro.kernels.moe_ffn.ref import moe_ffn_ref

MOE_CASES = [
    # (E, X, M, I, act, dtype)
    (4, 64, 32, 48, "swiglu", jnp.float32),
    (2, 100, 64, 96, "gelu", jnp.float32),       # row padding path
    (3, 128, 128, 256, "swiglu", jnp.bfloat16),
    (1, 8, 16, 512, "relu", jnp.float32),
    (8, 32, 64, 64, "swiglu", jnp.bfloat16),
    (2, 256, 32, 40, "gelu", jnp.float32),        # I not a power of two
]


@pytest.mark.parametrize("E,X,M,I,act,dt", MOE_CASES)
def test_moe_ffn_kernel_allclose(E, X, M, I, act, dt):
    ks = jax.random.split(jax.random.PRNGKey(E * X + I), 4)
    x = jax.random.normal(ks[0], (E, X, M), dt)
    wu = (jax.random.normal(ks[1], (E, M, I), dt) * 0.1).astype(dt)
    wg = (jax.random.normal(ks[2], (E, M, I), dt) * 0.1).astype(dt) if act == "swiglu" else None
    wd = (jax.random.normal(ks[3], (E, I, M), dt) * 0.1).astype(dt)
    y = moe_ffn(x, wu, wg, wd, act)
    yr = moe_ffn_ref(x, wu, wg, wd, act)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32),
                               atol=tol, rtol=tol)


FLASH_CASES = [
    (2, 128, 4, 2, 32, True, jnp.float32),
    (1, 96, 8, 8, 16, True, jnp.float32),
    (2, 64, 4, 1, 64, False, jnp.float32),
    (1, 256, 4, 2, 32, True, jnp.bfloat16),
    (1, 80, 2, 2, 128, True, jnp.float32),        # non-pow2 seq
]


@pytest.mark.parametrize("B,S,Hq,Hkv,D,causal,dt", FLASH_CASES)
def test_flash_attention_kernel_allclose(B, S, Hq, Hkv, D, causal, dt):
    ks = jax.random.split(jax.random.PRNGKey(B * S + D), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dt)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dt)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dt)
    o = flash_attention(q, k, v, causal=causal, block_q=64, block_kv=32)
    r = attention_ref(q, k, v, causal=causal)
    tol = 3e-2 if dt == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(r, np.float32),
                               atol=tol)


def test_chunked_attention_grads_match_reference():
    from repro.models.chunked_attention import chunked_attention

    B, S, Hkv, G, D = 2, 40, 2, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hkv * G, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))

    def f_chunk(q_, k_, v_):
        return chunked_attention(q_.reshape(B, S, Hkv, G, D), k_, v_, True, 0, 16, 0.0).sum()

    def f_ref(q_, k_, v_):
        return attention_ref(q_, k_, v_, causal=True).astype(jnp.float32).sum()

    g1 = jax.grad(f_chunk, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


DECODE_CASES = [
    (2, 128, 8, 2, 32, jnp.float32),
    (1, 96, 4, 4, 16, jnp.float32),
    (3, 256, 8, 1, 64, jnp.bfloat16),
    (2, 80, 2, 2, 128, jnp.float32),      # non-pow2 cache length
]


@pytest.mark.parametrize("B,T,Hq,Hkv,D,dt", DECODE_CASES)
def test_decode_attention_kernel_allclose(B, T, Hq, Hkv, D, dt):
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref

    ks = jax.random.split(jax.random.PRNGKey(B * T + D), 4)
    q = jax.random.normal(ks[0], (B, Hq, D), dt)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), dt)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), dt)
    lengths = jax.random.randint(ks[3], (B,), 1, T + 1)
    o = decode_attention(q, k, v, lengths, block_kv=32)
    r = decode_attention_ref(q, k, v, lengths)
    tol = 3e-2 if dt == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(r, np.float32),
                               atol=tol)


# ---------------------------------------------------------------------------
# Ragged grouped FFN (dropless): kernel in interpret mode vs the
# sorted-gather reference, including the scalar-prefetch expert lookup.
# ---------------------------------------------------------------------------

RAGGED_CASES = [
    # (E, NB, bx, M, I, act, dtype)
    (4, 6, 8, 32, 48, "swiglu", jnp.float32),
    (2, 4, 16, 64, 96, "gelu", jnp.float32),
    (3, 5, 8, 16, 40, "relu", jnp.float32),       # I not a power of two
    (8, 8, 8, 64, 64, "swiglu", jnp.bfloat16),
]


@pytest.mark.parametrize("E,NB,bx,M,I,act,dt", RAGGED_CASES)
def test_ragged_ffn_kernel_allclose(E, NB, bx, M, I, act, dt):
    from repro.kernels.moe_dropless.kernel import ragged_ffn_kernel
    from repro.kernels.moe_dropless.ref import ragged_ffn_ref

    ks = jax.random.split(jax.random.PRNGKey(E * NB + I), 5)
    x = jax.random.normal(ks[0], (NB * bx, M), dt)
    wu = (jax.random.normal(ks[1], (E, M, I), dt) * 0.1).astype(dt)
    wg = (jax.random.normal(ks[2], (E, M, I), dt) * 0.1).astype(dt) if act == "swiglu" else None
    wd = (jax.random.normal(ks[3], (E, I, M), dt) * 0.1).astype(dt)
    be = jax.random.randint(ks[4], (NB,), 0, E, jnp.int32)
    bi = I
    while bi > 1 and I % bi:
        bi //= 2
    y = ragged_ffn_kernel(x, be, wu, wg, wd, act, block_x=bx, block_i=bi,
                          interpret=True)
    yr = ragged_ffn_ref(x, be, wu, wg, wd, act)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32),
                               atol=tol, rtol=tol)


def test_ragged_ffn_custom_vjp_trains():
    """ragged_ffn is differentiable (reference backward through the
    custom_vjp; block_expert is integer metadata with a float0 tangent)."""
    from repro.kernels.moe_dropless.ops import ragged_ffn
    from repro.kernels.moe_dropless.ref import ragged_ffn_ref

    E, NB, bx, M, I = 3, 4, 8, 16, 24
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (NB * bx, M))
    wu = jax.random.normal(ks[1], (E, M, I)) * 0.1
    wg = jax.random.normal(ks[2], (E, M, I)) * 0.1
    wd = jax.random.normal(ks[3], (E, I, M)) * 0.1
    be = jax.random.randint(ks[4], (NB,), 0, E, jnp.int32)

    def loss(fn, x, wu, wg, wd):
        return jnp.sum(fn(x, be, wu, wg, wd, "swiglu") ** 2)

    g = jax.grad(lambda *a: loss(ragged_ffn, *a), argnums=(0, 1, 2, 3))(x, wu, wg, wd)
    gr = jax.grad(lambda *a: loss(
        lambda x, be, u, g_, d, act: ragged_ffn_ref(x, be, u, g_, d, act),
        *a), argnums=(0, 1, 2, 3))(x, wu, wg, wd)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
