"""Training stack: optimizers, compression, checkpoint/restart, fault
tolerance, Adafactor memory sublinearity."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig, TrainConfig
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import SyntheticLM
from repro.distributed.fault import StepWatchdog, run_with_restarts
from repro.models.registry import get_family
from repro.nn import init
from repro.optim import make_optimizer, warmup_constant
from repro.train.state import init_train_state
from repro.train.trainer import make_train_step


def _tiny_cfg():
    return ModelConfig(num_layers=2, d_model=48, num_heads=4, num_kv_heads=2,
                       d_ff=64, vocab_size=101, dtype="float32",
                       moe=MoEConfig(num_experts=4, routing="prototype",
                                     num_prototypes=2, group_size=64))


def _setup(tc, cfg=None, seed=0):
    cfg = cfg or _tiny_cfg()
    fam = get_family(cfg)
    params = init(fam.specs(cfg), jax.random.PRNGKey(seed))
    opt = make_optimizer(tc, warmup_constant(tc.learning_rate, tc.warmup_steps))
    state = init_train_state(params, opt, tc.grad_compression)
    step = jax.jit(make_train_step(cfg, tc, opt))
    return cfg, state, step


@pytest.mark.parametrize("opt,lr", [("adamw", 1e-2), ("adafactor", 1e-1)])
def test_loss_decreases(opt, lr):
    tc = TrainConfig(optimizer=opt, learning_rate=lr, warmup_steps=5)
    cfg, state, step = _setup(tc)
    pipe = SyntheticLM(cfg.vocab_size, batch=8, seq_len=32, seed=0)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    first = last = None
    for i in range(25):
        state, m = step(state, batch)
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < first * 0.9


def test_microbatch_equivalence():
    """grad accumulation over 2 microbatches == full batch (linear grads)."""
    tc1 = TrainConfig(optimizer="adamw", learning_rate=1e-3, microbatches=1)
    tc2 = TrainConfig(optimizer="adamw", learning_rate=1e-3, microbatches=2)
    cfg, state1, step1 = _setup(tc1)
    _, state2, step2 = _setup(tc2)
    pipe = SyntheticLM(cfg.vocab_size, batch=8, seq_len=16, seed=1)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    s1, m1 = step1(state1, batch)
    s2, m2 = step2(state2, batch)
    # parameters end up close (not exact: loss normalisation per microbatch)
    d = jax.tree_util.tree_map(lambda a, b: float(jnp.abs(a - b).max()),
                               s1.params, s2.params)
    assert max(jax.tree_util.tree_leaves(d)) < 5e-4


def test_adafactor_state_sublinear():
    cfg = _tiny_cfg()
    fam = get_family(cfg)
    params = init(fam.specs(cfg), jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree_util.tree_leaves(params))
    ada = make_optimizer(TrainConfig(optimizer="adafactor"), warmup_constant(1e-3))
    adam = make_optimizer(TrainConfig(optimizer="adamw"), warmup_constant(1e-3))
    n_ada = sum(s.size for s in jax.tree_util.tree_leaves(ada.init(params)))
    n_adam = sum(s.size for s in jax.tree_util.tree_leaves(adam.init(params)))
    assert n_adam == 2 * n_params
    assert n_ada < 0.25 * n_adam  # sublinear second moments


def test_checkpoint_restart_exact_resume():
    """Train 6 steps straight == train 3, checkpoint, restore, train 3."""
    tc = TrainConfig(optimizer="adamw", learning_rate=1e-3)
    cfg, state, step = _setup(tc)
    pipe = SyntheticLM(cfg.vocab_size, batch=4, seq_len=16, seed=2)
    batches = [{k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()} for i in range(6)]

    s = state
    for i in range(6):
        s, _ = step(s, batches[i])
    straight = s

    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        s = state
        for i in range(3):
            s, _ = step(s, batches[i])
        ck.save(3, s)
        template = jax.eval_shape(lambda: s)
        restored = ck.restore(3, template)
        for i in range(3, 6):
            restored, _ = step(restored, batches[i])

    for a, b in zip(jax.tree_util.tree_leaves(straight.params),
                    jax.tree_util.tree_leaves(restored.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_checkpoint_async_and_keep_last():
    tc = TrainConfig()
    cfg, state, step = _setup(tc)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        for s_i in [1, 2, 3, 4]:
            ck.save_async(s_i, {"x": jnp.full((4,), s_i)})
        ck.wait()
        assert ck.all_steps() == [3, 4]
        got = ck.restore(4, jax.eval_shape(lambda: {"x": jnp.zeros((4,))}))
        np.testing.assert_array_equal(np.asarray(got["x"]), 4.0)


def test_run_with_restarts_resumes_after_failure():
    attempts = []

    def resume():
        return len(attempts)  # "latest checkpoint" advances per attempt

    def loop(start):
        attempts.append(start)
        if len(attempts) < 3:
            raise RuntimeError("simulated worker failure")
        return 99

    assert run_with_restarts(loop, resume, max_restarts=5) == 99
    assert attempts == [0, 1, 2]


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(threshold=2.0, warmup=2)
    for _ in range(10):
        wd.observe(1.0)
    assert wd.observe(5.0) is True
    assert wd.straggler_events == 1
    assert wd.observe(1.0) is False


def test_grad_compression_int8_error_feedback_converges():
    tc = TrainConfig(optimizer="adamw", learning_rate=1e-2, grad_compression="int8")
    cfg, state, step = _setup(tc)
    pipe = SyntheticLM(cfg.vocab_size, batch=8, seq_len=32, seed=3)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    first = last = None
    # 35 steps: at 25 this sits right on the 10% bar on some jax/XLA
    # versions (9.8% on jax 0.4.37 CPU) — headroom, not a weaker claim.
    for _ in range(35):
        state, m = step(state, batch)
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < first * 0.9
