"""Router API: registry, RoutingPlan invariants, golden values, and the
structural guarantee that index-view paths never build (G,T,E,C) tensors.

Shared config/batch factories and the jaxpr structural probe live in
conftest.py; `plan_for` builds a plan the way the layer would."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.core.moe import group_tokens, moe_ffn_apply, moe_ffn_specs
from repro.core.routers import available_routers, get_router, register_router
from repro.core.routers.expert_choice import expert_choice_plan
from repro.core.routers.hashed import hash_plan
from repro.core.routing import route
from repro.nn import init

ALL_ROUTERS = ("topk", "prototype", "expert_choice", "hash")


def plan_for(m, G=2, T=24, M=16, capacity=8, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), (G, T, M))
    router = get_router(m.routing)
    spec = router.param_spec(m, M, jax.nn.initializers.normal(1.0))
    w = None
    if spec is not None:
        w = jax.random.normal(jax.random.PRNGKey(seed + 1), spec.shape)
    return route(x, w, m, capacity)


class TestRegistry:
    def test_builtin_keys(self):
        assert set(ALL_ROUTERS) <= set(available_routers())

    def test_unknown_key_lists_registry(self):
        with pytest.raises(ValueError, match="expert_choice.*topk"):
            get_router("nope")

    def test_config_validates_routing_key(self):
        with pytest.raises(ValueError, match="unknown routing mode"):
            MoEConfig(num_experts=4, routing="definitely-not-registered")
        # dense configs (num_experts=0) skip validation entirely
        MoEConfig(num_experts=0, routing="whatever")

    def test_plugin_registration(self):
        from repro.core.routers import _REGISTRY
        from repro.core.routers.topk import TopKRouter

        try:
            @register_router
            class MyRouter(TopKRouter):
                name = "my_plugin"

            assert get_router("my_plugin").name == "my_plugin"
            # config construction now accepts the plugin key
            MoEConfig(num_experts=4, routing="my_plugin")
        finally:
            _REGISTRY.pop("my_plugin", None)


class TestPlanInvariants:
    """The RoutingPlan contract every router must uphold."""

    @pytest.mark.parametrize("routing", ALL_ROUTERS)
    def test_index_view_contract(self, routing, moe_cfg):
        m = moe_cfg(routing)
        plan = plan_for(m)
        G, T, K = plan.expert_index.shape
        e = np.asarray(plan.expert_index)
        s = np.asarray(plan.slot_index)
        v = np.asarray(plan.valid)
        g = np.asarray(plan.masked_gate)
        assert ((e >= 0) & (e < plan.num_experts)).all()
        assert (s[v] < plan.capacity).all()          # valid => in capacity
        assert (g >= 0).all() and (g[~v] == 0).all()
        # per-token gate mass: one unit of softmax mass per independent
        # routing distribution (Z for prototyping, 1 otherwise)
        mass = m.num_prototypes if routing == "prototype" else 1
        assert g.sum(-1).max() <= mass + 1e-5
        # each valid (expert, slot) pair is unique within a group
        for gi in range(G):
            pairs = np.stack([e[gi][v[gi]], s[gi][v[gi]]], -1)
            assert len(np.unique(pairs, axis=0)) == len(pairs)

    @pytest.mark.parametrize("routing", ALL_ROUTERS)
    def test_dense_views_agree_with_index_view(self, routing, moe_cfg):
        plan = plan_for(moe_cfg(routing))
        combine = np.asarray(plan.combine)
        dispatch = np.asarray(plan.dispatch)
        assert combine.shape == (*plan.expert_index.shape[:2],
                                 plan.num_experts, plan.capacity)
        assert ((combine > 0) == dispatch).all()
        assert (dispatch.sum(axis=1) <= 1).all()     # slot occupancy
        # loads computed from the index view == loads from the dense view
        np.testing.assert_array_equal(
            np.asarray(plan.metrics["expert_loads"]),
            dispatch.sum(axis=(0, 1, 3)).astype(np.float32))

    @pytest.mark.parametrize("routing", ALL_ROUTERS)
    def test_plan_crosses_jit_boundary(self, routing, moe_cfg):
        """RoutingPlan is a registered pytree with static shape metadata,
        so route() can be jitted directly (as RoutingResult could)."""
        m = moe_cfg(routing)
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 16, 12))
        router = get_router(routing)
        spec = router.param_spec(m, 12, jax.nn.initializers.normal(1.0))
        w = None if spec is None else jax.random.normal(jax.random.PRNGKey(1),
                                                        spec.shape)
        plan = jax.jit(lambda xx, ww: route(xx, ww, m, 8))(x, w)
        assert plan.num_experts == m.num_experts and plan.capacity == 8
        assert plan.combine.shape == (1, 16, m.num_experts, 8)

    @pytest.mark.parametrize("routing", ["topk", "prototype"])
    def test_normalize_gates_sums_to_one(self, routing, moe_cfg):
        m = moe_cfg(routing, normalize_gates=True)
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 16, 12))
        router = get_router(routing)
        spec = router.param_spec(m, 12, jax.nn.initializers.normal(1.0))
        w = jax.random.normal(jax.random.PRNGKey(1), spec.shape)
        plan = route(x, w, m, capacity=16)
        # every token with >= 1 kept choice has its gates renormalised to 1
        mass = np.asarray(plan.masked_gate.sum(-1))
        has_any = np.asarray(plan.valid.any(-1))
        np.testing.assert_allclose(mass[has_any], 1.0, rtol=1e-5)

    @pytest.mark.parametrize("routing", ALL_ROUTERS)
    def test_capacity_overflow_marks_invalid(self, routing, moe_cfg):
        plan = plan_for(moe_cfg(routing), T=32, capacity=2)
        s = np.asarray(plan.slot_index)
        v = np.asarray(plan.valid)
        assert (~v[s >= 2]).all()
        # loads aggregate over groups; capacity binds per group
        loads = np.asarray(plan.metrics["expert_loads"])
        assert loads.max() <= 2 * plan.expert_index.shape[0]


class TestExpertChoiceGolden:
    def test_each_expert_fills_exactly_c(self):
        # 3 tokens, 2 experts, capacity 2: 4 slots > 3 tokens, so some
        # token must be picked twice — expert-choice's signature behavior.
        logits = jnp.array([[[1.0, 0.0], [0.5, 0.0], [0.0, 1.0]]])
        m = MoEConfig(num_experts=2, routing="expert_choice", top_k=2)
        plan = expert_choice_plan(logits, m, capacity=2)
        scores = np.asarray(jax.nn.softmax(logits, -1))[0]
        v = np.asarray(plan.valid)[0]                # (T=3, E=2)
        s = np.asarray(plan.slot_index)[0]
        # expert 0 ranks tokens 0 > 1 > 2; expert 1 ranks 2 > 1 > 0
        np.testing.assert_array_equal(v, [[True, False],
                                          [True, True],
                                          [False, True]])
        assert s[0, 0] == 0 and s[1, 0] == 1         # expert 0: t0 then t1
        assert s[2, 1] == 0 and s[1, 1] == 1         # expert 1: t2 then t1
        np.testing.assert_allclose(np.asarray(plan.masked_gate)[0][v],
                                   scores[v], rtol=1e-6)
        # structural balance: every expert exactly full, cv == 0, no aux
        np.testing.assert_array_equal(np.asarray(plan.metrics["expert_loads"]),
                                      [2.0, 2.0])
        assert float(plan.metrics["cv"]) == pytest.approx(0.0, abs=1e-6)
        assert float(plan.aux_loss) == 0.0

    def test_unpicked_tokens_reported_dropped(self):
        # 4 tokens, 2 experts, capacity 1: only 2 picks -> 2 tokens unrouted
        logits = jnp.array([[[1.0, 0.0], [0.8, 0.0], [0.0, 1.0], [0.0, 0.8]]])
        m = MoEConfig(num_experts=2, routing="expert_choice", top_k=1)
        plan = expert_choice_plan(logits, m, capacity=1)
        v = np.asarray(plan.valid)[0]
        np.testing.assert_array_equal(v.any(-1), [True, False, True, False])
        assert float(plan.metrics["dropped_fraction"]) == pytest.approx(0.5)

    def test_capacity_clamped_to_tokens(self):
        # capacity > T must not break top_k over the token axis
        logits = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 2))
        m = MoEConfig(num_experts=2, routing="expert_choice", top_k=1)
        plan = expert_choice_plan(logits, m, capacity=16)
        assert np.asarray(plan.metrics["expert_loads"]).max() <= 4


class TestHashGolden:
    def test_deterministic_assignment(self):
        m = MoEConfig(num_experts=4, routing="hash", top_k=1)
        plan = hash_plan(1, 8, m, capacity=4)
        # golden snapshot: fixed integer mix, stable across runs/platforms
        np.testing.assert_array_equal(
            np.asarray(plan.expert_index)[0, :, 0], [0, 0, 1, 1, 1, 2, 3, 2])
        np.testing.assert_array_equal(
            np.asarray(plan.slot_index)[0, :, 0], [0, 1, 0, 1, 2, 0, 0, 1])
        np.testing.assert_array_equal(
            np.asarray(plan.metrics["expert_loads"]), [2.0, 3.0, 2.0, 1.0])

    def test_k_choices_are_distinct_experts(self):
        m = MoEConfig(num_experts=4, routing="hash", top_k=2)
        plan = hash_plan(2, 16, m, capacity=16)
        e = np.asarray(plan.expert_index)
        assert (e[..., 0] != e[..., 1]).all()
        # uniform average gates: 1/k each, summing to 1 per token
        np.testing.assert_allclose(np.asarray(plan.gate), 0.5)

    def test_identical_tokens_route_identically(self):
        """True Hash Layers: token *identity* decides the experts, so
        every occurrence of a token id routes the same way regardless of
        its position (position hashing cannot do this)."""
        m = MoEConfig(num_experts=4, routing="hash", top_k=2)
        ids = jnp.array([[5, 9, 5, 3, 9, 5, 3, 5]], jnp.int32)
        plan = hash_plan(1, 8, m, capacity=8, token_ids=ids)
        e = np.asarray(plan.expert_index)[0]                 # (T, k)
        per_id = {}
        for tid in (3, 5, 9):
            rows = e[np.asarray(ids)[0] == tid]
            assert (rows == rows[0]).all(), tid              # within the batch
            per_id[tid] = rows[0]
        # ... and across completely different position layouts
        ids2 = jnp.array([[1, 3, 1, 5, 9, 1, 1, 5]], jnp.int32)
        plan2 = hash_plan(1, 8, m, capacity=8, token_ids=ids2)
        e2 = np.asarray(plan2.expert_index)[0]
        for tid in (3, 5, 9):
            rows2 = e2[np.asarray(ids2)[0] == tid]
            np.testing.assert_array_equal(rows2[0], per_id[tid])
        # the position hash would NOT be constant per id here
        pos_plan = hash_plan(1, 8, m, capacity=8)
        ep = np.asarray(pos_plan.expert_index)[0]
        assert not all((ep[np.asarray(ids)[0] == t] ==
                        ep[np.asarray(ids)[0] == t][0]).all() for t in (5, 9))

    def test_unknown_ids_fall_back_to_position_hash(self):
        """Rows with token_id < 0 (e.g. image-patch prefix embeddings)
        use the position hash; known rows use the identity hash."""
        m = MoEConfig(num_experts=4, routing="hash", top_k=1)
        ids = jnp.array([[-1, -1, 7, 7, -1, 7, -1, 7]], jnp.int32)
        plan = hash_plan(1, 8, m, capacity=8, token_ids=ids)
        pos_plan = hash_plan(1, 8, m, capacity=8)
        e = np.asarray(plan.expert_index)[0, :, 0]
        ep = np.asarray(pos_plan.expert_index)[0, :, 0]
        mask = np.asarray(ids)[0] < 0
        np.testing.assert_array_equal(e[mask], ep[mask])     # fallback rows
        assert (e[~mask] == e[~mask][0]).all()               # identity rows

    def test_position_fallback_is_layout_invariant(self):
        """With absolute positions, the fallback hash is consistent
        between a prefill-style group layout and single-token decode
        steps: sequence position p routes identically in both."""
        m = MoEConfig(num_experts=4, routing="hash", top_k=1)
        pos = jnp.arange(8, dtype=jnp.int32)[None, :]
        prefill = hash_plan(1, 8, m, capacity=8, positions=pos)
        pe = np.asarray(prefill.expert_index)[0, :, 0]
        for p in range(8):
            step = hash_plan(1, 1, m, capacity=1,
                             positions=jnp.array([[p]], jnp.int32))
            assert int(step.expert_index[0, 0, 0]) == int(pe[p]), p

    def test_stateless_no_router_param(self):
        cfg = ModelConfig(d_model=16, d_ff=32, dtype="float32",
                          moe=MoEConfig(num_experts=4, routing="hash",
                                        top_k=1, group_size=32))
        specs = moe_ffn_specs(cfg)
        assert "router" not in specs
        params = init(specs, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
        y, aux = jax.jit(lambda p, x: moe_ffn_apply(p, x, cfg))(params, x)
        assert y.shape == x.shape and not bool(jnp.isnan(y).any())
        assert float(aux["moe_aux_loss"]) == 0.0


# ---------------------------------------------------------------------------
# Structural guarantee: index-view paths never materialise (G,T,E,C)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("routing", ALL_ROUTERS)
def test_gather_path_has_no_dense_intermediate(routing, moe_model_cfg,
                                               toy_batch, dense_shape_present):
    cfg = moe_model_cfg(routing, impl="gather")
    params = init(moe_ffn_specs(cfg), jax.random.PRNGKey(0))
    x = toy_batch()
    xg, G = group_tokens(x, cfg.moe)
    T = xg.shape[1]
    dense = (G, T, cfg.moe.num_experts, cfg.moe.capacity(T))

    assert not dense_shape_present(
        lambda p, xx: moe_ffn_apply(p, xx, cfg)[0], (params, x), dense)
    # ... including through the backward pass
    assert not dense_shape_present(
        jax.grad(lambda p, xx: jnp.sum(moe_ffn_apply(p, xx, cfg)[0] ** 2)),
        (params, x), dense)
    if routing == "expert_choice":
        # slot-major dispatch: no (G, T*E, M) token blowup from the
        # K = E token-choice columns either
        blown = (G, T * cfg.moe.num_experts, cfg.d_model)
        assert not dense_shape_present(
            lambda p, xx: moe_ffn_apply(p, xx, cfg)[0], (params, x), blown)
    # control: the einsum path does materialise exactly that tensor
    cfg_e = cfg.replace_moe(impl="einsum")
    assert dense_shape_present(
        lambda p, xx: moe_ffn_apply(p, xx, cfg_e)[0], (params, x), dense)
