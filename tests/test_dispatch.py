"""Dispatcher API: registry, cross-backend equivalence (fwd+bwd, every
router), MoEContext threading, the explicit expert-parallel ``alltoall``
backend on a multi-device host mesh, and the capacity-free ``dropless``
backend (ragged grouped GEMM) including conservation guarantees.

Shared fixtures (toy configs/batches, the 8-device subprocess runner,
the jaxpr structural probe) live in conftest.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.core.context import MoEContext
from repro.core.dispatch import (
    available_dispatchers,
    get_dispatcher,
    register_dispatcher,
)
from repro.core.moe import group_tokens, moe_ffn_apply, moe_ffn_specs
from repro.core.routing import route
from repro.nn import init

ALL_ROUTERS = ("topk", "prototype", "expert_choice", "hash")
ALL_DISPATCHERS = ("alltoall", "dropless", "einsum", "gather", "pallas")
NON_REFERENCE = ("gather", "pallas", "alltoall", "dropless")


class TestRegistry:
    def test_builtin_keys(self):
        assert set(ALL_DISPATCHERS) <= set(available_dispatchers())

    def test_resolves_all_backends(self):
        for name in ALL_DISPATCHERS:
            assert get_dispatcher(name).name == name

    def test_unknown_key_lists_registry(self):
        with pytest.raises(ValueError, match="alltoall.*einsum"):
            get_dispatcher("nope")

    def test_config_validates_impl_key(self):
        with pytest.raises(ValueError, match="unknown moe impl"):
            MoEConfig(num_experts=4, impl="definitely-not-registered")
        # dense configs (num_experts=0) skip validation entirely
        MoEConfig(num_experts=0, impl="whatever")

    def test_plugin_registration(self):
        from repro.core.dispatch import _REGISTRY
        from repro.core.dispatch.gather import GatherDispatcher

        try:
            @register_dispatcher
            class MyDispatcher(GatherDispatcher):
                name = "my_backend"

            assert get_dispatcher("my_backend").name == "my_backend"
            MoEConfig(num_experts=4, impl="my_backend")
        finally:
            _REGISTRY.pop("my_backend", None)

    def test_dropless_requires_capable_backend(self):
        """capacity_factor=None is validated against the registry: only
        backends declaring supports_dropless may execute it."""
        for impl in ("einsum", "gather", "pallas"):
            with pytest.raises(ValueError, match="dropless"):
                MoEConfig(num_experts=4, impl=impl, capacity_factor=None)
        m = MoEConfig(num_experts=4, impl="dropless", capacity_factor=None)
        assert m.dropless
        # alltoall routes dropless plans through the ragged expert-parallel
        # exchange (falling back to the single-device ragged layout off a
        # mesh), so it declares supports_dropless too.
        assert MoEConfig(num_experts=4, impl="alltoall",
                         capacity_factor=None).dropless
        # dropless capacity is the per-group token count: a token's K
        # choices target distinct experts, so nothing can ever overflow.
        assert m.capacity(64) == 64
        # a finite capacity_factor on the dropless backend is also legal
        # (the backend executes any plan, drops included)
        MoEConfig(num_experts=4, impl="dropless", capacity_factor=1.25)
        # moe_attention runs the dense einsum path unconditionally, whose
        # (G,T,E,C=T) view would be quadratic in T — rejected up front
        with pytest.raises(ValueError, match="moe_attention"):
            MoEConfig(num_experts=4, impl="dropless", capacity_factor=None,
                      moe_attention=True)


# ---------------------------------------------------------------------------
# Cross-dispatcher equivalence: every backend == the einsum reference,
# forward and backward, for every registered router.
# ---------------------------------------------------------------------------

class TestEquivalence:
    @pytest.mark.parametrize("routing", ALL_ROUTERS)
    @pytest.mark.parametrize("impl", NON_REFERENCE)
    def test_forward_matches_einsum(self, routing, impl, moe_model_cfg, toy_batch):
        cfg_e, cfg_o = moe_model_cfg(routing), moe_model_cfg(routing, impl=impl)
        params = init(moe_ffn_specs(cfg_e), jax.random.PRNGKey(0))
        x = toy_batch()
        y0, a0 = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg_e))(params, x)
        y1, a1 = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg_o))(params, x)
        tol = 1e-4 if impl == "pallas" else 1e-5
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=tol)
        # routing metrics are dispatcher-independent (the plan is shared)
        assert float(a0["moe_cv"]) == pytest.approx(float(a1["moe_cv"]))
        assert float(a0["moe_dropped_fraction"]) == pytest.approx(
            float(a1["moe_dropped_fraction"]))

    @pytest.mark.parametrize("routing", ALL_ROUTERS)
    @pytest.mark.parametrize("impl", NON_REFERENCE)
    def test_backward_matches_einsum(self, routing, impl, moe_model_cfg, toy_batch):
        cfg_e, cfg_o = moe_model_cfg(routing), moe_model_cfg(routing, impl=impl)
        params = init(moe_ffn_specs(cfg_e), jax.random.PRNGKey(0))
        x = toy_batch()

        def grads(cfg):
            return jax.grad(
                lambda p: jnp.mean(moe_ffn_apply(p, x, cfg)[0] ** 2))(params)

        g_e, g_o = grads(cfg_e), grads(cfg_o)
        for k in g_e:
            a, b = np.asarray(g_e[k]), np.asarray(g_o[k])
            np.testing.assert_allclose(
                a, b, atol=1e-4 * max(np.abs(a).max(), 1e-9), err_msg=k)

    @pytest.mark.parametrize("impl", NON_REFERENCE)
    def test_dropped_token_parity(self, impl, moe_model_cfg):
        """Under heavy capacity pressure every backend drops the *same*
        tokens (zero rows in identical places) as the einsum reference —
        including `dropless`, which executes the shared plan's assignment
        (its no-drop guarantee comes from capacity_factor=None, not from
        overriding a finite-capacity plan)."""
        cfg_e = moe_model_cfg("topk", capacity_factor=0.05)
        cfg_o = moe_model_cfg("topk", impl=impl, capacity_factor=0.05)
        params = init(moe_ffn_specs(cfg_e), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))
        y0, a0 = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg_e))(params, x)
        y1, a1 = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg_o))(params, x)
        assert float(a0["moe_dropped_fraction"]) > 0.3
        assert float(a1["moe_dropped_fraction"]) == pytest.approx(
            float(a0["moe_dropped_fraction"]))
        z0 = np.linalg.norm(np.asarray(y0)[0], axis=-1) == 0.0
        z1 = np.linalg.norm(np.asarray(y1)[0], axis=-1) == 0.0
        np.testing.assert_array_equal(z0, z1)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-4)


# ---------------------------------------------------------------------------
# Dropless conservation: every routed token is processed exactly once —
# no drops, no duplicates — and the execution matches the einsum
# reference in the no-drop regime, forward and backward.
# ---------------------------------------------------------------------------

def _plan_and_params(cfg, x):
    m = cfg.moe
    params = init(moe_ffn_specs(cfg), jax.random.PRNGKey(0))
    xg, G = group_tokens(x, m)
    T = xg.shape[1]
    w = params.get("router")
    plan = route(xg, None if w is None else w.astype(jnp.float32),
                 m, m.capacity(T))
    return plan, params, xg


class TestDroplessConservation:
    @pytest.mark.parametrize("routing", ALL_ROUTERS)
    def test_ragged_view_processes_each_routed_token_once(
            self, routing, moe_model_cfg, toy_batch):
        """The ragged view holds exactly the plan's valid choices — as a
        multiset of (expert, token, gate) triples — each inside its
        expert's block-aligned segment. No token is dropped, none is
        duplicated."""
        bx = 8
        cfg = moe_model_cfg(routing, impl="dropless", capacity_factor=None)
        plan, _, xg = _plan_and_params(cfg, toy_batch())
        rag = plan.ragged(block_rows=bx)
        G = xg.shape[0]
        E = plan.num_experts

        e = np.asarray(plan.expert_index)
        v = np.asarray(plan.valid)
        g = np.asarray(plan.masked_gate)
        K = e.shape[-1]
        tok_rag = np.asarray(rag.token)
        gate_rag = np.asarray(rag.gate)
        off = np.asarray(rag.expert_offsets)
        be = np.asarray(rag.block_expert)

        for gi in range(G):
            # expectation straight off the index view
            tv, kv = np.nonzero(v[gi])
            want = sorted(zip(e[gi][tv, kv], tv, np.round(g[gi][tv, kv], 5)))
            # realisation from the ragged view
            rows = np.nonzero(tok_rag[gi] >= 0)[0]
            row_e = np.searchsorted(off[gi], rows, side="right") - 1
            got = sorted(zip(row_e, tok_rag[gi][rows],
                             np.round(gate_rag[gi][rows], 5)))
            assert got == want                      # exactly once, each
            # empty rows carry gate 0; segments are block-aligned and
            # block_expert agrees with the offsets
            assert (gate_rag[gi][tok_rag[gi] < 0] == 0.0).all()
            assert (off[gi] % bx == 0).all()
            for b, eb in enumerate(be[gi]):
                blk = np.arange(b * bx, (b + 1) * bx)
                filled = blk[tok_rag[gi][blk] >= 0]
                block_experts = np.searchsorted(off[gi], filled, side="right") - 1
                assert (block_experts == eb).all()

    @pytest.mark.parametrize("routing", ALL_ROUTERS)
    def test_sort_order_is_a_partial_permutation(self, routing, moe_model_cfg,
                                                 toy_batch):
        """sort_order holds each valid flat choice index exactly once."""
        cfg = moe_model_cfg(routing, impl="dropless", capacity_factor=None)
        plan, _, _ = _plan_and_params(cfg, toy_batch())
        rag = plan.ragged(block_rows=8)
        so = np.asarray(rag.sort_order)
        v = np.asarray(plan.valid)
        n_valid = int(v.sum())
        real = so[so >= 0]
        assert real.size == n_valid
        assert np.unique(real).size == real.size    # no duplicates
        # every row's choice index is consistent with its token
        if plan.token_at_slot is None:
            K = plan.expert_index.shape[-1]
            tok = np.asarray(rag.token)
            assert (tok[so >= 0] == real // K).all()

    @pytest.mark.parametrize("routing", ALL_ROUTERS)
    def test_matches_einsum_in_no_drop_regime(self, routing, moe_model_cfg,
                                              toy_batch):
        """capacity_factor=None (dropless) == einsum with a capacity
        large enough to drop nothing: identical assignment, identical
        numerics, fwd + bwd."""
        # gamma = E makes C >= k*T: overflow is impossible for the
        # einsum reference, so both execute the capacity-infinity plan.
        cfg_e = moe_model_cfg(routing, capacity_factor=8.0)
        cfg_d = moe_model_cfg(routing, impl="dropless", capacity_factor=None)
        params = init(moe_ffn_specs(cfg_e), jax.random.PRNGKey(0))
        x = toy_batch()
        y0, a0 = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg_e))(params, x)
        y1, a1 = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg_d))(params, x)
        assert float(a0["moe_dropped_fraction"]) == 0.0
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)

        def grads(cfg):
            return jax.grad(
                lambda p: jnp.mean(moe_ffn_apply(p, x, cfg)[0] ** 2))(params)

        g_e, g_d = grads(cfg_e), grads(cfg_d)
        for k in g_e:
            a, b = np.asarray(g_e[k]), np.asarray(g_d[k])
            np.testing.assert_allclose(
                a, b, atol=1e-4 * max(np.abs(a).max(), 1e-9), err_msg=k)

    @pytest.mark.parametrize("routing", ALL_ROUTERS)
    def test_dropped_fraction_identically_zero(self, routing, moe_model_cfg,
                                               toy_batch):
        cfg = moe_model_cfg(routing, impl="dropless", capacity_factor=None)
        params = init(moe_ffn_specs(cfg), jax.random.PRNGKey(0))
        y, aux = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg))(params, toy_batch())
        assert float(aux["moe_dropped_fraction"]) == 0.0   # exact, not approx

    @pytest.mark.parametrize("routing", ALL_ROUTERS)
    def test_no_dense_or_capacity_intermediate(self, routing, moe_model_cfg,
                                               toy_batch, dense_shape_present):
        """The dropless path never builds the (G,T,E,C) one-hot tensors
        nor an (E, G*C, M) capacity buffer — fwd or bwd."""
        cfg = moe_model_cfg(routing, impl="dropless", capacity_factor=None)
        params = init(moe_ffn_specs(cfg), jax.random.PRNGKey(0))
        x = toy_batch()
        xg, G = group_tokens(x, cfg.moe)
        T = xg.shape[1]
        E, C = cfg.moe.num_experts, cfg.moe.capacity(T)
        for shape in [(G, T, E, C), (E, G * C, cfg.d_model)]:
            assert not dense_shape_present(
                lambda p, xx: moe_ffn_apply(p, xx, cfg)[0], (params, x), shape)
            assert not dense_shape_present(
                jax.grad(lambda p, xx: jnp.sum(moe_ffn_apply(p, xx, cfg)[0] ** 2)),
                (params, x), shape)

    def test_dropless_rescues_dropped_tokens(self, moe_model_cfg):
        """The point of the backend: where a tight capacity factor zeroes
        token rows, capacity_factor=None processes every token."""
        cfg_tight = moe_model_cfg("topk", capacity_factor=0.05)
        cfg_d = moe_model_cfg("topk", impl="dropless", capacity_factor=None)
        params = init(moe_ffn_specs(cfg_tight), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))
        y0, a0 = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg_tight))(params, x)
        y1, a1 = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg_d))(params, x)
        assert float(a0["moe_dropped_fraction"]) > 0.3
        assert float(a1["moe_dropped_fraction"]) == 0.0
        zeroed = np.linalg.norm(np.asarray(y0)[0], axis=-1) == 0.0
        assert zeroed.any()
        assert (np.linalg.norm(np.asarray(y1)[0], axis=-1) > 0.0).all()

    def test_end_to_end_train_step(self, moe_model_cfg):
        """A dropless MoE LM takes a full train step (losses finite)."""
        from repro.configs.base import TrainConfig
        from repro.models.registry import get_family
        from repro.optim import make_optimizer, warmup_constant
        from repro.train.state import init_train_state
        from repro.train.trainer import make_train_step

        cfg = ModelConfig(num_layers=2, d_model=32, d_ff=48, num_heads=4,
                          num_kv_heads=4, vocab_size=64, dtype="float32",
                          moe=MoEConfig(num_experts=8, routing="topk", top_k=2,
                                        group_size=32, impl="dropless",
                                        capacity_factor=None))
        fam = get_family(cfg)
        tc = TrainConfig(optimizer="adamw", learning_rate=1e-3)
        params = init(fam.specs(cfg), jax.random.PRNGKey(0))
        opt = make_optimizer(tc, warmup_constant(tc.learning_rate, tc.warmup_steps))
        state = init_train_state(params, opt, "none")
        step = jax.jit(make_train_step(cfg, tc, opt))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0, 64)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        # per-layer trace: exactly zero drops in every MoE layer
        assert (np.asarray(metrics["moe_dropped_fraction"]) == 0.0).all()


# ---------------------------------------------------------------------------
# MoEContext threading
# ---------------------------------------------------------------------------

class TestContext:
    def test_context_is_a_pytree(self):
        ctx = MoEContext(token_ids=jnp.zeros((2, 8), jnp.int32),
                         positions=jnp.zeros((2, 8), jnp.int32),
                         is_training=True)
        leaves, treedef = jax.tree_util.tree_flatten(ctx)
        ctx2 = jax.tree_util.tree_unflatten(treedef, leaves)
        assert ctx2.is_training and ctx2.token_ids.shape == (2, 8)

    def test_layer_regroups_context(self, moe_model_cfg, toy_batch):
        """Identity-routing (hash) changes when token ids are provided —
        proof the context reaches the router through the layer."""
        cfg = moe_model_cfg("hash")
        params = init(moe_ffn_specs(cfg), jax.random.PRNGKey(0))
        x = toy_batch()
        ids = jnp.full((2, 50), 7, jnp.int32)   # all the same token id
        ctx = MoEContext(token_ids=ids)
        y0, a0 = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg))(params, x)
        y1, a1 = jax.jit(
            lambda p, xx, c: moe_ffn_apply(p, xx, cfg, ctx=c))(params, x, ctx)
        # all-identical ids hash to ONE expert pair -> drops under capacity
        assert float(a1["moe_dropped_fraction"]) > float(a0["moe_dropped_fraction"])

    def test_lm_apply_threads_token_ids(self):
        """End to end: a decoder LM with hash routing routes by token id
        (two prompts with permuted tokens produce identical expert loads)."""
        from repro.models import transformer as TF

        cfg = ModelConfig(num_layers=1, d_model=32, d_ff=48, num_heads=4,
                          num_kv_heads=4, vocab_size=64, dtype="float32",
                          moe=MoEConfig(num_experts=8, routing="hash", top_k=1,
                                        group_size=256, capacity_factor=8.0))
        params = init(TF.lm_specs(cfg), jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0, 64)
        perm = toks[:, ::-1]
        # capture the plan via the aux cv metric: same multiset of ids ->
        # same expert loads -> identical cv, which position-hash (fixed
        # pseudo-random permutation over positions) would not give.
        _, a1 = TF.lm_apply(params, toks, cfg)
        _, a2 = TF.lm_apply(params, perm, cfg)
        assert float(jnp.sum(a1["moe_cv"])) == pytest.approx(
            float(jnp.sum(a2["moe_cv"])), abs=1e-6)

    def test_serving_engine_threads_decode_context(self):
        """ServingEngine threads a MoEContext into prefill and decode
        (smoke: hash-routed MoE generates without NaNs; the layout
        invariance of the absolute-position fallback itself is asserted
        in test_routers.TestHashGolden)."""
        from repro.serving.engine import ServingEngine

        cfg = ModelConfig(num_layers=2, d_model=32, d_ff=48, num_heads=4,
                          num_kv_heads=4, vocab_size=64, dtype="float32",
                          max_seq_len=64,
                          moe=MoEConfig(num_experts=4, routing="hash", top_k=1,
                                        group_size=32, capacity_factor=4.0))
        from repro.models.registry import get_family

        params = init(get_family(cfg).specs(cfg), jax.random.PRNGKey(0))
        eng = ServingEngine(cfg, params, max_len=32)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)
        out, _ = eng.generate(prompts, num_tokens=4)
        assert out.shape == (2, 4)
        assert not bool(jnp.isnan(out.astype(jnp.float32)).any())

    def test_serving_engine_dropless(self):
        """A dropless-configured model serves end to end."""
        from repro.models.registry import get_family
        from repro.serving.engine import ServingEngine

        cfg = ModelConfig(num_layers=2, d_model=32, d_ff=48, num_heads=4,
                          num_kv_heads=4, vocab_size=64, dtype="float32",
                          max_seq_len=64,
                          moe=MoEConfig(num_experts=4, routing="topk", top_k=2,
                                        group_size=32, impl="dropless",
                                        capacity_factor=None))
        params = init(get_family(cfg).specs(cfg), jax.random.PRNGKey(0))
        eng = ServingEngine(cfg, params, max_len=32)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)
        out, _ = eng.generate(prompts, num_tokens=4)
        assert out.shape == (2, 4)


# ---------------------------------------------------------------------------
# Structural guarantee: the alltoall backend never materialises the dense
# (G,T,E,C) tensors — in fallback mode here, under shard_map below.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("routing", ALL_ROUTERS)
def test_alltoall_no_dense_intermediate(routing, moe_model_cfg, toy_batch,
                                        dense_shape_present):
    cfg = moe_model_cfg(routing, impl="alltoall")
    params = init(moe_ffn_specs(cfg), jax.random.PRNGKey(0))
    x = toy_batch()
    xg, G = group_tokens(x, cfg.moe)
    T = xg.shape[1]
    dense = (G, T, cfg.moe.num_experts, cfg.moe.capacity(T))
    assert not dense_shape_present(
        lambda p, xx: moe_ffn_apply(p, xx, cfg)[0], (params, x), dense)
    assert not dense_shape_present(
        jax.grad(lambda p, xx: jnp.sum(moe_ffn_apply(p, xx, cfg)[0] ** 2)),
        (params, x), dense)


# ---------------------------------------------------------------------------
# The real thing: shard_map + all_to_all on an 8-device host mesh.
# ---------------------------------------------------------------------------

def test_alltoall_in_process_on_8_devices(mesh8, moe_model_cfg):
    """When the test process itself owns >= 8 devices (the CI mesh-8
    job), run the shard_map path in-process: Rules sharding + explicit
    all_to_all against the einsum reference."""
    from repro.distributed.sharding import make_rules, use_rules

    mesh = mesh8
    cfg = moe_model_cfg("topk", impl="alltoall", group_size=32)
    rules = make_rules(cfg, mesh)
    assert rules.params["expert"] == "model"
    params = init(moe_ffn_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 32))
    cfg_e = cfg.replace_moe(impl="einsum")
    y0, _ = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg_e))(params, x)

    def fwd(p, xx):
        with use_rules(rules):
            return moe_ffn_apply(p, xx, cfg)[0]

    with mesh:
        y1 = jax.jit(fwd)(params, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(jax.device_get(y1)),
                               atol=2e-5)

    def loss(c, r):
        def g(p):
            with use_rules(r):
                return jnp.sum(moe_ffn_apply(p, x, c)[0] ** 2)
        return g

    g_e = jax.grad(loss(cfg_e, None))(params)
    with mesh:
        g_a = jax.jit(jax.grad(loss(cfg, rules)))(params)
    for k in g_e:
        a, b = np.asarray(g_e[k]), np.asarray(jax.device_get(g_a[k]))
        np.testing.assert_allclose(a, b, atol=1e-4 * max(np.abs(a).max(), 1e-9),
                                   err_msg=k)


def test_dropless_in_process_on_8_devices(mesh8, moe_model_cfg):
    """Dropless conservation holds under a sharded (2, 4) mesh: with the
    expert axis 4-way sharded and G divisible by the device grid, the
    backend runs the *ragged expert-parallel* exchange (structurally
    asserted: all_to_all in the jaxpr) and still matches the einsum
    reference with zero drops, fwd + bwd."""
    from repro.distributed.sharding import make_rules, use_rules

    mesh = mesh8
    cfg = moe_model_cfg("topk", impl="dropless", capacity_factor=None,
                        group_size=32)
    rules = make_rules(cfg, mesh)
    params = init(moe_ffn_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 32))
    cfg_e = cfg.replace_moe(impl="einsum", capacity_factor=8.0)
    y0, _ = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg_e))(params, x)

    def fwd(p, xx):
        with use_rules(rules):
            return moe_ffn_apply(p, xx, cfg)

    with mesh:
        y1, aux = jax.jit(fwd)(params, x)
    assert float(jax.device_get(aux["moe_dropped_fraction"])) == 0.0
    np.testing.assert_allclose(np.asarray(y0), np.asarray(jax.device_get(y1)),
                               atol=2e-5)
    # the expert-sharded mesh must engage the ragged EP exchange, not
    # fall back to the GSPMD path (let alone gather)
    with use_rules(rules):
        assert "all_to_all" in str(jax.make_jaxpr(
            lambda p, xx: moe_ffn_apply(p, xx, cfg)[0])(params, x))

    def loss(c, r):
        def g(p):
            with use_rules(r):
                return jnp.sum(moe_ffn_apply(p, x, c)[0] ** 2)
        return g

    g_e = jax.grad(loss(cfg_e, None))(params)
    with mesh:
        g_d = jax.jit(jax.grad(loss(cfg, rules)))(params)
    for k in g_e:
        a, b = np.asarray(g_e[k]), np.asarray(jax.device_get(g_d[k]))
        np.testing.assert_allclose(a, b, atol=1e-4 * max(np.abs(a).max(), 1e-9),
                                   err_msg=k)


def test_ragged_ep_alltoall_impl_in_process(mesh8, moe_model_cfg,
                                            dense_shape_present):
    """capacity_factor=None on the ``alltoall`` backend: dropless plans
    route through the ragged EP dispatch (the (E,C)-buffered exchange
    has no capacity dimension to buffer) and match the single-device
    dropless path fwd + bwd; the jaxpr holds the all_to_all exchange and
    no dense capacity tensor, global or per-shard."""
    from repro.distributed.sharding import make_rules, use_rules

    mesh = mesh8
    cfg = moe_model_cfg("topk", impl="alltoall", capacity_factor=None,
                        group_size=32)
    rules = make_rules(cfg, mesh)
    params = init(moe_ffn_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 32))   # G = 8
    cfg_d = cfg.replace_moe(impl="dropless")
    y0, _ = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg_d))(params, x)

    def fwd(p, xx):
        with use_rules(rules):
            return moe_ffn_apply(p, xx, cfg)[0]

    with mesh:
        y1 = jax.jit(fwd)(params, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(jax.device_get(y1)),
                               atol=2e-5)

    def loss(c, r):
        def g(p):
            with use_rules(r):
                return jnp.sum(moe_ffn_apply(p, x, c)[0] ** 2)
        return g

    g_d = jax.grad(loss(cfg_d, None))(params)
    with mesh:
        g_a = jax.jit(jax.grad(loss(cfg, rules)))(params)
    for k in g_d:
        a, b = np.asarray(g_d[k]), np.asarray(jax.device_get(g_a[k]))
        np.testing.assert_allclose(a, b, atol=1e-4 * max(np.abs(a).max(), 1e-9),
                                   err_msg=k)

    xg, G = group_tokens(x, cfg.moe)
    T = xg.shape[1]
    E, C = cfg.moe.num_experts, cfg.moe.capacity(T)
    with use_rules(rules):
        closed = jax.make_jaxpr(fwd)(params, x)
    assert "all_to_all" in str(closed)
    from conftest import _walk_avals
    shapes = {getattr(a, "shape", None) for a in _walk_avals(closed.jaxpr)}
    assert (G, T, E, C) not in shapes           # global dense
    assert (G // 8, T, E, C) not in shapes      # per-shard dense


@pytest.mark.skipif(jax.device_count() >= 8,
                    reason="multi-device parent runs the in-process mesh test "
                           "instead; the subprocess variant belongs to the "
                           "single-device CI job")
def test_alltoall_on_mesh_matches_einsum_all_routers(run_sub):
    """2x4 (data, model) mesh: the explicit expert-parallel dispatch
    matches the einsum reference forward AND backward for every router,
    and its jaxpr (including the shard_map body) holds no dense
    (G,T,E,C) or per-shard (Gl,T,E,C) tensor."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelConfig, MoEConfig
    from repro.core.moe import group_tokens, moe_ffn_apply, moe_ffn_specs
    from repro.distributed.sharding import make_rules, use_rules
    from repro.launch.mesh import make_debug_mesh
    from repro.nn import init

    assert jax.device_count() == 8
    mesh = make_debug_mesh(2, 4)

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                yield v.aval
            for p in eqn.params.values():
                for pv in (p if isinstance(p, (list, tuple)) else [p]):
                    inner = getattr(pv, "jaxpr", pv)
                    if hasattr(inner, "eqns"):
                        yield from walk(inner)

    for routing in ("topk", "prototype", "expert_choice", "hash"):
        cfg = ModelConfig(d_model=32, d_ff=48, dtype="float32",
                          moe=MoEConfig(num_experts=8, routing=routing,
                                        top_k=2, num_prototypes=2,
                                        group_size=32, capacity_factor=2.0,
                                        impl="alltoall"))
        rules = make_rules(cfg, mesh)
        assert rules.params["expert"] == "model"
        params = init(moe_ffn_specs(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 32))  # 8 groups
        cfg_e = cfg.replace_moe(impl="einsum")

        def fwd(p, xx):
            with use_rules(rules):
                return moe_ffn_apply(p, xx, cfg)[0]

        def loss(c, r):
            def g(p, xx):
                with use_rules(r):
                    return jnp.sum(moe_ffn_apply(p, xx, c)[0] ** 2)
            return g

        y0 = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg_e)[0])(params, x)
        with mesh:
            y1 = jax.jit(fwd)(params, x)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(jax.device_get(y1)),
                                   atol=2e-5)

        g_e = jax.grad(loss(cfg_e, None))(params, x)
        with mesh:
            g_a = jax.jit(jax.grad(loss(cfg, rules)))(params, x)
        for k in g_e:
            a = np.asarray(g_e[k]); b = np.asarray(jax.device_get(g_a[k]))
            np.testing.assert_allclose(a, b, atol=1e-4 * max(np.abs(a).max(), 1e-9),
                                       err_msg=routing + "/" + k)

        # structural: no dense one-hot tensors, global or per-shard
        xg, G = group_tokens(x, cfg.moe)
        T = xg.shape[1]
        E, C = cfg.moe.num_experts, cfg.moe.capacity(T)
        with use_rules(rules):
            closed = jax.make_jaxpr(lambda p, xx: moe_ffn_apply(p, xx, cfg)[0])(params, x)
        shapes = {getattr(a, "shape", None) for a in walk(closed.jaxpr)}
        assert (G, T, E, C) not in shapes, (routing, "global dense")
        assert (G // 8, T, E, C) not in shapes, (routing, "per-shard dense")
        # the shard_map body must actually contain the two all_to_alls
        txt = str(closed)
        assert "all_to_all" in txt, routing
        print(routing, "mesh-ok")
    """
    # 4 routers x (fwd + bwd) compiles are heavy on a 2-core CI box:
    # give the subprocess real headroom over the ~8 min observed runtime.
    out = run_sub(code, timeout=1500)
    for routing in ALL_ROUTERS:
        assert f"{routing} mesh-ok" in out


@pytest.mark.skipif(jax.device_count() >= 8,
                    reason="multi-device parent runs the in-process mesh test "
                           "instead; the subprocess variant belongs to the "
                           "single-device CI job")
def test_dropless_on_mesh_conserves_tokens(run_sub):
    """8-virtual-device mesh: the dropless backend under Rules sharding
    matches the no-drop einsum reference and reports exactly zero
    dropped tokens (fwd + bwd)."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelConfig, MoEConfig
    from repro.core.moe import moe_ffn_apply, moe_ffn_specs
    from repro.distributed.sharding import make_rules, use_rules
    from repro.launch.mesh import make_debug_mesh
    from repro.nn import init

    assert jax.device_count() == 8
    mesh = make_debug_mesh(2, 4)
    cfg = ModelConfig(d_model=32, d_ff=48, dtype="float32",
                      moe=MoEConfig(num_experts=8, routing="topk", top_k=2,
                                    group_size=32, impl="dropless",
                                    capacity_factor=None))
    rules = make_rules(cfg, mesh)
    params = init(moe_ffn_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 32))
    cfg_e = cfg.replace_moe(impl="einsum", capacity_factor=8.0)
    y0, _ = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg_e))(params, x)

    def fwd(p, xx):
        with use_rules(rules):
            return moe_ffn_apply(p, xx, cfg)

    with mesh:
        y1, aux = jax.jit(fwd)(params, x)
    assert float(jax.device_get(aux["moe_dropped_fraction"])) == 0.0
    np.testing.assert_allclose(np.asarray(y0), np.asarray(jax.device_get(y1)),
                               atol=2e-5)

    def loss(c, r):
        def g(p):
            with use_rules(r):
                return jnp.sum(moe_ffn_apply(p, x, c)[0] ** 2)
        return g

    g_e = jax.grad(loss(cfg_e, None))(params)
    with mesh:
        g_d = jax.jit(jax.grad(loss(cfg, rules)))(params)
    for k in g_e:
        a = np.asarray(g_e[k]); b = np.asarray(jax.device_get(g_d[k]))
        np.testing.assert_allclose(a, b, atol=1e-4 * max(np.abs(a).max(), 1e-9),
                                   err_msg=k)
    print("dropless-mesh-ok")
    """
    assert "dropless-mesh-ok" in run_sub(code)


@pytest.mark.skipif(jax.device_count() >= 8,
                    reason="multi-device parent runs the in-process ragged-EP "
                           "tests instead; the subprocess variant belongs to "
                           "the single-device CI job")
def test_ragged_ep_on_mesh_matches_dropless(run_sub):
    """8-virtual-device (2, 4) mesh: the ragged expert-parallel dispatch
    (dropless plans on the ``dropless`` AND ``alltoall`` backends)
    matches the single-device dropless reference fwd + bwd, engages the
    all_to_all exchange and builds no dense capacity tensor."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelConfig, MoEConfig
    from repro.core.moe import group_tokens, moe_ffn_apply, moe_ffn_specs
    from repro.distributed.sharding import make_rules, use_rules
    from repro.launch.mesh import make_debug_mesh
    from repro.nn import init

    assert jax.device_count() == 8
    mesh = make_debug_mesh(2, 4)

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                yield v.aval
            for p in eqn.params.values():
                for pv in (p if isinstance(p, (list, tuple)) else [p]):
                    inner = getattr(pv, "jaxpr", pv)
                    if hasattr(inner, "eqns"):
                        yield from walk(inner)

    for routing, impl in (("topk", "alltoall"), ("hash", "dropless")):
        cfg = ModelConfig(d_model=32, d_ff=48, dtype="float32",
                          moe=MoEConfig(num_experts=8, routing=routing,
                                        top_k=2, group_size=32,
                                        capacity_factor=None, impl=impl))
        rules = make_rules(cfg, mesh)
        params = init(moe_ffn_specs(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 32))  # G = 8
        cfg_d = cfg.replace_moe(impl="dropless")
        y0, _ = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg_d))(params, x)

        def fwd(p, xx):
            with use_rules(rules):
                return moe_ffn_apply(p, xx, cfg)[0]

        with mesh:
            y1 = jax.jit(fwd)(params, x)
        np.testing.assert_allclose(np.asarray(y0),
                                   np.asarray(jax.device_get(y1)), atol=2e-5)

        def loss(c, r):
            def g(p):
                with use_rules(r):
                    return jnp.sum(moe_ffn_apply(p, x, c)[0] ** 2)
            return g

        g_d = jax.grad(loss(cfg_d, None))(params)
        with mesh:
            g_a = jax.jit(jax.grad(loss(cfg, rules)))(params)
        for k in g_d:
            a = np.asarray(g_d[k]); b = np.asarray(jax.device_get(g_a[k]))
            np.testing.assert_allclose(
                a, b, atol=1e-4 * max(np.abs(a).max(), 1e-9),
                err_msg=routing + "/" + k)

        xg, G = group_tokens(x, cfg.moe)
        T = xg.shape[1]
        E, C = cfg.moe.num_experts, cfg.moe.capacity(T)
        with use_rules(rules):
            closed = jax.make_jaxpr(fwd)(params, x)
        assert "all_to_all" in str(closed), (routing, impl)
        shapes = {getattr(a, "shape", None) for a in walk(closed.jaxpr)}
        assert (G, T, E, C) not in shapes, (routing, impl)
        assert (G // 8, T, E, C) not in shapes, (routing, impl)
        print(routing, impl, "ragged-ep-ok")
    """
    out = run_sub(code, timeout=1500)
    assert "topk alltoall ragged-ep-ok" in out
    assert "hash dropless ragged-ep-ok" in out
