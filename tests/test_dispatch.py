"""Dispatcher API: registry, cross-backend equivalence (fwd+bwd, every
router), MoEContext threading, and the explicit expert-parallel
``alltoall`` backend on a multi-device host mesh."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.core.context import MoEContext
from repro.core.dispatch import (
    available_dispatchers,
    get_dispatcher,
    register_dispatcher,
)
from repro.core.moe import group_tokens, moe_ffn_apply, moe_ffn_specs
from repro.nn import init

ALL_ROUTERS = ("topk", "prototype", "expert_choice", "hash")
ALL_DISPATCHERS = ("einsum", "gather", "pallas", "alltoall")


def _cfg(routing="topk", impl="einsum", **kw):
    moe_kw = dict(num_experts=8, routing=routing, top_k=2, num_prototypes=2,
                  group_size=64, impl=impl, capacity_factor=2.0)
    moe_kw.update(kw)
    return ModelConfig(d_model=32, d_ff=48, dtype="float32",
                       moe=MoEConfig(**moe_kw))


def _run_sub(code: str, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


class TestRegistry:
    def test_builtin_keys(self):
        assert set(ALL_DISPATCHERS) <= set(available_dispatchers())

    def test_resolves_all_four_backends(self):
        for name in ALL_DISPATCHERS:
            assert get_dispatcher(name).name == name

    def test_unknown_key_lists_registry(self):
        with pytest.raises(ValueError, match="alltoall.*einsum"):
            get_dispatcher("nope")

    def test_config_validates_impl_key(self):
        with pytest.raises(ValueError, match="unknown moe impl"):
            MoEConfig(num_experts=4, impl="definitely-not-registered")
        # dense configs (num_experts=0) skip validation entirely
        MoEConfig(num_experts=0, impl="whatever")

    def test_plugin_registration(self):
        from repro.core.dispatch import _REGISTRY
        from repro.core.dispatch.gather import GatherDispatcher

        try:
            @register_dispatcher
            class MyDispatcher(GatherDispatcher):
                name = "my_backend"

            assert get_dispatcher("my_backend").name == "my_backend"
            MoEConfig(num_experts=4, impl="my_backend")
        finally:
            _REGISTRY.pop("my_backend", None)


# ---------------------------------------------------------------------------
# Cross-dispatcher equivalence: every backend == the einsum reference,
# forward and backward, for every registered router.
# ---------------------------------------------------------------------------

class TestEquivalence:
    @pytest.mark.parametrize("routing", ALL_ROUTERS)
    @pytest.mark.parametrize("impl", ["gather", "pallas", "alltoall"])
    def test_forward_matches_einsum(self, routing, impl):
        cfg_e, cfg_o = _cfg(routing), _cfg(routing, impl=impl)
        params = init(moe_ffn_specs(cfg_e), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 50, 32))
        y0, a0 = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg_e))(params, x)
        y1, a1 = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg_o))(params, x)
        tol = 1e-5 if impl in ("gather", "alltoall") else 1e-4
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=tol)
        # routing metrics are dispatcher-independent (the plan is shared)
        assert float(a0["moe_cv"]) == pytest.approx(float(a1["moe_cv"]))
        assert float(a0["moe_dropped_fraction"]) == pytest.approx(
            float(a1["moe_dropped_fraction"]))

    @pytest.mark.parametrize("routing", ALL_ROUTERS)
    @pytest.mark.parametrize("impl", ["gather", "pallas", "alltoall"])
    def test_backward_matches_einsum(self, routing, impl):
        cfg_e, cfg_o = _cfg(routing), _cfg(routing, impl=impl)
        params = init(moe_ffn_specs(cfg_e), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 50, 32))

        def grads(cfg):
            return jax.grad(
                lambda p: jnp.mean(moe_ffn_apply(p, x, cfg)[0] ** 2))(params)

        g_e, g_o = grads(cfg_e), grads(cfg_o)
        for k in g_e:
            a, b = np.asarray(g_e[k]), np.asarray(g_o[k])
            np.testing.assert_allclose(
                a, b, atol=1e-4 * max(np.abs(a).max(), 1e-9), err_msg=k)

    @pytest.mark.parametrize("impl", ["gather", "pallas", "alltoall"])
    def test_dropped_token_parity(self, impl):
        """Under heavy capacity pressure every backend drops the *same*
        tokens (zero rows in identical places) as the einsum reference."""
        cfg_e = _cfg("topk", capacity_factor=0.05)
        cfg_o = _cfg("topk", impl=impl, capacity_factor=0.05)
        params = init(moe_ffn_specs(cfg_e), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))
        y0, a0 = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg_e))(params, x)
        y1, a1 = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg_o))(params, x)
        assert float(a0["moe_dropped_fraction"]) > 0.3
        assert float(a1["moe_dropped_fraction"]) == pytest.approx(
            float(a0["moe_dropped_fraction"]))
        z0 = np.linalg.norm(np.asarray(y0)[0], axis=-1) == 0.0
        z1 = np.linalg.norm(np.asarray(y1)[0], axis=-1) == 0.0
        np.testing.assert_array_equal(z0, z1)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-4)


# ---------------------------------------------------------------------------
# MoEContext threading
# ---------------------------------------------------------------------------

class TestContext:
    def test_context_is_a_pytree(self):
        ctx = MoEContext(token_ids=jnp.zeros((2, 8), jnp.int32),
                         positions=jnp.zeros((2, 8), jnp.int32),
                         is_training=True)
        leaves, treedef = jax.tree_util.tree_flatten(ctx)
        ctx2 = jax.tree_util.tree_unflatten(treedef, leaves)
        assert ctx2.is_training and ctx2.token_ids.shape == (2, 8)

    def test_layer_regroups_context(self):
        """Identity-routing (hash) changes when token ids are provided —
        proof the context reaches the router through the layer."""
        cfg = _cfg("hash")
        params = init(moe_ffn_specs(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 50, 32))
        ids = jnp.full((2, 50), 7, jnp.int32)   # all the same token id
        ctx = MoEContext(token_ids=ids)
        y0, a0 = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg))(params, x)
        y1, a1 = jax.jit(
            lambda p, xx, c: moe_ffn_apply(p, xx, cfg, ctx=c))(params, x, ctx)
        # all-identical ids hash to ONE expert pair -> drops under capacity
        assert float(a1["moe_dropped_fraction"]) > float(a0["moe_dropped_fraction"])

    def test_lm_apply_threads_token_ids(self):
        """End to end: a decoder LM with hash routing routes by token id
        (two prompts with permuted tokens produce identical expert loads)."""
        from repro.models import transformer as TF

        cfg = ModelConfig(num_layers=1, d_model=32, d_ff=48, num_heads=4,
                          num_kv_heads=4, vocab_size=64, dtype="float32",
                          moe=MoEConfig(num_experts=8, routing="hash", top_k=1,
                                        group_size=256, capacity_factor=8.0))
        params = init(TF.lm_specs(cfg), jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0, 64)
        perm = toks[:, ::-1]
        # capture the plan via the aux cv metric: same multiset of ids ->
        # same expert loads -> identical cv, which position-hash (fixed
        # pseudo-random permutation over positions) would not give.
        _, a1 = TF.lm_apply(params, toks, cfg)
        _, a2 = TF.lm_apply(params, perm, cfg)
        assert float(jnp.sum(a1["moe_cv"])) == pytest.approx(
            float(jnp.sum(a2["moe_cv"])), abs=1e-6)

    def test_serving_engine_threads_decode_context(self):
        """ServingEngine threads a MoEContext into prefill and decode
        (smoke: hash-routed MoE generates without NaNs; the layout
        invariance of the absolute-position fallback itself is asserted
        in test_routers.TestHashGolden)."""
        from repro.serving.engine import ServingEngine

        cfg = ModelConfig(num_layers=2, d_model=32, d_ff=48, num_heads=4,
                          num_kv_heads=4, vocab_size=64, dtype="float32",
                          max_seq_len=64,
                          moe=MoEConfig(num_experts=4, routing="hash", top_k=1,
                                        group_size=32, capacity_factor=4.0))
        from repro.models.registry import get_family

        params = init(get_family(cfg).specs(cfg), jax.random.PRNGKey(0))
        eng = ServingEngine(cfg, params, max_len=32)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)
        out, _ = eng.generate(prompts, num_tokens=4)
        assert out.shape == (2, 4)
        assert not bool(jnp.isnan(out.astype(jnp.float32)).any())


# ---------------------------------------------------------------------------
# Structural guarantee: the alltoall backend never materialises the dense
# (G,T,E,C) tensors — in fallback mode here, under shard_map below.
# ---------------------------------------------------------------------------

def _walk_avals(jaxpr):
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            yield v.aval
        for p in eqn.params.values():
            for pv in (p if isinstance(p, (list, tuple)) else [p]):
                inner = getattr(pv, "jaxpr", pv)
                if hasattr(inner, "eqns"):
                    yield from _walk_avals(inner)


def _dense_shape_present(fn, args, dense_shape):
    closed = jax.make_jaxpr(fn)(*args)
    return any(getattr(a, "shape", None) == dense_shape
               for a in _walk_avals(closed.jaxpr))


@pytest.mark.parametrize("routing", ALL_ROUTERS)
def test_alltoall_no_dense_intermediate(routing):
    cfg = _cfg(routing, impl="alltoall")
    params = init(moe_ffn_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 50, 32))
    xg, G = group_tokens(x, cfg.moe)
    T = xg.shape[1]
    dense = (G, T, cfg.moe.num_experts, cfg.moe.capacity(T))
    assert not _dense_shape_present(
        lambda p, xx: moe_ffn_apply(p, xx, cfg)[0], (params, x), dense)
    assert not _dense_shape_present(
        jax.grad(lambda p, xx: jnp.sum(moe_ffn_apply(p, xx, cfg)[0] ** 2)),
        (params, x), dense)


# ---------------------------------------------------------------------------
# The real thing: shard_map + all_to_all on an 8-device host mesh.
# ---------------------------------------------------------------------------

@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 host devices (CI mesh-8 matrix job sets "
                           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_alltoall_in_process_on_8_devices():
    """When the test process itself owns >= 8 devices (the CI mesh-8
    job), run the shard_map path in-process: Rules sharding + explicit
    all_to_all against the einsum reference."""
    from repro.distributed.sharding import make_rules, use_rules
    from repro.launch.mesh import make_debug_mesh

    mesh = make_debug_mesh(2, 4)
    cfg = _cfg("topk", impl="alltoall", group_size=32)
    rules = make_rules(cfg, mesh)
    assert rules.params["expert"] == "model"
    params = init(moe_ffn_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 32))
    cfg_e = cfg.replace_moe(impl="einsum")
    y0, _ = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg_e))(params, x)

    def fwd(p, xx):
        with use_rules(rules):
            return moe_ffn_apply(p, xx, cfg)[0]

    with mesh:
        y1 = jax.jit(fwd)(params, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(jax.device_get(y1)),
                               atol=2e-5)

    def loss(c, r):
        def g(p):
            with use_rules(r):
                return jnp.sum(moe_ffn_apply(p, x, c)[0] ** 2)
        return g

    g_e = jax.grad(loss(cfg_e, None))(params)
    with mesh:
        g_a = jax.jit(jax.grad(loss(cfg, rules)))(params)
    for k in g_e:
        a, b = np.asarray(g_e[k]), np.asarray(jax.device_get(g_a[k]))
        np.testing.assert_allclose(a, b, atol=1e-4 * max(np.abs(a).max(), 1e-9),
                                   err_msg=k)


@pytest.mark.skipif(jax.device_count() >= 8,
                    reason="multi-device parent runs the in-process mesh test "
                           "instead; the subprocess variant belongs to the "
                           "single-device CI job")
def test_alltoall_on_mesh_matches_einsum_all_routers():
    """2x4 (data, model) mesh: the explicit expert-parallel dispatch
    matches the einsum reference forward AND backward for every router,
    and its jaxpr (including the shard_map body) holds no dense
    (G,T,E,C) or per-shard (Gl,T,E,C) tensor."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelConfig, MoEConfig
    from repro.core.moe import group_tokens, moe_ffn_apply, moe_ffn_specs
    from repro.distributed.sharding import make_rules, use_rules
    from repro.launch.mesh import make_debug_mesh
    from repro.nn import init

    assert jax.device_count() == 8
    mesh = make_debug_mesh(2, 4)

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                yield v.aval
            for p in eqn.params.values():
                for pv in (p if isinstance(p, (list, tuple)) else [p]):
                    inner = getattr(pv, "jaxpr", pv)
                    if hasattr(inner, "eqns"):
                        yield from walk(inner)

    for routing in ("topk", "prototype", "expert_choice", "hash"):
        cfg = ModelConfig(d_model=32, d_ff=48, dtype="float32",
                          moe=MoEConfig(num_experts=8, routing=routing,
                                        top_k=2, num_prototypes=2,
                                        group_size=32, capacity_factor=2.0,
                                        impl="alltoall"))
        rules = make_rules(cfg, mesh)
        assert rules.params["expert"] == "model"
        params = init(moe_ffn_specs(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 32))  # 8 groups
        cfg_e = cfg.replace_moe(impl="einsum")

        def fwd(p, xx):
            with use_rules(rules):
                return moe_ffn_apply(p, xx, cfg)[0]

        def loss(c, r):
            def g(p, xx):
                with use_rules(r):
                    return jnp.sum(moe_ffn_apply(p, xx, c)[0] ** 2)
            return g

        y0 = jax.jit(lambda p, xx: moe_ffn_apply(p, xx, cfg_e)[0])(params, x)
        with mesh:
            y1 = jax.jit(fwd)(params, x)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(jax.device_get(y1)),
                                   atol=2e-5)

        g_e = jax.grad(loss(cfg_e, None))(params, x)
        with mesh:
            g_a = jax.jit(jax.grad(loss(cfg, rules)))(params, x)
        for k in g_e:
            a = np.asarray(g_e[k]); b = np.asarray(jax.device_get(g_a[k]))
            np.testing.assert_allclose(a, b, atol=1e-4 * max(np.abs(a).max(), 1e-9),
                                       err_msg=routing + "/" + k)

        # structural: no dense one-hot tensors, global or per-shard
        xg, G = group_tokens(x, cfg.moe)
        T = xg.shape[1]
        E, C = cfg.moe.num_experts, cfg.moe.capacity(T)
        with use_rules(rules):
            closed = jax.make_jaxpr(lambda p, xx: moe_ffn_apply(p, xx, cfg)[0])(params, x)
        shapes = {getattr(a, "shape", None) for a in walk(closed.jaxpr)}
        assert (G, T, E, C) not in shapes, (routing, "global dense")
        assert (G // 8, T, E, C) not in shapes, (routing, "per-shard dense")
        # the shard_map body must actually contain the two all_to_alls
        txt = str(closed)
        assert "all_to_all" in txt, routing
        print(routing, "mesh-ok")
    """
    # 4 routers x (fwd + bwd) compiles are heavy on a 2-core CI box:
    # give the subprocess real headroom over the ~8 min observed runtime.
    out = _run_sub(code, timeout=1500)
    for routing in ALL_ROUTERS:
        assert f"{routing} mesh-ok" in out
