"""Regression tests: ``repro.launch.dryrun`` must *append* its
``--xla_force_host_platform_device_count`` to caller-set ``XLA_FLAGS``
at import time — never clobber them — and must respect a device count
the caller already forced (it used to overwrite both, silently dropping
e.g. a debugger's dump flags and breaking any parent that had already
pinned a smaller virtual-device grid).

Each test runs in a subprocess because the flag block executes once, at
first import, before jax initialises."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, **env_over) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_DRYRUN_DEVICES", None)
    env.update(env_over)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_appends_to_existing_xla_flags():
    """Caller-set flags survive, the device-count flag is added, and jax
    actually sees the requested virtual device count."""
    out = _run("""
        import os
        import repro.launch.dryrun  # noqa: F401  (flag block runs at import)
        flags = os.environ["XLA_FLAGS"]
        assert "--xla_cpu_enable_fast_math=false" in flags, flags
        assert "--xla_force_host_platform_device_count=4" in flags, flags
        import jax
        print("devices", jax.device_count())
    """, XLA_FLAGS="--xla_cpu_enable_fast_math=false",
        REPRO_DRYRUN_DEVICES="4")
    assert "devices 4" in out


def test_respects_caller_forced_device_count():
    """A device count the caller already forced wins: no second
    (conflicting) flag is appended."""
    out = _run("""
        import os
        import repro.launch.dryrun  # noqa: F401
        flags = os.environ["XLA_FLAGS"]
        assert flags.count("--xla_force_host_platform_device_count") == 1, flags
        import jax
        print("devices", jax.device_count())
    """, XLA_FLAGS="--xla_force_host_platform_device_count=2")
    assert "devices 2" in out


def test_default_is_512_virtual_devices():
    out = _run("""
        import os
        import repro.launch.dryrun  # noqa: F401
        print("flags:", os.environ["XLA_FLAGS"])
    """)
    assert "--xla_force_host_platform_device_count=512" in out
