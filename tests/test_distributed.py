"""Sharding rules, ZeRO specs, HLO parsing, costs validation, and a
small-mesh end-to-end pjit train step (run through conftest's shared
`run_sub` fixture: a subprocess with 8 virtual devices, so the main test
process keeps 1 device)."""
import jax
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_smoke_config
from repro.distributed.costs import flops_for


def test_rules_divisibility_fallbacks(run_sub):
    """granite: 40 experts / 24 heads don't divide 16 -> replicated, with
    expert-TP fallback sharding the per-expert ffn dim instead."""
    code = """
    import jax
    from repro.configs.base import ModelConfig, MoEConfig
    from repro.distributed.sharding import make_rules
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    # 6 experts / 5 heads do not divide the 4-way model axis (the granite-
    # on-16 situation, scaled to this 8-device test mesh)
    cfg = ModelConfig(num_heads=5, num_kv_heads=2, head_dim=10, d_model=60,
                      d_ff=32, moe=MoEConfig(num_experts=6, top_k=2))
    rules = make_rules(cfg, mesh)
    assert rules.params["expert"] is None, rules.params
    assert rules.params["mlp"] == "model"      # expert-TP fallback
    assert rules.params["heads"] is None       # 5*10 % 4 != 0
    from repro.configs.registry import get_config
    cfg2 = get_config("olmoe-1b-7b")           # 64 experts divide 4
    rules2 = make_rules(cfg2, mesh)
    assert rules2.params["expert"] == "model"
    assert rules2.params["mlp"] is None        # EP consumes the axis
    # pure-DP mode folds the model axis into DP
    rules3 = make_rules(cfg, mesh, expert_axis="dp")
    assert rules3.acts["batch"] == ("data", "model")
    assert rules3.params["mlp"] is None
    print("rules-ok")
    """
    assert "rules-ok" in run_sub(code)


def test_pjit_train_step_multidevice_matches_single(run_sub):
    """2x4 mesh pjit train step == single-device step (same batch/seed)."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelConfig, MoEConfig, TrainConfig
    from repro.models.registry import get_family
    from repro.nn import init
    from repro.optim import make_optimizer, warmup_constant
    from repro.train.state import init_train_state
    from repro.train.trainer import make_train_step
    from repro.distributed.sharding import make_rules, param_shardings, use_rules

    cfg = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                      d_ff=64, vocab_size=128, dtype="float32",
                      moe=MoEConfig(num_experts=8, routing="prototype",
                                    num_prototypes=2, group_size=32,
                                    capacity_factor=8.0))
    fam = get_family(cfg)
    tc = TrainConfig(optimizer="adamw", learning_rate=1e-3)
    params = init(fam.specs(cfg), jax.random.PRNGKey(0))
    opt = make_optimizer(tc, warmup_constant(tc.learning_rate, tc.warmup_steps))
    step = make_train_step(cfg, tc, opt)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 33), 0, 128)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    # single device
    s1 = init_train_state(params, opt, "none")
    s1, m1 = jax.jit(step)(s1, batch)

    # 2x4 mesh
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = make_rules(cfg, mesh)
    p_shard = param_shardings(fam.specs(cfg), rules)
    def wrapped(state, b):
        with use_rules(rules):
            return step(state, b)
    sharded_params = jax.device_put(params, p_shard)
    s2 = init_train_state(sharded_params, opt, "none")
    with mesh:
        s2, m2 = jax.jit(wrapped)(s2, batch)
    print("LOSS", float(m1["loss"]), float(m2["loss"]))
    d = jax.tree_util.tree_map(lambda a, b: float(jnp.abs(a - b).max()),
                               s1.params, jax.device_get(s2.params))
    print("MAXDIFF", max(jax.tree_util.tree_leaves(d)))
    """
    out = run_sub(code)
    loss_line = [l for l in out.splitlines() if l.startswith("LOSS")][0]
    l1, l2 = map(float, loss_line.split()[1:])
    assert abs(l1 - l2) < 1e-4
    maxdiff = float([l for l in out.splitlines() if l.startswith("MAXDIFF")][0].split()[1])
    assert maxdiff < 1e-4


def test_elastic_checkpoint_reshard(run_sub):
    """Save on a (2,4) mesh, restore on (4,2) — elastic restart."""
    code = """
    import jax, jax.numpy as jnp, numpy as np, tempfile, os
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint.checkpointer import Checkpointer
    d = tempfile.mkdtemp()
    mesh1 = jax.make_mesh((2, 4), ("data", "model"))
    x = jnp.arange(64.0).reshape(8, 8)
    xs = jax.device_put(x, NamedSharding(mesh1, P("data", "model")))
    ck = Checkpointer(d)
    ck.save(1, {"x": xs})
    mesh2 = jax.make_mesh((4, 2), ("data", "model"))
    sh2 = {"x": NamedSharding(mesh2, P("model", "data"))}
    got = ck.restore(1, {"x": jax.eval_shape(lambda: x)}, shardings=sh2)
    np.testing.assert_array_equal(np.asarray(got["x"]), np.asarray(x))
    assert got["x"].sharding.spec == P("model", "data")
    print("elastic-ok")
    """
    assert "elastic-ok" in run_sub(code)


def test_hlo_collective_parser_trip_counts(run_sub):
    code = """
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.hlo import collective_bytes
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    def f(x, ws):
        def body(c, w):
            y = c @ w
            return jax.lax.with_sharding_constraint(
                y, NamedSharding(mesh, P("data", None))), None
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y)
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
    comp = jax.jit(f, in_shardings=(NamedSharding(mesh, P("data", None)),
                                    NamedSharding(mesh, P(None, None, "model")))
                   ).lower(x, ws).compile()
    cb = collective_bytes(comp.as_text())
    assert cb["all-gather"] == 6 * 64 * 64 * 4, cb   # trip-count weighted
    print("parser-ok")
    """
    assert "parser-ok" in run_sub(code)


@pytest.mark.parametrize("arch", ["qwen3-8b", "olmoe-1b-7b", "zamba2-7b",
                                  "seamless-m4t-large-v2", "xlstm-125m"])
def test_analytic_flops_vs_unrolled_cost_analysis(arch):
    """The roofline's analytic FLOPs agree with XLA cost_analysis on
    unrolled reduced-depth probes (within napkin tolerance)."""
    cfg = get_smoke_config(arch).replace(scan_layers=False, remat=False)
    from repro.models.registry import get_family
    from repro.nn import abstract
    from repro.train.losses import total_loss

    fam = get_family(cfg)
    shape = ShapeConfig("probe", seq_len=128, global_batch=4, kind="train")
    params = abstract(fam.specs(cfg))
    batch = fam.input_specs(cfg, shape)

    def f(p, b):
        logits, aux = fam.forward(p, b, cfg)
        return total_loss(logits, b["labels"], aux)[0]

    from repro.distributed.costs import cost_analysis_dict
    compiled = jax.jit(jax.grad(f)).lower(params, batch).compile()
    measured = cost_analysis_dict(compiled)["flops"]
    analytic = flops_for(cfg, shape)
    ratio = analytic / measured
    assert 0.6 < ratio < 1.7, (arch, ratio)
