"""Speculative decoding subsystem: drafter registry + drafters,
acceptance rules (greedy token-identity, rejection sampling preserving
the target distribution), paged-cache rollback, verify-shape paged
attention, scheduler admission policies, and end-to-end greedy parity
of the speculative continuous engine against the static engine."""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig, ServeConfig, SpecConfig
from repro.models.registry import get_family
from repro.nn import init
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PagedKVCache
from repro.serving.request import Request
from repro.serving.scheduler import Scheduler, available_policies, get_policy
from repro.serving.speculative import (
    available_drafters,
    get_drafter_cls,
    make_drafter,
)
from repro.serving.speculative.accept import (
    accept_greedy,
    accept_rejection,
    softmax_rows,
)
from repro.serving.speculative.base import DraftItem
from repro.serving.speculative.ngram import lookup_continuation


def tiny_cfg(**kw) -> ModelConfig:
    base = dict(name="t", family="decoder_lm", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                max_seq_len=128, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def build(cfg, seed=0):
    return init(get_family(cfg).specs(cfg), jax.random.PRNGKey(seed))


def draft_pair(cfg, seed=5):
    """A tiny draft model sharing the target's vocab."""
    dcfg = cfg.replace(name="draft", num_layers=1, d_model=32, d_ff=64,
                       num_heads=2, num_kv_heads=2, moe=MoEConfig())
    return dcfg, build(dcfg, seed=seed)


# ---------------------------------------------------------------------------
# Registries
# ---------------------------------------------------------------------------

def test_drafter_registry():
    assert {"ngram", "model"} <= set(available_drafters())
    assert get_drafter_cls("ngram").name == "ngram"
    with pytest.raises(ValueError, match="registered drafters"):
        get_drafter_cls("nope")
    with pytest.raises(ValueError):
        SpecConfig(drafter="nope")
    with pytest.raises(ValueError):
        SpecConfig(gamma=0)


def test_policy_registry():
    assert {"fcfs", "sjf", "prefill_first"} <= set(available_policies())
    with pytest.raises(ValueError, match="registered policies"):
        get_policy("nope")
    with pytest.raises(ValueError):
        ServeConfig(sched_policy="nope")


def test_model_drafter_requires_shared_vocab():
    cfg = tiny_cfg()
    dcfg = cfg.replace(vocab_size=cfg.vocab_size * 2)
    with pytest.raises(ValueError, match="vocab"):
        make_drafter(SpecConfig(drafter="model"), cfg, ServeConfig(),
                     draft_model=(dcfg, None))


# ---------------------------------------------------------------------------
# ngram (prompt-lookup) drafter
# ---------------------------------------------------------------------------

def test_ngram_lookup_repetition():
    # cycle ABCABC... : the trailing trigram recurs one period back, and
    # the longest-suffix match continues the cycle
    ctx = np.array([1, 2, 3, 1, 2, 3, 1, 2, 3, 1, 2], np.int32)
    out = lookup_continuation(ctx, max_tokens=4, max_ngram=3)
    assert out.tolist() == [3, 1, 2, 3]
    # shorter context: the earliest match still yields what is available
    short = np.array([1, 2, 3, 1, 2, 3, 1, 2], np.int32)
    assert lookup_continuation(short, 4, 3).tolist() == [3, 1, 2]


def test_ngram_lookup_prefers_full_continuation():
    # suffix [9] matches at positions 0 and 3; only the first leaves a
    # 3-token continuation, so it must win over the more recent one
    ctx = np.array([9, 5, 6, 9, 7, 9], np.int32)
    out = lookup_continuation(ctx, max_tokens=3, max_ngram=1)
    assert out.tolist() == [5, 6, 9]


def test_ngram_lookup_no_match_and_budget():
    assert lookup_continuation(np.arange(10, 20), 4, 3).size == 0
    assert lookup_continuation(np.array([7]), 4, 3).size == 0
    ctx = np.array([1, 2, 1, 2], np.int32)
    assert lookup_continuation(ctx, 0, 3).size == 0
    # budget respected even when more continuation is available
    ctx = np.array([1, 2, 3, 4, 1], np.int32)
    assert lookup_continuation(ctx, 2, 1).tolist() == [2, 3]


# ---------------------------------------------------------------------------
# Acceptance rules
# ---------------------------------------------------------------------------

def test_accept_greedy_prefix():
    rows = np.zeros((4, 8), np.float32)
    rows[0, 3] = rows[1, 5] = rows[2, 1] = rows[3, 6] = 10.0  # argmax 3,5,1,6
    # draft matches argmax for 2 rows then diverges
    emitted, n = accept_greedy(np.array([3, 5, 2]), rows)
    assert (emitted, n) == ([3, 5, 1], 2)
    # full acceptance earns the bonus token
    emitted, n = accept_greedy(np.array([3, 5, 1]), rows)
    assert (emitted, n) == ([3, 5, 1, 6], 3)
    # immediate rejection still emits the row-0 argmax
    emitted, n = accept_greedy(np.array([0, 0, 0]), rows)
    assert (emitted, n) == ([3], 0)


def test_rejection_sampling_preserves_target_distribution():
    """With a point-mass draft, the first emitted token must be
    distributed exactly as the target softmax regardless of what the
    drafter proposed (the speculative-sampling theorem)."""
    rng = np.random.default_rng(0)
    V, temp = 6, 0.7
    logits = rng.standard_normal((1, V)).astype(np.float32) * 2.0
    p = softmax_rows(logits, temp)[0]
    trials = 20_000
    for d in (int(np.argmax(p)), int(np.argmin(p))):  # likely + unlikely draft
        counts = np.zeros(V)
        for t in range(trials):
            gen = np.random.default_rng(t)
            emitted, _ = accept_rejection(
                np.array([d]), np.vstack([logits, logits]), temp,
                lambda j, g=gen: g)
            counts[emitted[0]] += 1
        np.testing.assert_allclose(counts / trials, p, atol=0.015)


def test_rejection_sampling_deterministic_per_key():
    logits = np.random.default_rng(1).standard_normal((3, 8)).astype(np.float32)
    draft = np.array([2, 5])

    def rngs(j):
        return np.random.default_rng(
            np.random.SeedSequence(entropy=[0, 4, 10 + j]))

    a = accept_rejection(draft, logits, 0.8, rngs)
    b = accept_rejection(draft, logits, 0.8, rngs)
    assert a == b


# ---------------------------------------------------------------------------
# Paged-cache rollback (truncate_slot)
# ---------------------------------------------------------------------------

def test_truncate_slot_returns_blocks_and_conserves():
    cfg = tiny_cfg()
    serve = ServeConfig(max_slots=2, kv_block_size=8, max_len=64)
    cache = PagedKVCache(cfg, serve)
    cache.allocate_slot(0, 40)                  # reserves 5 blocks, holds 0
    assert cache.held_blocks(0) == 0
    cache.ensure_capacity(0, 20)                # 3 blocks
    held3 = cache.allocator.allocated_count
    assert cache.held_blocks(0) == 3 == held3
    cache.ensure_capacity(0, 33)                # grow to 5
    assert cache.held_blocks(0) == 5
    cache.truncate_slot(0, 17)                  # rollback to 3 blocks
    assert cache.held_blocks(0) == 3
    assert cache.allocator.free_count == cache.num_blocks - 3
    cache.check_conservation()
    # table rows never dangle: freed tail points at garbage again
    assert (cache.block_table[0, 3:] == cache.garbage_block).all()
    cache.ensure_capacity(0, 40)                # grow back within reservation
    assert cache.held_blocks(0) == 5
    cache.truncate_slot(0, 0)                   # full rewind
    assert cache.held_blocks(0) == 0
    cache.free_slot(0)
    cache.check_conservation()
    assert cache.allocator.free_count == cache.num_blocks


def test_ensure_capacity_respects_reservation():
    cfg = tiny_cfg()
    cache = PagedKVCache(cfg, ServeConfig(max_slots=2, kv_block_size=8,
                                          max_len=64))
    cache.allocate_slot(0, 16)                  # 2 blocks reserved
    with pytest.raises(AssertionError):
        cache.ensure_capacity(0, 17)            # 3rd block not reserved


# ---------------------------------------------------------------------------
# Verify-shape paged attention (gamma+1 consecutive rows per slot)
# ---------------------------------------------------------------------------

def _verify_shape_case(rng, B=3, T=48, Hq=8, Hkv=4, D=16, bs=8, gamma=3):
    """Rows = (slot, consecutive positions c..c+gamma) — the speculative
    verify layout: every row of a slot shares one block table, lengths
    ascend by one."""
    from tests.test_serving import _pack_pool

    k = rng.standard_normal((B, T, Hkv, D)).astype(np.float32)
    v = rng.standard_normal((B, T, Hkv, D)).astype(np.float32)
    k_pool, v_pool, tables = _pack_pool(k, v, bs, rng)
    c = np.array([5, 17, 0], np.int32)          # per-slot base context
    N = B * (gamma + 1)
    q = rng.standard_normal((N, Hq, D)).astype(np.float32)
    row_tables = np.zeros((N, tables.shape[1]), np.int32)
    lengths = np.zeros(N, np.int32)
    for b in range(B):
        for j in range(gamma + 1):
            r = b * (gamma + 1) + j
            row_tables[r] = tables[b]
            lengths[r] = c[b] + j + 1
    return q, k, v, k_pool, v_pool, row_tables, lengths


def test_paged_attention_verify_shape_matches_dense():
    from repro.kernels.decode_attention import (
        decode_attention_ref,
        paged_decode_attention,
    )

    rng = np.random.default_rng(2)
    gamma = 3
    q, k, v, k_pool, v_pool, row_tables, lengths = _verify_shape_case(rng)
    # dense oracle: replicate each slot's cache per row
    B = k.shape[0]
    reps = np.repeat(np.arange(B), gamma + 1)
    dense = decode_attention_ref(jnp.asarray(q), jnp.asarray(k[reps]),
                                 jnp.asarray(v[reps]), jnp.asarray(lengths))
    paged = paged_decode_attention(jnp.asarray(q), jnp.asarray(k_pool),
                                   jnp.asarray(v_pool),
                                   jnp.asarray(row_tables),
                                   jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(paged), np.asarray(dense), atol=1e-5)


def test_paged_kernel_interpret_verify_shape():
    from repro.kernels.decode_attention.kernel import paged_decode_attention_kernel
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref

    rng = np.random.default_rng(3)
    q, _, _, k_pool, v_pool, row_tables, lengths = _verify_shape_case(rng)
    N, Hq, D = q.shape
    Hkv = k_pool.shape[1]
    out = paged_decode_attention_kernel(
        jnp.asarray(q).reshape(N, Hkv, Hq // Hkv, D), jnp.asarray(k_pool),
        jnp.asarray(v_pool), jnp.asarray(row_tables), jnp.asarray(lengths),
        interpret=True).reshape(N, Hq, D)
    ref = paged_decode_attention_ref(jnp.asarray(q), jnp.asarray(k_pool),
                                     jnp.asarray(v_pool),
                                     jnp.asarray(row_tables),
                                     jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


# ---------------------------------------------------------------------------
# Admission policies
# ---------------------------------------------------------------------------

def _policy_sched(policy):
    cfg = tiny_cfg()
    # 6 blocks of 8: uid 0 needs 4 blocks, uid 1 needs 3, uid 2 needs 1
    serve = ServeConfig(max_slots=2, kv_block_size=8, max_len=48, num_blocks=6)
    cache = PagedKVCache(cfg, serve)
    sched = Scheduler(serve.max_slots, serve.max_len, cache, policy=policy)
    sched.add(Request(uid=0, prompt=np.arange(20), max_new_tokens=10))  # 30 tok
    sched.add(Request(uid=1, prompt=np.arange(12), max_new_tokens=8))   # 20 tok
    sched.add(Request(uid=2, prompt=np.arange(4), max_new_tokens=4))    # 8 tok
    return sched


def test_sjf_admits_shortest_first():
    sched = _policy_sched("sjf")
    assert [st.request.uid for st in sched.admit(0.0)] == [2, 1]
    sched.check_conservation()


def test_prefill_first_backfills_past_blocked_head():
    sched = _policy_sched("prefill_first")
    # head (uid 0, 4 blocks) admitted; uid 1 (3 blocks) no longer fits
    # but uid 2 (1 block) backfills — fcfs would stall behind uid 1
    assert [st.request.uid for st in sched.admit(0.0)] == [0, 2]
    sched.check_conservation()


def test_fcfs_head_blocks_queue():
    sched = _policy_sched("fcfs")
    assert [st.request.uid for st in sched.admit(0.0)] == [0]
    assert sched.admit(0.0) == []               # uid 1 blocked, uid 2 waits


def test_policies_respect_arrival_times():
    cfg = tiny_cfg()
    serve = ServeConfig(max_slots=2, kv_block_size=8, max_len=48)
    for policy in available_policies():
        sched = Scheduler(2, 48, PagedKVCache(cfg, serve), policy=policy)
        sched.add(Request(uid=0, prompt=np.arange(4), max_new_tokens=4,
                          arrival_ms=50.0))
        assert sched.admit(0.0) == []
        assert [st.request.uid for st in sched.admit(50.0)] == [0]


def test_engine_runs_with_each_policy():
    cfg = tiny_cfg(num_layers=1)
    params = build(cfg)
    from repro.serving.trace import synthetic_trace

    reqs = synthetic_trace(5, cfg.vocab_size, seed=1, qps=1e6,
                           prompt_lens=(3, 10), gen_lens=(2, 5))
    outs = {}
    for policy in available_policies():
        eng = ContinuousEngine(
            cfg, params, ServeConfig(max_slots=2, kv_block_size=8,
                                     prefill_chunk=8, max_len=32,
                                     sched_policy=policy),
            check_invariants=True)
        outs[policy], _ = eng.run(reqs)
        eng.scheduler.check_conservation()
    # greedy decode: per-request outputs are policy-invariant
    assert outs["sjf"] == outs["fcfs"] == outs["prefill_first"]


# ---------------------------------------------------------------------------
# End-to-end greedy parity: speculative == non-speculative == static
# ---------------------------------------------------------------------------

def _spec_parity(cfg, B, S, gen, serve, drafter, draft_model=None, seed=0):
    import dataclasses

    params = build(cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    toks_s, _ = ServingEngine(cfg, params, max_len=S + gen + 1).generate(prompts, gen)
    sv = dataclasses.replace(serve, spec=SpecConfig(drafter=drafter, gamma=3))
    eng = ContinuousEngine(cfg, params, sv, draft_model=draft_model,
                           check_invariants=True)
    toks_c, stats = eng.generate(prompts, gen)
    np.testing.assert_array_equal(np.asarray(toks_s), np.asarray(toks_c))
    return eng, stats


def test_spec_parity_dense_ngram_slot_reuse():
    # 4 requests on 2 slots: slot reuse + queueing under speculation
    eng, stats = _spec_parity(
        tiny_cfg(num_layers=1), B=4, S=9, gen=8,
        serve=ServeConfig(max_slots=2, kv_block_size=8, prefill_chunk=4,
                          max_len=32), drafter="ngram")
    assert stats["steps"] > 0 and eng.spec_stats["verify_steps"] > 0


def test_spec_parity_dense_model_drafter():
    cfg = tiny_cfg(num_layers=1)
    _spec_parity(cfg, B=3, S=7, gen=7,
                 serve=ServeConfig(max_slots=2, kv_block_size=8,
                                   prefill_chunk=4, max_len=32),
                 drafter="model", draft_model=draft_pair(cfg))


def test_spec_parity_moe_dropless_hash():
    cfg = tiny_cfg(d_ff=96,
                   moe=MoEConfig(num_experts=4, routing="hash", top_k=2,
                                 impl="dropless", capacity_factor=None,
                                 group_size=64))
    _spec_parity(cfg, B=2, S=9, gen=7,
                 serve=ServeConfig(max_slots=2, kv_block_size=8,
                                   prefill_chunk=4, max_len=64),
                 drafter="ngram")


def test_spec_parity_moe_dropless_hash_model():
    cfg = tiny_cfg(d_ff=96,
                   moe=MoEConfig(num_experts=4, routing="hash", top_k=2,
                                 impl="dropless", capacity_factor=None,
                                 group_size=64))
    _spec_parity(cfg, B=2, S=8, gen=6,
                 serve=ServeConfig(max_slots=2, kv_block_size=8,
                                   prefill_chunk=4, max_len=32),
                 drafter="model", draft_model=draft_pair(cfg))


def test_spec_parity_moe_dropless_topk_ngram():
    cfg = tiny_cfg(d_ff=96,
                   moe=MoEConfig(num_experts=4, routing="topk", top_k=2,
                                 impl="dropless", capacity_factor=None,
                                 group_size=64))
    _spec_parity(cfg, B=2, S=9, gen=7,
                 serve=ServeConfig(max_slots=2, kv_block_size=8,
                                   prefill_chunk=4, max_len=64),
                 drafter="ngram")


def test_spec_parity_moe_dropless_topk_model():
    cfg = tiny_cfg(d_ff=96,
                   moe=MoEConfig(num_experts=4, routing="topk", top_k=2,
                                 impl="dropless", capacity_factor=None,
                                 group_size=64))
    _spec_parity(cfg, B=2, S=8, gen=6,
                 serve=ServeConfig(max_slots=2, kv_block_size=8,
                                   prefill_chunk=8, max_len=32),
                 drafter="model", draft_model=draft_pair(cfg))


def test_spec_multi_token_bursts_and_conservation():
    """A repetitive prompt makes the ngram drafter productive: some step
    must emit > 1 token for a slot, and slot/block/reservation
    conservation holds after every step (check_invariants=True asserts
    in-step; re-assert the drained end state)."""
    cfg = tiny_cfg(num_layers=1)
    params = build(cfg)
    serve = ServeConfig(max_slots=2, kv_block_size=8, prefill_chunk=8,
                        max_len=64, spec=SpecConfig(drafter="ngram", gamma=4))
    eng = ContinuousEngine(cfg, params, serve, check_invariants=True)
    prompt = np.tile(np.array([5, 9, 7], np.int32), 5)      # strongly cyclic
    out, stats = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=24),
                          Request(uid=1, prompt=prompt[:7], max_new_tokens=20)])
    assert len(out[0]) == 24 and len(out[1]) == 20
    assert stats["spec_tokens_per_step"] > 1.0
    assert eng.spec_stats["accepted"] > 0
    eng.scheduler.check_conservation()
    assert eng.cache.allocator.free_count == serve.resolved_num_blocks


def test_spec_eos_mid_burst():
    """EOS inside an accepted burst truncates the emission at (and
    including) the EOS token, exactly like sequential decoding."""
    cfg = tiny_cfg(num_layers=1)
    params = build(cfg)

    def run(spec):
        sv = ServeConfig(max_slots=1, kv_block_size=8, prefill_chunk=8,
                         max_len=64, spec=spec)
        eng = ContinuousEngine(cfg, params, sv, check_invariants=True)
        return eng.run([Request(uid=0, prompt=np.arange(5),
                                max_new_tokens=16)])[0][0]

    base = run(None)
    eos = base[2]
    sv = SpecConfig(drafter="ngram", gamma=4)
    eng = ContinuousEngine(cfg, params,
                           ServeConfig(max_slots=1, kv_block_size=8,
                                       prefill_chunk=8, max_len=64, spec=sv),
                           check_invariants=True)
    out, _ = eng.run([Request(uid=0, prompt=np.arange(5), max_new_tokens=16,
                              eos_id=int(eos))])
    assert out[0] == base[:base.index(eos) + 1]
    # acceptance accounting counts only draft tokens actually used: the
    # EOS cut discards accepted-but-dropped drafts
    assert eng.spec_stats["accepted"] <= eng.spec_stats["emitted"]
    eng.scheduler.check_conservation()


def test_spec_temperature_runs_and_is_reproducible():
    cfg = tiny_cfg(num_layers=1)
    params = build(cfg)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (3, 6),
                                            0, cfg.vocab_size))

    def run():
        sv = ServeConfig(max_slots=2, kv_block_size=8, prefill_chunk=4,
                         max_len=32, spec=SpecConfig(drafter="ngram", gamma=3))
        eng = ContinuousEngine(cfg, params, sv, temperature=0.8, seed=3,
                               check_invariants=True)
        reqs = [Request(uid=i, prompt=prompts[i], max_new_tokens=6)
                for i in range(3)]
        return eng.run(reqs)[0]

    out1, out2 = run(), run()
    assert out1 == out2                        # per-(slot, position) keys
    assert all(len(v) == 6 for v in out1.values())
    assert all(0 <= t < cfg.vocab_size for v in out1.values() for t in v)


def test_empty_drafts_fall_back_to_decode_step():
    """A drafter that never proposes must cost (nearly) nothing: the
    engine falls through to the ordinary decode step instead of paying
    a (gamma+1)x verify forward for one token per slot.  Also exercises
    the registry plugin path."""
    from repro.serving.speculative import register_drafter

    @register_drafter
    class NullDrafter:
        name = "null-test"

        def __init__(self, spec, target_cfg, serve, *, seed=0,
                     draft_model=None):
            pass

        def propose(self, items):
            return [np.empty(0, np.int32) for _ in items]

    cfg = tiny_cfg(num_layers=1)
    params = build(cfg)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (2, 6),
                                            0, cfg.vocab_size))
    base, _ = ContinuousEngine(
        cfg, params, ServeConfig(max_slots=2, kv_block_size=8,
                                 prefill_chunk=4, max_len=32)
    ).generate(prompts, 6)
    eng = ContinuousEngine(
        cfg, params, ServeConfig(max_slots=2, kv_block_size=8,
                                 prefill_chunk=4, max_len=32,
                                 spec=SpecConfig(drafter="null-test")),
        check_invariants=True)
    toks, _ = eng.generate(prompts, 6)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(toks))
    assert eng.spec_stats["verify_steps"] == 0   # every step fell through


def test_spec_requires_paged_mode():
    cfg = ModelConfig(name="x", family="xlstm", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype="float32")
    with pytest.raises(NotImplementedError, match="rollback"):
        ContinuousEngine(cfg, {}, ServeConfig(
            spec=SpecConfig(drafter="ngram")))


# ---------------------------------------------------------------------------
# Example smoke (CI satellite)
# ---------------------------------------------------------------------------

def test_example_serve_decode_smoke():
    """examples/serve_decode.py --fast: static + continuous + speculative
    demo end-to-end at tiny scale."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, os.path.join(root, "examples", "serve_decode.py"),
         "--fast"],
        capture_output=True, text=True, timeout=900, env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "speculative" in proc.stdout
