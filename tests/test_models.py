"""Model-family behaviour: decode==parallel equivalences, cache handling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import transformer as TF
from repro.models import xlstm as XL
from repro.models import mamba2 as M2
from repro.models import zamba as ZB
from repro.models import encdec as ED
from repro.nn import init


def test_transformer_prefill_decode_matches_full():
    # capacity_factor high enough that no token is ever dropped -> exact
    cfg = ModelConfig(num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
                      d_ff=96, vocab_size=97, dtype="float32",
                      moe=MoEConfig(num_experts=4, routing="prototype",
                                    num_prototypes=2, group_size=32,
                                    capacity_factor=8.0))
    params = init(TF.lm_specs(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 97)
    full, _ = jax.jit(lambda p, t: TF.lm_apply(p, t, cfg))(params, toks)
    lg, caches, _ = jax.jit(lambda p, t: TF.prefill_apply(p, t, cfg, max_len=16))(
        params, toks[:, :8])
    errs = [float(jnp.abs(lg[:, 7] - full[:, 7]).max())]
    for i in range(8, 12):
        lg2, caches = jax.jit(lambda p, t, c: TF.decode_apply(p, t, c, cfg))(
            params, toks[:, i:i + 1], caches)
        errs.append(float(jnp.abs(lg2[:, 0] - full[:, i]).max()))
    assert max(errs) < 3e-4, errs


def test_chunked_attention_in_model_matches_reference():
    base = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                       d_ff=96, vocab_size=97, dtype="float32")
    params = init(TF.lm_specs(base), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0, 97)
    ref, _ = jax.jit(lambda p, t: TF.lm_apply(p, t, base.replace(attention_impl="reference")))(params, toks)
    chk, _ = jax.jit(lambda p, t: TF.lm_apply(p, t, base.replace(
        attention_impl="chunked", attention_block=16)))(params, toks)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(chk), atol=3e-4)


def test_xlstm_decode_matches_parallel():
    cfg = ModelConfig(family="xlstm", num_layers=4, d_model=48, num_heads=4,
                      num_kv_heads=4, vocab_size=61, xlstm_slstm_period=4,
                      ssm_chunk=16, dtype="float32")
    params = init(XL.xlstm_specs(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, 61)
    full, _, _ = jax.jit(lambda p, t: XL.xlstm_apply(p, t, cfg))(params, toks)
    states = XL.xlstm_init_states(cfg, 2)
    for i in range(10):
        lg, _, states = jax.jit(lambda p, t, s: XL.xlstm_apply(p, t, cfg, states=s))(
            params, toks[:, i:i + 1], states)
        np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, i]),
                                   atol=2e-4)


def test_mamba2_chunk_invariance_and_decode():
    cfg = ModelConfig(d_model=32, ssm_state=8, ssm_heads=4, ssm_chunk=8,
                      dtype="float32")
    params = init(M2.mamba2_block_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32)) * 0.3
    y1, _ = M2.mamba2_block_apply(params, x, cfg)
    y2, _ = M2.mamba2_block_apply(params, x, cfg.replace(ssm_chunk=24))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    st = M2.mamba2_init_state(cfg, 2)
    for i in range(8):
        yi, st = M2.mamba2_block_apply(params, x[:, i:i + 1], cfg, state=st)
        np.testing.assert_allclose(np.asarray(yi[:, 0]), np.asarray(y1[:, i]), atol=1e-5)


def test_zamba_decode_matches_parallel():
    cfg = ModelConfig(family="zamba", num_layers=5, d_model=32, num_heads=4,
                      num_kv_heads=4, d_ff=64, vocab_size=61, ssm_state=8,
                      ssm_heads=4, ssm_chunk=8, zamba_shared_period=2,
                      dtype="float32")
    params = init(ZB.zamba_specs(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 61)
    full, _, _ = jax.jit(lambda p, t: ZB.zamba_apply(p, t, cfg))(params, toks)
    state = ZB.zamba_init_state(cfg, 2, max_len=12)
    for i in range(8):
        lg, _, state = jax.jit(lambda p, t, s: ZB.zamba_apply(p, t, cfg, state=s))(
            params, toks[:, i:i + 1], state)
        np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, i]),
                                   atol=3e-4)


def test_encdec_decode_matches_teacher_forcing():
    cfg = ModelConfig(family="encdec", num_layers=2, num_encoder_layers=2,
                      d_model=48, num_heads=4, num_kv_heads=4, d_ff=64,
                      vocab_size=73, norm="layernorm", ffn_activation="relu",
                      dtype="float32")
    params = init(ED.encdec_specs(cfg), jax.random.PRNGKey(0))
    frames = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 48))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 7), 0, 73)
    full = jax.jit(lambda p, f, t: ED.encdec_train_apply(p, f, t, cfg)[0])(
        params, frames, toks)
    memory = ED.encode(params, frames, cfg)
    state = ED.init_state(params, memory, cfg, max_len=8)
    for i in range(7):
        lg, state = jax.jit(lambda p, t, s: ED.decode_step(p, t, s, cfg))(
            params, toks[:, i:i + 1], state)
        np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, i]),
                                   atol=3e-4)


def test_encdec_moe_decode_matches_teacher_forcing():
    """MoE decoder FFN (hash routing by token id, no drops): decode-time
    routing sees the same token identity the teacher-forcing pass saw, so
    step-by-step decode reproduces the full forward exactly."""
    from repro.configs.base import MoEConfig

    cfg = ModelConfig(family="encdec", num_layers=2, num_encoder_layers=2,
                      d_model=48, num_heads=4, num_kv_heads=4, d_ff=64,
                      vocab_size=73, norm="layernorm", ffn_activation="relu",
                      dtype="float32",
                      moe=MoEConfig(num_experts=4, routing="hash", top_k=1,
                                    group_size=32, capacity_factor=16.0))
    params = init(ED.encdec_specs(cfg), jax.random.PRNGKey(0))
    frames = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 48))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 7), 0, 73)
    full, aux = jax.jit(lambda p, f, t: ED.encdec_train_apply(p, f, t, cfg))(
        params, frames, toks)
    assert abs(float(aux["moe_dropped_fraction"].sum())) < 1e-6
    memory = ED.encode(params, frames, cfg)
    state = ED.init_state(params, memory, cfg, max_len=8)
    for i in range(7):
        lg, state = jax.jit(lambda p, t, s: ED.decode_step(p, t, s, cfg))(
            params, toks[:, i:i + 1], state)
        np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, i]),
                                   atol=3e-4)


def test_vlm_prefix_positions():
    cfg = ModelConfig(num_layers=2, d_model=32, num_heads=4, num_kv_heads=4,
                      d_ff=64, vocab_size=61, num_image_tokens=4, dtype="float32")
    params = init(TF.lm_specs(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, 61)
    embeds = jax.random.normal(jax.random.PRNGKey(2), (2, 4, 32))
    logits, _ = TF.lm_apply(params, toks, cfg, extra_embeds=embeds)
    assert logits.shape[1] == 10  # image prefix + text
